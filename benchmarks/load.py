"""Load/SLO harness for the network serving front-end.

Boots an in-process `EngineServer` over the demo ASR engine
(`repro.launch.serve.asr_demo_engine`) and replays N concurrent
staggered synthetic utterance streams against it through the real wire
protocol (`AsrClient`: HTTP chunked push/poll/finish).  Reports, per
group:

  * first-result latency p50/p95/p99 — client-observed time from
    opening the stream to the first poll whose hypothesis covers a
    decoded step
  * finalize latency p50/p95/p99 — finish() round-trip to the final
    transcript
  * throughput (completed utterances/s and x realtime audio)
  * rejection rate + engine-side max queue depth (the backpressure
    policy under overload: sessions beyond `--max-queue` get 503)

  PYTHONPATH=src python -m benchmarks.load --streams 100 --slots 8 \\
      --json BENCH_load.json
  PYTHONPATH=src python -m benchmarks.load --streams 48 --slots 2 \\
      --max-queue 4 --stagger-ms 0 --group overload --json BENCH_load.json

Rows are written/merged into the ``--json`` mapping as
``<group>_<metric>`` keys (same contract as benchmarks/run.py);
benchmarks/compare.py ``--load`` annotates p95 regressions between a
committed BENCH_load.json and a fresh run.
"""
from __future__ import annotations

import argparse
import asyncio
import json
import pathlib
import time

import numpy as np

ROWS = {}


def row(name: str, value, unit: str = ""):
    # integer counters (stream/slot/queue-depth counts) round-trip as
    # JSON ints — emitting them as 8.0/100.0 made compare.py --load
    # diffs format-drift against hand-read baselines
    if isinstance(value, (int, np.integer)) and not isinstance(value, bool):
        ROWS[name] = int(value)
    else:
        ROWS[name] = round(float(value), 4)
    print(f"{name},{ROWS[name]}{',' + unit if unit else ''}", flush=True)


def _pct(seconds: list, q: float) -> float:
    return float(np.percentile(np.asarray(seconds, float), q)) * 1e3


async def _run_stream(host: str, port: int, audio: np.ndarray,
                      chunk: int, stagger_s: float, realtime: bool,
                      retries: int = 0, backoff: float = 0.05,
                      seed: int = 0) -> dict:
    """One client: staggered open (with optional jittered retry on
    503), chunked pushes with a poll after each, finish; returns
    client-observed latencies (or the rejection / fault)."""
    from repro.serving.server import AsrClient, ServerRejected

    await asyncio.sleep(stagger_s)
    t0 = time.perf_counter()
    try:
        try:
            client = await AsrClient.open(host, port, retries=retries,
                                          backoff=backoff, seed=seed)
        except ServerRejected:
            return {"rejected": True}
        first = None
        for off in range(0, len(audio), chunk):
            res = await client.push(audio[off:off + chunk])
            if res.get("error"):
                return {"rejected": False, "faulted": True,
                        "error": res["error"]}
            res = await client.poll()
            if res.get("error"):
                return {"rejected": False, "faulted": True,
                        "error": res["error"]}
            if first is None and res["steps"] > 0:
                first = time.perf_counter() - t0
            if realtime:
                await asyncio.sleep(chunk / 16000.0)
        t_fin = time.perf_counter()
        final = await client.finish()
        t_end = time.perf_counter()
        if final.get("error"):
            return {"rejected": False, "faulted": True,
                    "error": final["error"]}
    except ConnectionError:
        return {"rejected": True}
    if first is None:            # tail-flush produced the only step
        first = t_end - t0
    return {"rejected": False, "faulted": False, "first_result_s": first,
            "finalize_s": t_end - t_fin, "e2e_s": t_end - t0,
            "audio_s": len(audio) / 16000.0, "steps": final["steps"]}


async def _run_load(args) -> dict:
    from repro.data.pipeline import SyntheticASR
    from repro.launch.serve import asr_demo_engine
    from repro.serving.server import EngineServer, fetch_metrics

    engine, words = asr_demo_engine(args.slots, max_queue=args.max_queue)
    data = SyntheticASR(words)
    utts = [data.utterance(i % 16)["audio"] for i in range(args.streams)]
    chunk = max(1, int(16000 * args.chunk_ms / 1000.0))

    server = EngineServer(asr_engine=engine, host="127.0.0.1", port=0)
    await server.start()
    try:
        # warmup wave (excluded from stats): traces the fused-step jit
        # buckets the measured wave will hit, so the report shows
        # steady-state serving latency, not first-use compile time
        n_warm = args.slots if args.warmup is None else args.warmup
        if n_warm:
            await asyncio.gather(*[
                _run_stream(server.host, server.port,
                            utts[i % len(utts)], chunk, i * 0.01, False)
                for i in range(n_warm)])
        pre = (await fetch_metrics(server.host, server.port))["asr"]
        t0 = time.perf_counter()
        outs = await asyncio.gather(*[
            _run_stream(server.host, server.port, audio, chunk,
                        i * args.stagger_ms / 1000.0, args.realtime,
                        retries=args.retries, backoff=args.backoff, seed=i)
            for i, audio in enumerate(utts)])
        wall = time.perf_counter() - t0
        metrics = (await fetch_metrics(server.host, server.port))["asr"]
    finally:
        await server.aclose()
    rejected_in_run = (metrics["sessions"]["rejected"]
                       - pre["sessions"]["rejected"])
    return {"outs": outs, "wall": wall, "metrics": metrics,
            "rejected_in_run": rejected_in_run}


def report(args, res: dict) -> None:
    g = args.group
    outs, wall, metrics = res["outs"], res["wall"], res["metrics"]
    n_faulted = sum(1 for o in outs if o.get("faulted"))
    done = [o for o in outs
            if not o["rejected"] and not o.get("faulted")]
    n_rejected = len(outs) - len(done) - n_faulted
    assert done, "every stream was rejected — raise --max-queue"

    row(f"{g}_streams", len(outs))
    row(f"{g}_slots", args.slots)
    for metric in ("first_result", "finalize"):
        vals = [o[f"{metric}_s"] for o in done]
        for q in (50, 95, 99):
            row(f"{g}_{metric}_p{q}_ms", _pct(vals, q), "ms")
    row(f"{g}_e2e_p95_ms", _pct([o["e2e_s"] for o in done], 95), "ms")
    row(f"{g}_wall_s", wall, "s")
    row(f"{g}_throughput_utt_per_s", len(done) / wall)
    row(f"{g}_throughput_x_realtime",
        sum(o["audio_s"] for o in done) / wall)
    row(f"{g}_rejection_rate", n_rejected / len(outs))
    row(f"{g}_faulted", n_faulted)
    row(f"{g}_max_queue_depth", metrics["queue"]["max_depth"])
    row(f"{g}_occupancy", metrics["steps"]["occupancy"] or 0.0)
    if args.max_queue is not None:
        # the backpressure invariant the SLO story rests on (also
        # pinned by tests): overload bounds the queue, never grows it
        assert metrics["queue"]["max_depth"] <= args.max_queue, metrics
        if args.retries == 0:
            # with retries, each 503'd attempt bumps the server-side
            # rejected counter, so it can exceed client-observed fails
            assert res["rejected_in_run"] == n_rejected, \
                (metrics["sessions"], n_rejected)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--streams", type=int, default=100,
                    help="concurrent client streams to replay")
    ap.add_argument("--slots", type=int, default=8,
                    help="ASR engine slot-pool size")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="EngineConfig.max_queue backpressure bound "
                         "(default: unbounded — no rejections)")
    ap.add_argument("--stagger-ms", type=float, default=20.0,
                    help="arrival stagger between consecutive streams")
    ap.add_argument("--chunk-ms", type=float, default=80.0,
                    help="audio chunk size per push")
    ap.add_argument("--warmup", type=int, default=None,
                    help="warmup streams run (and discarded) before the "
                         "measured wave, to trace the jit step buckets "
                         "(default: one per slot)")
    ap.add_argument("--retries", type=int, default=0,
                    help="client-side retry attempts on 503/connection "
                         "failure (jittered exponential backoff; "
                         "default: fail fast, counted as rejection)")
    ap.add_argument("--backoff", type=float, default=0.05,
                    help="base backoff delay in seconds for --retries")
    ap.add_argument("--realtime", action="store_true",
                    help="pace each stream at realtime (sleep one chunk "
                         "duration per push) instead of replaying as "
                         "fast as the server accepts")
    ap.add_argument("--group", default="load",
                    help="row-name prefix in the JSON output")
    ap.add_argument("--json", type=pathlib.Path, default=None,
                    help="merge rows into this JSON mapping")
    args = ap.parse_args(argv)

    res = asyncio.run(_run_load(args))
    report(args, res)
    if args.json:
        merged = {}
        if args.json.exists():
            merged = json.loads(args.json.read_text())
        merged.update(ROWS)
        args.json.write_text(json.dumps(merged, indent=1, sort_keys=True)
                             + "\n")
        print(f"wrote {len(ROWS)} rows to {args.json}")
    return ROWS


if __name__ == "__main__":
    main()
