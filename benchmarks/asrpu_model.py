"""ASRPU analytical performance model (paper §5.1 methodology).

The paper estimates execution time by instruction counting: "a loop will
usually consist of two instructions for the comparison and conditional
jump, one instruction for the variable update and the instructions for
the loop body, all multiplied by the average number of iterations ...
every PE executes one instruction per cycle" — divided by 8 PEs @ 500 MHz.

We reproduce that model over our kernel plan (core/scheduler.StepPlan):

  MAC loop body (conv/fc, 8-wide vector MAC): 1 vMAC + 2 vector loads +
    3 loop bookkeeping = 6 instr / 8 inputs; +12 instr thread prologue /
    activation / store.
  LayerNorm thread: two reduction passes + normalize = 3 passes x n/8
    vector ops x 2 instr + 16.
  MFCC thread: macs_per_thread from the plan (FFT counted 5 n log n).
  Hypothesis expansion thread: per candidate ~24 instr (gather node,
    score add, hash, emit) x (2C+2) candidates + LM lookup 12.

These constants are stated here once and used for every kernel — the
claim check (paper: 40 ms per 80 ms step => 2x real-time) is then a
genuine output of the model, not a fit.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List

from repro.configs.tds_asr import ASRPU_HW, DECODER_CONFIG, TDS_CONFIG
from repro.core.scheduler import PlannedKernel, StepPlan, make_step_plan

INSTR_PER_VMAC_ITER = 6
THREAD_PROLOGUE = 12
LN_INSTR_PER_VEC = 2
LN_PROLOGUE = 16
HYP_INSTR_PER_CAND = 24
HYP_LM_LOOKUP = 12


@dataclass
class KernelTime:
    name: str
    kind: str
    n_threads: int
    instr: float
    time_ms: float
    weight_kb: float
    n_subkernels: int


def kernel_time(k: PlannedKernel, hw=ASRPU_HW) -> KernelTime:
    v = hw.mac_vector
    if k.kind in ("conv", "fc", "feature"):
        per_thread = (k.macs_per_thread / v) * INSTR_PER_VMAC_ITER \
            + THREAD_PROLOGUE
    elif k.kind == "layernorm":
        per_thread = 3 * (k.macs_per_thread / 2 / v) * LN_INSTR_PER_VEC \
            + LN_PROLOGUE
    else:
        per_thread = k.macs_per_thread
    instr = k.n_threads * per_thread
    t = instr / (hw.n_pes * hw.freq_hz)
    return KernelTime(k.name, k.kind, k.n_threads, instr, t * 1e3,
                      k.weight_bytes / 1024.0, k.n_subkernels)


def hyp_expansion_time(n_hyps: int, max_children: int,
                       n_frames: int, hw=ASRPU_HW) -> KernelTime:
    cands = 2 * max_children + 2
    per_thread = cands * HYP_INSTR_PER_CAND + HYP_LM_LOOKUP
    instr = n_frames * n_hyps * per_thread
    t = instr / (hw.n_pes * hw.freq_hz)
    return KernelTime("hyp_expansion", "hyp", n_frames * n_hyps, instr,
                      t * 1e3, 0.0, 1)


def step_breakdown(plan: StepPlan = None, n_hyps: int = None,
                   hw=ASRPU_HW) -> List[KernelTime]:
    if plan is None:
        plan = make_step_plan(TDS_CONFIG)
    if n_hyps is None:
        n_hyps = DECODER_CONFIG.beam_size
    out = [kernel_time(k, hw) for k in plan.kernels]
    out.append(hyp_expansion_time(n_hyps, DECODER_CONFIG.max_children,
                                  plan.acoustic_frames_per_step, hw))
    return out


def step_time_ms(hw=ASRPU_HW) -> float:
    return sum(k.time_ms for k in step_breakdown(hw=hw))


def realtime_factor(hw=ASRPU_HW) -> float:
    """<1 means faster than real time; paper reports 0.5 (2x real-time)."""
    return step_time_ms(hw) / hw.step_audio_ms
