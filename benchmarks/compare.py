"""Diff a fresh benchmark JSON against a committed baseline.

Non-gating perf-regression annotator for the CI bench-smoke and
load-smoke jobs:

  python -m benchmarks.compare BENCH_decode.json bench_fresh.json \\
      --threshold 1.3
  python -m benchmarks.compare --load BENCH_load.json load_fresh.json

prints one line per row present in BOTH files and emits a GitHub
`::warning::` annotation for every row whose fresh time exceeds
threshold x baseline.  `*_pre_refactor` trajectory keys are skipped;
baseline rows ABSENT from the fresh run also get a `::warning::` — a
renamed or dropped bench row would otherwise silently exit regression
coverage.  (Fresh-only rows are fine: they are new benches the baseline
will pick up when re-committed.)

``--load BASE FRESH`` compares a benchmarks/load.py latency report
instead: only ``*_ms`` rows are diffed and only ``*_p95_*`` rows can
annotate (p50 is too schedule-sensitive and p99 too tail-noisy on
shared runners to gate on; they still print for the trajectory).

Always exits 0 — bench hosts are noisy shared runners, so regressions
annotate the run instead of failing it.
"""
from __future__ import annotations

import argparse
import json
import pathlib


def compare(base: dict, fresh: dict, threshold: float) -> list:
    regressed = []
    for name in sorted(set(base) & set(fresh)):
        if name.endswith("_pre_refactor"):
            continue
        b, f = float(base[name]), float(fresh[name])
        if b <= 0.0:            # derived-only rows carry 0 us
            continue
        ratio = f / b
        flag = " REGRESSED" if ratio > threshold else ""
        print(f"{name}: {b:.2f} -> {f:.2f} us ({ratio:.2f}x){flag}")
        if flag:
            regressed.append((name, b, f, ratio))
    return regressed


def compare_load(base: dict, fresh: dict, threshold: float) -> list:
    """Latency-row diff for benchmarks/load.py reports: `*_ms` rows
    only, with `*_p95_*` rows carrying the regression annotations."""
    regressed = []
    for name in sorted(set(base) & set(fresh)):
        if not name.endswith("_ms"):
            continue
        b, f = float(base[name]), float(fresh[name])
        if b <= 0.0:
            continue
        ratio = f / b
        flag = " REGRESSED" if "_p95_" in name and ratio > threshold else ""
        print(f"{name}: {b:.2f} -> {f:.2f} ms ({ratio:.2f}x){flag}")
        if flag:
            regressed.append((name, b, f, ratio))
    return regressed


def missing_rows(base: dict, fresh: dict) -> list:
    """Baseline rows the fresh run no longer measures (renamed/dropped
    benches silently leave regression coverage without this check)."""
    return [name for name in sorted(set(base) - set(fresh))
            if not name.endswith("_pre_refactor")]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("base", nargs="?",
                    help="committed baseline JSON (BENCH_decode.json)")
    ap.add_argument("fresh", nargs="?", help="freshly measured JSON")
    ap.add_argument("--threshold", type=float, default=1.3,
                    help="annotate rows slower than threshold x baseline")
    ap.add_argument("--load", nargs=2, metavar=("BASE", "FRESH"),
                    default=None,
                    help="compare benchmarks/load.py latency reports "
                         "instead (only *_ms rows; only *_p95_* rows "
                         "annotate)")
    args = ap.parse_args(argv)
    if args.load is not None:
        base_path, fresh_path = args.load
        unit, diff = "ms", compare_load
    elif args.base and args.fresh:
        base_path, fresh_path = args.base, args.fresh
        unit, diff = "us", compare
    else:
        ap.error("need BASE FRESH positionals or --load BASE FRESH")

    base = json.loads(pathlib.Path(base_path).read_text())
    fresh = json.loads(pathlib.Path(fresh_path).read_text())
    regressed = diff(base, fresh, args.threshold)
    # load-mode fresh runs are usually a smoke subset of the committed
    # groups (16 streams in CI vs the 100-stream committed report), so
    # the absent-row check only applies to the decode comparison
    if args.load is None:
        for name in missing_rows(base, fresh):
            print(f"::warning file={base_path}::baseline row {name} is "
                  f"missing from the fresh run — renamed or dropped rows "
                  f"silently leave perf-regression coverage; re-measure "
                  f"it or update {base_path}")
    if regressed:
        for name, b, f, ratio in regressed:
            print(f"::warning file={base_path}::{name} regressed "
                  f"{ratio:.2f}x ({b:.0f} -> {f:.0f} {unit}, "
                  f"threshold {args.threshold}x)")
        print(f"{len(regressed)} row(s) regressed (non-gating)")
    else:
        print("no rows regressed beyond "
              f"{args.threshold}x ({len(set(base) & set(fresh))} compared)")


if __name__ == "__main__":
    main()
