"""Diff a fresh benchmark JSON against a committed baseline.

Non-gating perf-regression annotator for the CI bench-smoke job:

  python -m benchmarks.compare BENCH_decode.json bench_fresh.json \\
      --threshold 1.3

prints one line per row present in BOTH files and emits a GitHub
`::warning::` annotation for every row whose fresh time exceeds
threshold x baseline.  `*_pre_refactor` trajectory keys are skipped;
baseline rows ABSENT from the fresh run also get a `::warning::` — a
renamed or dropped bench row would otherwise silently exit regression
coverage.  (Fresh-only rows are fine: they are new benches the baseline
will pick up when re-committed.)  Always exits 0 — bench hosts are
noisy shared runners, so regressions annotate the run instead of
failing it.
"""
from __future__ import annotations

import argparse
import json
import pathlib


def compare(base: dict, fresh: dict, threshold: float) -> list:
    regressed = []
    for name in sorted(set(base) & set(fresh)):
        if name.endswith("_pre_refactor"):
            continue
        b, f = float(base[name]), float(fresh[name])
        if b <= 0.0:            # derived-only rows carry 0 us
            continue
        ratio = f / b
        flag = " REGRESSED" if ratio > threshold else ""
        print(f"{name}: {b:.2f} -> {f:.2f} us ({ratio:.2f}x){flag}")
        if flag:
            regressed.append((name, b, f, ratio))
    return regressed


def missing_rows(base: dict, fresh: dict) -> list:
    """Baseline rows the fresh run no longer measures (renamed/dropped
    benches silently leave regression coverage without this check)."""
    return [name for name in sorted(set(base) - set(fresh))
            if not name.endswith("_pre_refactor")]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("base", help="committed baseline JSON (BENCH_decode.json)")
    ap.add_argument("fresh", help="freshly measured JSON")
    ap.add_argument("--threshold", type=float, default=1.3,
                    help="annotate rows slower than threshold x baseline")
    args = ap.parse_args(argv)

    base = json.loads(pathlib.Path(args.base).read_text())
    fresh = json.loads(pathlib.Path(args.fresh).read_text())
    regressed = compare(base, fresh, args.threshold)
    for name in missing_rows(base, fresh):
        print(f"::warning file={args.base}::baseline row {name} is "
              f"missing from the fresh run — renamed or dropped rows "
              f"silently leave perf-regression coverage; re-measure it "
              f"or update {args.base}")
    if regressed:
        for name, b, f, ratio in regressed:
            print(f"::warning file={args.base}::{name} regressed "
                  f"{ratio:.2f}x ({b:.0f} -> {f:.0f} us, "
                  f"threshold {args.threshold}x)")
        print(f"{len(regressed)} row(s) regressed (non-gating)")
    else:
        print("no rows regressed beyond "
              f"{args.threshold}x ({len(set(base) & set(fresh))} compared)")


if __name__ == "__main__":
    main()
