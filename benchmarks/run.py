"""Benchmark harness — one function per paper table/figure + system benches.

Prints ``name,us_per_call,derived`` CSV rows (scaffold contract).
``--rows`` selects row groups (``paper``, ``decode``, ``sharded``,
``kernels``, ``dryrun``, or ``all``); ``--json PATH`` additionally writes the
name -> µs mapping as JSON (the CI bench-smoke job uploads
``BENCH_decode.json`` built from the kernel + decode groups; the copy
at the repo root records the perf trajectory, including the
pre-refactor sequential-vs-batched decode rows under ``*_pre_refactor``
keys).

  fig9_layer_sizes    — paper Fig. 9: TDS layer weight sizes (KB)
  fig11_kernel_times  — paper Fig. 11: per-kernel exec time via the
                        instruction-count model (§5.1)
  sec54_realtime      — paper §5.4 headline: decoding-step time vs the
                        80 ms audio window (paper: ~40 ms => 2x real-time)
  rtf_measured        — measured JAX wall-clock RTF of the streaming
                        decoder on this CPU (not the ASRPU estimate)
  beam_throughput     — hypothesis-expansion executions/sec (measured)
  multistream         — sequential vs batched (slot-pool) ASR serving
                        throughput over the same utterances
  sharded (group)     — the model-parallel (--mesh) serving step over 2
                        host devices: acoustic step + batched serve
                        (skipped rows on a 1-device host)
  sharded2d (group)   — the 2D ('data','model') mesh serving step over
                        4 host devices (--mesh 2x2): slot pool sharded
                        on 'data', weights on 'model' (skipped rows
                        below 4 devices)
  kernel_<name>       — Pallas kernels, interpret-mode wall time +
                        analytic v5e roofline time (derived column)
  dryrun_summary      — roofline terms per dry-run artifact (if present)
"""
from __future__ import annotations

import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import asrpu_model
from repro.configs.tds_asr import (FEATURE_CONFIG, TDS_CONFIG, DecoderConfig)
from repro.core import decoder, features, lexicon as lx
from repro.kernels import ops
from repro.models import tds

ROWS = []


def row(name: str, us_per_call: float, derived: str = ""):
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.2f},{derived}", flush=True)


def _timeit(fn, *args, n=5, warmup=2):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(n):
        out = jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / n * 1e6, out


# ---------------------------------------------------------------------------
def fig9_layer_sizes():
    """Layer weight KB; paper: convs in a few KB, FCs in the MB range,
    example 1200x1200 FC = 1.4MB split into 2 kernels of 600 neurons."""
    specs = tds.build_kernel_specs(TDS_CONFIG)
    conv_kb = [s.weight_bytes / 1024 for s in specs if s.kind == "conv"]
    fc_kb = [s.weight_bytes / 1024 for s in specs if s.kind in ("fc", "head")]
    row("fig9_conv_max_kb", 0.0, f"{max(conv_kb):.1f}")
    row("fig9_fc_max_kb", 0.0, f"{max(fc_kb):.1f}")
    fc1200 = [s for s in specs if s.n_in == 1200 and s.kind == "fc"][0]
    row("fig9_fc1200_mb", 0.0,
        f"{fc1200.weight_bytes/2**20:.2f}MB_in_{fc1200.n_subkernels}_kernels")
    assert max(conv_kb) < 64 and max(fc_kb) > 1024  # paper's shape of Fig 9


def fig11_kernel_times():
    """Per-kernel execution time estimates (instruction-count model)."""
    times = asrpu_model.step_breakdown()
    by_kind = {}
    for k in times:
        by_kind.setdefault(k.kind, 0.0)
        by_kind[k.kind] += k.time_ms
    for kind, ms in sorted(by_kind.items()):
        row(f"fig11_{kind}_ms", ms * 1e3, f"{ms:.2f}ms_per_step")
    worst = max(times, key=lambda k: k.time_ms)
    row("fig11_slowest_kernel", worst.time_ms * 1e3, worst.name)


def sec54_realtime():
    est = asrpu_model.step_time_ms()
    rtf = asrpu_model.realtime_factor()
    row("sec54_step_ms_est", est * 1e3,
        f"paper=40ms;model={est:.1f}ms_per_80ms")
    row("sec54_rtf_est", 0.0,
        f"{rtf:.2f}x_realtime(paper=0.50;<1_is_realtime)")


# ---------------------------------------------------------------------------
def rtf_measured():
    """Actual CPU wall-clock of the fused decoding step (full TDS),
    streamed through one serving-engine session in 80 ms pushes."""
    from repro.serving import AsrEngine, AsrProgram, EngineConfig

    words = {f"w{i}": [1 + (i * 7 + j) % 30 for j in range(3)]
             for i in range(20)}
    lex = lx.build_lexicon(words, max_children=32)
    lm = lx.uniform_bigram(len(words))
    params = tds.init_tds(jax.random.PRNGKey(0), TDS_CONFIG)
    program = AsrProgram(TDS_CONFIG, lex, lm,
                         dec_cfg=DecoderConfig(beam_size=64))
    engine = AsrEngine(EngineConfig(program, n_slots=1), params)
    audio = np.random.RandomState(0).randn(16000 * 2).astype(np.float32)
    spp = engine.plan.samples_per_step
    session = engine.open()
    session.push(audio[:spp * 2]).poll()     # warmup/compile
    t0 = time.perf_counter()
    n = 0
    for off in range(spp * 2, len(audio) - spp, spp):
        session.push(audio[off:off + spp]).poll()
        n += 1
    dt = time.perf_counter() - t0
    per_step = dt / max(n, 1)
    row("rtf_measured_step", per_step * 1e6,
        f"cpu_rtf={per_step/0.080:.2f}")


def multistream_throughput():
    """Sequential vs batched ASR serving over the same utterance set: a
    1-slot serving engine decoding utterances back-to-back vs a B-slot
    pool advancing all of them through one vmapped decoding step."""
    from repro.data.pipeline import SyntheticASR
    from repro.launch.serve import asr_demo_engine

    single, words = asr_demo_engine(1)
    data = SyntheticASR(words)
    utts = [data.utterance(i)["audio"] for i in range(4)]
    audio_s = sum(len(a) for a in utts) / 16000

    # warmup must cover the full timed shape set (every (sub-batch,
    # window-bucket) jit entry the schedule hits + finalize + best +
    # slot reset on re-admission), not just the fused step, or one-time
    # tracing/compiles land in the timed region — serving the SAME
    # utterance set replays the exact schedule
    single.serve(utts)
    single.reset()
    t0 = time.perf_counter()
    single.serve(utts)        # 1 slot => utterances decode back-to-back
    dt_seq = time.perf_counter() - t0

    multi, _ = asr_demo_engine(len(utts))
    multi.serve(utts)                             # warmup/compile
    multi.reset()
    t0 = time.perf_counter()
    multi.serve(utts)
    dt_bat = time.perf_counter() - t0

    row("serve_asr_sequential", dt_seq * 1e6,
        f"rtf={dt_seq/audio_s:.3f};{audio_s/dt_seq:.2f}x_realtime")
    row("serve_asr_batched_b4", dt_bat * 1e6,
        f"rtf={dt_bat/audio_s:.3f};{audio_s/dt_bat:.2f}x_realtime;"
        f"speedup={dt_seq/dt_bat:.2f}x")


def sharded_rows():
    """Model-parallel serving on host devices (--mesh): TDS FC/head
    weights split over a 2-wide ('model',) mesh, the fused step under
    shard_map.  Needs >= 2 jax devices — the CI bench-smoke job runs
    this group in a second process with
    XLA_FLAGS=--xla_force_host_platform_device_count=2 (the flag must
    precede jax init); on a 1-device host the rows are emitted as
    skipped.  NOTE on CPU hosts the 'devices' share the same cores, so
    these rows track the sharded path's health/overhead trajectory —
    the weight-bandwidth win needs real accelerator devices."""
    if jax.device_count() < 2:
        # NOT recorded as rows: a 0.0 "measurement" merged into the JSON
        # would shadow the committed baseline and silently pass
        # compare.py; an absent row triggers its missing-row ::warning::
        print("# sharded rows skipped: needs >= 2 devices "
              "(XLA_FLAGS=--xla_force_host_platform_device_count=2)",
              flush=True)
        return
    from jax.sharding import PartitionSpec as P

    from repro import compat
    from repro.data.pipeline import SyntheticASR
    from repro.launch.serve import asr_demo_engine, serve_mesh
    from repro.parallel import sharding as shlib

    mesh = jax.make_mesh((2,), ("model",))
    params = tds.init_tds(jax.random.PRNGKey(0), TDS_CONFIG)
    fc = FEATURE_CONFIG
    nfr = 8
    need = fc.frame_len + (nfr - 1) * fc.frame_shift
    pspecs = shlib.tds_param_specs(TDS_CONFIG, mesh)
    placed = shlib.place_tree(params, pspecs, mesh)

    def body(p, ss, x):
        feats = features.mfcc(x, fc, use_pallas=True, hot=True)[:, :nfr]
        return tds.forward_batched(p, TDS_CONFIG, feats, ss, axis="model")

    step = jax.jit(compat.shard_map(body, mesh=mesh,
                                    in_specs=(pspecs, P(), P()),
                                    out_specs=(P(), P()), check_vma=False))
    R = np.random.RandomState(0)
    ss = tds.init_batched_stream_state(TDS_CONFIG, 4)
    x = jnp.asarray(R.randn(4, need).astype(np.float32))
    us, _ = _timeit(step, placed, ss, x, n=5, warmup=2)
    row("acoustic_step_sharded", us,
        f"d2_model_parallel_b4;{us/4:.0f}us_per_slot")

    engine, words = asr_demo_engine(4, mesh=serve_mesh(2))
    data = SyntheticASR(words)
    utts = [data.utterance(i)["audio"] for i in range(4)]
    audio_s = sum(len(a) for a in utts) / 16000
    engine.serve(utts)        # warmup replays the exact timed schedule
    engine.reset()
    t0 = time.perf_counter()
    engine.serve(utts)
    dt = time.perf_counter() - t0
    row("serve_asr_sharded_d2", dt * 1e6,
        f"rtf={dt/audio_s:.3f};{audio_s/dt:.2f}x_realtime;model_parallel=2")


def sharded_2d_rows():
    """2D ('data','model') mesh serving on host devices (--mesh RxC):
    the slot pool shards over a 2-wide 'data' axis (each shard holds
    b/2 slots end-to-end) while FC/head weights shard over a 2-wide
    'model' axis.  Needs >= 4 jax devices — the CI bench-smoke job runs
    this group in its own process with
    XLA_FLAGS=--xla_force_host_platform_device_count=4; on a smaller
    host the rows are emitted as skipped (see sharded_rows).  Same CPU
    caveat: forced host devices share cores, so these rows pin the 2D
    path's health/overhead — the throughput-scaling win needs real
    accelerator devices (ROADMAP item 5)."""
    if jax.device_count() < 4:
        print("# sharded2d rows skipped: needs >= 4 devices "
              "(XLA_FLAGS=--xla_force_host_platform_device_count=4)",
              flush=True)
        return
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro import compat
    from repro.data.pipeline import SyntheticASR
    from repro.launch.serve import asr_demo_engine, serve_mesh
    from repro.parallel import sharding as shlib

    mesh = jax.make_mesh((2, 2), ("data", "model"))
    params = tds.init_tds(jax.random.PRNGKey(0), TDS_CONFIG)
    fc = FEATURE_CONFIG
    nfr = 8
    need = fc.frame_len + (nfr - 1) * fc.frame_shift
    pspecs = shlib.tds_param_specs(TDS_CONFIG, mesh)
    placed = shlib.place_tree(params, pspecs, mesh)

    def body(p, ss, x):
        feats = features.mfcc(x, fc, use_pallas=True, hot=True)[:, :nfr]
        return tds.forward_batched(p, TDS_CONFIG, feats, ss, axis="model")

    ss = tds.init_batched_stream_state(TDS_CONFIG, 4)
    sspecs = shlib.asr_state_specs(ss, mesh)
    step = jax.jit(compat.shard_map(
        body, mesh=mesh, in_specs=(pspecs, sspecs, P("data", None)),
        out_specs=(P("data", None, None), sspecs), check_vma=False))
    R = np.random.RandomState(0)
    ss = shlib.place_tree(ss, sspecs, mesh)
    x = jax.device_put(R.randn(4, need).astype(np.float32),
                       NamedSharding(mesh, P("data", None)))
    us, _ = _timeit(step, placed, ss, x, n=5, warmup=2)
    row("acoustic_step_2d", us,
        f"2x2_data_x_model_b4;{us/4:.0f}us_per_slot")

    engine, words = asr_demo_engine(4, mesh=serve_mesh("2x2"))
    data = SyntheticASR(words)
    utts = [data.utterance(i)["audio"] for i in range(4)]
    audio_s = sum(len(a) for a in utts) / 16000
    engine.serve(utts)        # warmup replays the exact timed schedule
    engine.reset()
    t0 = time.perf_counter()
    engine.serve(utts)
    dt = time.perf_counter() - t0
    row("serve_asr_2d_d4", dt * 1e6,
        f"rtf={dt/audio_s:.3f};{audio_s/dt:.2f}x_realtime;mesh=2x2")


def acoustic_steps():
    """The acoustic half of the decoding step — fused-logmel MFCC tail +
    the slot-native TDS forward — jitted, at B=1 and B=4 slots (the
    (B, T) rows fold into one matmul row dimension per kernel)."""
    params = tds.init_tds(jax.random.PRNGKey(0), TDS_CONFIG)
    fc = FEATURE_CONFIG
    nfr = 8
    need = fc.frame_len + (nfr - 1) * fc.frame_shift

    @jax.jit
    def step(p, ss, x):
        feats = features.mfcc(x, fc, use_pallas=True, hot=True)[:, :nfr]
        return tds.forward_batched(p, TDS_CONFIG, feats, ss)

    R = np.random.RandomState(0)
    for b in (1, 4):
        ss = tds.init_batched_stream_state(TDS_CONFIG, b)
        x = jnp.asarray(R.randn(b, need).astype(np.float32))
        us, _ = _timeit(step, params, ss, x, n=5, warmup=2)
        row(f"acoustic_step_b{b}", us,
            f"fused_mfcc+tds_forward;{us/b:.0f}us_per_slot")


def beam_throughput():
    words = {f"w{i}": [1 + (i * 7 + j) % 30 for j in range(3)]
             for i in range(20)}
    lex = lx.build_lexicon(words, max_children=32)
    lm = lx.uniform_bigram(len(words))
    cfg = DecoderConfig(beam_size=128)
    logp = jax.nn.log_softmax(
        jnp.asarray(np.random.RandomState(0).randn(32).astype(np.float32)))
    st = decoder.init_state(cfg.beam_size, lm)
    step = jax.jit(lambda s, lp: decoder.expand_step(s, lp, lex, lm, cfg))
    us, _ = _timeit(step, st, logp, n=20)
    row("beam_expand_step", us, f"{1e6/us:.0f}_expansions_per_s")


# ---------------------------------------------------------------------------
V5E_FLOPS = 197e12
V5E_HBM = 819e9


def kernel_benches():
    R = np.random.RandomState(0)
    # int8 matmul — ASRPU's hot loop: the 1200x1200 FC layer (fig 9)
    x = jnp.asarray(R.randn(8, 1200).astype(np.float32))
    w = jnp.asarray(R.randn(1200, 1200).astype(np.float32))
    us, _ = _timeit(ops.int8_matmul, x, w, n=3, warmup=1)
    flops = 2 * 8 * 1200 * 1200
    v5e_us = max(flops / (V5E_FLOPS * 2),          # int8 ~2x bf16 peak
                 (1200 * 1200 + 8 * 1200 * 2) / V5E_HBM) * 1e6
    row("kernel_int8_matmul_fc1200", us, f"v5e_est={v5e_us:.2f}us")

    q = jnp.asarray(R.randn(1, 8, 256, 64).astype(np.float32))
    us, _ = _timeit(lambda: ops.flash_attention(q, q, q, block_q=64,
                                                block_kv=64), n=3, warmup=1)
    flops = 2 * 2 * 8 * 256 * 256 * 64 * 0.5
    row("kernel_flash_attention_256", us,
        f"v5e_est={flops/V5E_FLOPS*1e6:.2f}us")

    xx = jnp.asarray(R.randn(512, 1840).astype(np.float32))
    s = jnp.ones((1840,), jnp.float32)
    b = jnp.zeros((1840,), jnp.float32)
    us, _ = _timeit(ops.layernorm, xx, s, b, n=3, warmup=1)
    bytes_ = 2 * 512 * 1840 * 4
    row("kernel_layernorm_512x1840", us,
        f"v5e_est={bytes_/V5E_HBM*1e6:.2f}us")

    p = jnp.abs(jnp.asarray(R.randn(256, 257).astype(np.float32)))
    fb = jnp.asarray(features.mel_filterbank(FEATURE_CONFIG))
    dct = jnp.asarray(features.dct_matrix(80, 80))
    us, _ = _timeit(ops.logmel, p, fb, dct, n=3, warmup=1)
    row("kernel_logmel_256", us, "fused_mel+log+dct")

    sc = jnp.asarray(R.randn(8448).astype(np.float32))
    us, _ = _timeit(lambda: ops.beam_prune(sc, 25.0), n=3, warmup=1)
    row("kernel_beam_prune_8448", us, "hypothesis_unit_threshold")

    # fused hypothesis unit: merge + threshold + top-k in one op over a
    # beam-128 / 32-children candidate set (N = 128 * 65), batch of 4
    # slots — the decode hot path's shape
    hh = jnp.asarray(R.randint(0, 4096, (4, 8320)).astype(np.int32))
    hp = jnp.asarray((R.randn(4, 8320) * 3).astype(np.float32))
    hq = jnp.asarray((R.randn(4, 8320) * 3).astype(np.float32))
    ref_policy = ops.KernelPolicy("ref")
    us, _ = _timeit(lambda: ops.hypothesis_unit(hh, hp, hq, 128, 25.0,
                                                policy=ref_policy),
                    n=3, warmup=1)
    row("kernel_hypothesis_unit_b4_n8320", us, "fused_merge+threshold+topk")

    xc = jnp.asarray(R.randn(8 + 64, 80, 15).astype(np.float32))
    wc = jnp.asarray(R.randn(9, 15, 15).astype(np.float32) * 0.1)
    bc = jnp.zeros((15,), jnp.float32)
    us, _ = _timeit(lambda: ops.tds_conv(xc, wc, bc), n=3, warmup=1)
    row("kernel_tds_conv_64", us, "stage1_conv")

    # the full 79-kernel TDS sequence, one 80 ms window (the acoustic
    # model inside every decoding step)
    tparams = tds.init_tds(jax.random.PRNGKey(0), TDS_CONFIG)
    feats8 = jnp.asarray(R.randn(8, 80).astype(np.float32))
    fwd = jax.jit(lambda p, f: tds.forward(p, TDS_CONFIG, f)[0])
    us, _ = _timeit(fwd, tparams, feats8, n=3, warmup=1)
    row("kernel_tds_forward", us, "79_kernel_sequence_T8")


def dryrun_summary():
    art = pathlib.Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"
    if not art.exists():
        row("dryrun_summary", 0.0, "no_artifacts")
        return
    n_ok = n_skip = n_fail = 0
    worst = (0.0, "")
    for f in sorted(art.glob("*.json")):
        rec = json.loads(f.read_text())
        if rec["status"] == "ok":
            n_ok += 1
            r = rec.get("roofline", {})
            t = max(r.get("t_compute", 0), r.get("t_memory", 0),
                    r.get("t_collective", 0))
            if t > worst[0]:
                worst = (t, f.stem)
        elif rec["status"] == "skipped":
            n_skip += 1
        else:
            n_fail += 1
    row("dryrun_cells", 0.0, f"ok={n_ok};skipped={n_skip};fail={n_fail}")
    row("dryrun_worst_cell", worst[0] * 1e6, worst[1])


GROUPS = {
    "paper": (fig9_layer_sizes, fig11_kernel_times, sec54_realtime),
    "decode": (beam_throughput, acoustic_steps, multistream_throughput,
               rtf_measured),
    "sharded": (sharded_rows,),
    "sharded2d": (sharded_2d_rows,),
    "kernels": (kernel_benches,),
    "dryrun": (dryrun_summary,),
}
GROUP_ORDER = ("paper", "decode", "sharded", "sharded2d", "kernels",
               "dryrun")


def main(argv=None) -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--rows", default="all",
                    help="comma-separated row groups to run: "
                         f"{', '.join(GROUP_ORDER)} or all")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the name -> us_per_call mapping as "
                         "JSON (e.g. BENCH_decode.json at the repo root)")
    args = ap.parse_args(argv)

    wanted = [g.strip() for g in args.rows.split(",") if g.strip()]
    if "all" in wanted:
        wanted = list(GROUP_ORDER)
    unknown = set(wanted) - set(GROUPS)
    if unknown:
        ap.error(f"unknown row group(s): {sorted(unknown)}")

    print("name,us_per_call,derived")
    for group in GROUP_ORDER:
        if group in wanted:
            for fn in GROUPS[group]:
                fn()

    if args.json:
        path = pathlib.Path(args.json)
        # merge-update: rows not re-measured this run (other groups,
        # recorded *_pre_refactor trajectory keys) are preserved
        payload = {}
        if path.exists():
            payload = json.loads(path.read_text())
        payload.update({name: round(us, 2) for name, us, _ in ROWS})
        path.write_text(json.dumps(payload, indent=2) + "\n")
        print(f"wrote {len(ROWS)} rows to {path} "
              f"({len(payload)} total)", flush=True)


if __name__ == "__main__":
    main()
