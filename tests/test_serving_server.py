"""Network serving front-end (repro.serving.server): wire-protocol
parity against in-process decoding, concurrent streaming sessions over
one engine-worker thread, typed 503 backpressure with a bounded queue,
the /metrics endpoint, and one-shot LM generation over the wire."""
import asyncio

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.pipeline import SyntheticASR
from repro.models import LM
from repro.serving import (AsrEngine, AsrProgram, EngineConfig, LmEngine,
                           LmProgram)
from repro.serving.server import (AsrClient, EngineServer, ServerRejected,
                                  fetch_metrics, lm_generate)
from test_serving import FEAT16, TINY_TDS, _asr_system, _same


def _asr_engine(n_slots, max_queue=None):
    words, lex, lm, dcfg, params = _asr_system()
    program = AsrProgram(TINY_TDS, lex, lm, FEAT16, dcfg)
    engine = AsrEngine(EngineConfig(program, n_slots=n_slots,
                                    max_queue=max_queue), params)
    return engine, words


def _as_result(payload: dict) -> dict:
    """Wire payload (JSON lists) -> the in-process result shape."""
    return {"words": np.asarray(payload["words"], np.int32),
            "tokens": np.asarray(payload["tokens"], np.int32),
            "score": float(payload["score"]),
            "steps": payload["steps"]}


async def _with_server(server: EngineServer, coro_fn):
    await server.start()
    try:
        return await coro_fn(server)
    finally:
        await server.aclose()


def test_server_asr_stream_matches_inprocess_and_metrics():
    """One streaming session over the wire — chunked pushes, live
    polls, finish — returns exactly the in-process decode, and the
    /metrics endpoint reports the session's lifecycle."""
    engine, words = _asr_engine(1)
    audio = SyntheticASR(words).utterance(3)["audio"]

    async def go(server):
        client = await AsrClient.open(server.host, server.port)
        saw_live_poll = False
        for off in range(0, len(audio), 4000):
            assert (await client.push(audio[off:off + 4000]))["ok"]
            live = await client.poll()
            assert {"words", "tokens", "score", "steps"} <= set(live)
            saw_live_poll |= live["steps"] > 0
        final = await client.finish()
        metrics = await fetch_metrics(server.host, server.port)
        return final, saw_live_poll, metrics

    final, saw_live_poll, metrics = asyncio.run(
        _with_server(EngineServer(asr_engine=engine), go))
    assert saw_live_poll           # the worker stepped between pushes

    ref_engine, _ = _asr_engine(1)
    ref = ref_engine.open().push(audio).finish()
    _same(_as_result(final), ref)
    assert final["steps"] == ref["steps"]

    m = metrics["asr"]
    assert m["sessions"] == {"opened": 1, "admitted": 1, "rejected": 0,
                             "finalized": 1}
    assert m["latency"]["first_result"]["count"] == 1
    assert m["latency"]["finalize"]["count"] == 1
    assert m["steps"]["occupancy"] > 0


def test_server_concurrent_streams_all_match_dedicated_decode():
    """Five concurrent staggered client streams over a 2-slot engine:
    every transcript equals its dedicated in-process decode (the
    worker's pump loop batches whoever holds a slot)."""
    n_utts = 5
    engine, words = _asr_engine(2)
    data = SyntheticASR(words)
    utts = [data.utterance(i)["audio"] for i in range(n_utts)]

    async def one_stream(server, audio, stagger):
        await asyncio.sleep(stagger)
        client = await AsrClient.open(server.host, server.port)
        for off in range(0, len(audio), 3000):
            await client.push(audio[off:off + 3000])
            await asyncio.sleep(0)
        return await client.finish()

    async def go(server):
        return await asyncio.gather(*[
            one_stream(server, audio, 0.01 * i)
            for i, audio in enumerate(utts)])

    finals = asyncio.run(_with_server(EngineServer(asr_engine=engine), go))

    single, _ = _asr_engine(1)
    for audio, final in zip(utts, finals):
        ref = single.open().push(audio).finish()
        _same(_as_result(final), ref)


def test_server_overload_rejects_503_and_bounds_queue():
    """Overload policy over the wire: with the slot busy and the queue
    at max_queue, a new connection gets a 503 (raised client-side as
    `ServerRejected` carrying depth and bound), the engine queue depth
    never exceeds the bound, and rejected sessions are counted.  Once
    streams drain, admission opens again."""
    engine, words = _asr_engine(1, max_queue=1)
    audio = SyntheticASR(words).utterance(0)["audio"]

    async def go(server):
        active = await AsrClient.open(server.host, server.port)
        queued = await AsrClient.open(server.host, server.port)
        with pytest.raises(ServerRejected) as exc:
            await AsrClient.open(server.host, server.port)
        assert exc.value.queue_depth == 1 and exc.value.max_queue == 1

        await active.push(audio)
        await queued.push(audio)
        r_active = await active.finish()     # frees the slot -> admits
        r_queued = await queued.finish()

        late = await AsrClient.open(server.host, server.port)
        await late.push(audio)
        r_late = await late.finish()
        metrics = await fetch_metrics(server.host, server.port)
        return [r_active, r_queued, r_late], metrics

    finals, metrics = asyncio.run(
        _with_server(EngineServer(asr_engine=engine), go))

    m = metrics["asr"]
    assert m["sessions"]["rejected"] == 1
    assert m["sessions"]["opened"] == m["sessions"]["finalized"] == 3
    assert m["queue"]["max_depth"] <= 1      # bounded under overload
    single, _ = _asr_engine(1)
    ref = single.open().push(audio).finish()
    for final in finals:
        _same(_as_result(final), ref)


def test_server_lm_generate_matches_inprocess():
    cfg = get_config("mamba2-1.3b").tiny()
    params = LM(cfg).init(jax.random.PRNGKey(0))
    program = LmProgram(cfg, cache_len=16, max_new=4)
    engine = LmEngine(EngineConfig(program, n_slots=2), params)
    prompts = [np.arange(1, 6, dtype=np.int32),
               np.arange(2, 9, dtype=np.int32)]

    async def go(server):
        return await asyncio.gather(*[
            lm_generate(server.host, server.port, p) for p in prompts])

    outs = asyncio.run(_with_server(EngineServer(lm_engine=engine), go))

    ref_engine = LmEngine(EngineConfig(program, n_slots=1), params)
    for prompt, out in zip(prompts, outs):
        assert out["done"]
        assert out["tokens"] == ref_engine.serve([prompt])[0]


def test_server_unknown_route_and_missing_engine():
    """Bad routes 404; an LM request against an ASR-only server 404s
    (typed errors cross the wire, they don't hang the connection)."""
    engine, _ = _asr_engine(1)

    async def go(server):
        with pytest.raises(RuntimeError, match="404"):
            await lm_generate(server.host, server.port, [1, 2, 3])
        return True

    assert asyncio.run(_with_server(EngineServer(asr_engine=engine), go))
