"""Network serving front-end (repro.serving.server): wire-protocol
parity against in-process decoding, concurrent streaming sessions over
one engine-worker thread, typed 503 backpressure with a bounded queue,
the /metrics endpoint, one-shot LM generation over the wire, and the
malformed-input / abrupt-disconnect containment paths."""
import asyncio
import json

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.pipeline import SyntheticASR
from repro.models import LM
from repro.serving import (AsrEngine, AsrProgram, EngineConfig, LmEngine,
                           LmProgram)
from repro.serving.server import (AsrClient, EngineServer, ServerRejected,
                                  fetch_metrics, lm_generate)
from test_serving import FEAT16, TINY_TDS, _asr_system, _same


def _asr_engine(n_slots, max_queue=None):
    words, lex, lm, dcfg, params = _asr_system()
    program = AsrProgram(TINY_TDS, lex, lm, FEAT16, dcfg)
    engine = AsrEngine(EngineConfig(program, n_slots=n_slots,
                                    max_queue=max_queue), params)
    return engine, words


def _as_result(payload: dict) -> dict:
    """Wire payload (JSON lists) -> the in-process result shape."""
    return {"words": np.asarray(payload["words"], np.int32),
            "tokens": np.asarray(payload["tokens"], np.int32),
            "score": float(payload["score"]),
            "steps": payload["steps"]}


async def _with_server(server: EngineServer, coro_fn):
    await server.start()
    try:
        return await coro_fn(server)
    finally:
        await server.aclose()


def test_server_asr_stream_matches_inprocess_and_metrics():
    """One streaming session over the wire — chunked pushes, live
    polls, finish — returns exactly the in-process decode, and the
    /metrics endpoint reports the session's lifecycle."""
    engine, words = _asr_engine(1)
    audio = SyntheticASR(words).utterance(3)["audio"]

    async def go(server):
        client = await AsrClient.open(server.host, server.port)
        saw_live_poll = False
        for off in range(0, len(audio), 4000):
            assert (await client.push(audio[off:off + 4000]))["ok"]
            live = await client.poll()
            assert {"words", "tokens", "score", "steps"} <= set(live)
            saw_live_poll |= live["steps"] > 0
        final = await client.finish()
        metrics = await fetch_metrics(server.host, server.port)
        return final, saw_live_poll, metrics

    final, saw_live_poll, metrics = asyncio.run(
        _with_server(EngineServer(asr_engine=engine), go))
    assert saw_live_poll           # the worker stepped between pushes

    ref_engine, _ = _asr_engine(1)
    ref = ref_engine.open().push(audio).finish()
    _same(_as_result(final), ref)
    assert final["steps"] == ref["steps"]

    m = metrics["asr"]
    assert m["sessions"] == {"opened": 1, "admitted": 1, "rejected": 0,
                             "finalized": 1, "faulted": 0,
                             "deadline_evicted": 0}
    assert m["workers"] == {"restarts": 0}
    assert m["latency"]["first_result"]["count"] == 1
    assert m["latency"]["finalize"]["count"] == 1
    assert m["steps"]["occupancy"] > 0


def test_server_concurrent_streams_all_match_dedicated_decode():
    """Five concurrent staggered client streams over a 2-slot engine:
    every transcript equals its dedicated in-process decode (the
    worker's pump loop batches whoever holds a slot)."""
    n_utts = 5
    engine, words = _asr_engine(2)
    data = SyntheticASR(words)
    utts = [data.utterance(i)["audio"] for i in range(n_utts)]

    async def one_stream(server, audio, stagger):
        await asyncio.sleep(stagger)
        client = await AsrClient.open(server.host, server.port)
        for off in range(0, len(audio), 3000):
            await client.push(audio[off:off + 3000])
            await asyncio.sleep(0)
        return await client.finish()

    async def go(server):
        return await asyncio.gather(*[
            one_stream(server, audio, 0.01 * i)
            for i, audio in enumerate(utts)])

    finals = asyncio.run(_with_server(EngineServer(asr_engine=engine), go))

    single, _ = _asr_engine(1)
    for audio, final in zip(utts, finals):
        ref = single.open().push(audio).finish()
        _same(_as_result(final), ref)


def test_server_overload_rejects_503_and_bounds_queue():
    """Overload policy over the wire: with the slot busy and the queue
    at max_queue, a new connection gets a 503 (raised client-side as
    `ServerRejected` carrying depth and bound), the engine queue depth
    never exceeds the bound, and rejected sessions are counted.  Once
    streams drain, admission opens again."""
    engine, words = _asr_engine(1, max_queue=1)
    audio = SyntheticASR(words).utterance(0)["audio"]

    async def go(server):
        active = await AsrClient.open(server.host, server.port)
        queued = await AsrClient.open(server.host, server.port)
        with pytest.raises(ServerRejected) as exc:
            await AsrClient.open(server.host, server.port)
        assert exc.value.queue_depth == 1 and exc.value.max_queue == 1

        await active.push(audio)
        await queued.push(audio)
        r_active = await active.finish()     # frees the slot -> admits
        r_queued = await queued.finish()

        late = await AsrClient.open(server.host, server.port)
        await late.push(audio)
        r_late = await late.finish()
        metrics = await fetch_metrics(server.host, server.port)
        return [r_active, r_queued, r_late], metrics

    finals, metrics = asyncio.run(
        _with_server(EngineServer(asr_engine=engine), go))

    m = metrics["asr"]
    assert m["sessions"]["rejected"] == 1
    assert m["sessions"]["opened"] == m["sessions"]["finalized"] == 3
    assert m["queue"]["max_depth"] <= 1      # bounded under overload
    single, _ = _asr_engine(1)
    ref = single.open().push(audio).finish()
    for final in finals:
        _same(_as_result(final), ref)


def test_server_lm_generate_matches_inprocess():
    cfg = get_config("mamba2-1.3b").tiny()
    params = LM(cfg).init(jax.random.PRNGKey(0))
    program = LmProgram(cfg, cache_len=16, max_new=4)
    engine = LmEngine(EngineConfig(program, n_slots=2), params)
    prompts = [np.arange(1, 6, dtype=np.int32),
               np.arange(2, 9, dtype=np.int32)]

    async def go(server):
        return await asyncio.gather(*[
            lm_generate(server.host, server.port, p) for p in prompts])

    outs = asyncio.run(_with_server(EngineServer(lm_engine=engine), go))

    ref_engine = LmEngine(EngineConfig(program, n_slots=1), params)
    for prompt, out in zip(prompts, outs):
        assert out["done"]
        assert out["tokens"] == ref_engine.serve([prompt])[0]


def test_server_unknown_route_and_missing_engine():
    """Bad routes 404; an LM request against an ASR-only server 404s
    (typed errors cross the wire, they don't hang the connection)."""
    engine, _ = _asr_engine(1)

    async def go(server):
        with pytest.raises(RuntimeError, match="404"):
            await lm_generate(server.host, server.port, [1, 2, 3])
        return True

    assert asyncio.run(_with_server(EngineServer(asr_engine=engine), go))


# ---------------------------------------------------------------------------
# malformed input: bad commands, garbage framing
# ---------------------------------------------------------------------------

async def _session_counts(host, port, role="asr"):
    m = (await fetch_metrics(host, port))[role]["sessions"]
    return m


async def _await_reclaimed(server, opened, timeout=10.0):
    """Poll /metrics until every opened session left the engine (slot
    and queue reclaimed: finalized or faulted)."""
    loop = asyncio.get_running_loop()
    deadline = loop.time() + timeout
    while True:
        m = await _session_counts(server.host, server.port)
        if m["finalized"] + m["faulted"] + m["deadline_evicted"] >= opened:
            return m
        assert loop.time() < deadline, m
        await asyncio.sleep(0.02)


def test_server_malformed_command_chunks_keep_session_alive():
    """Bad JSON / missing audio / non-numeric audio / NaN samples each
    get an in-stream {"error": ...} reply and the session survives: the
    same connection then streams a clean utterance to the exact
    in-process transcript."""
    from repro.serving.server import _read_chunk, _write_chunk

    engine, words = _asr_engine(1)
    audio = SyntheticASR(words).utterance(2)["audio"]

    async def bad_cmd(client, raw: bytes) -> dict:
        await _write_chunk(client._writer, raw)
        return json.loads(await _read_chunk(client._reader))

    async def go(server):
        client = await AsrClient.open(server.host, server.port)
        for raw in (b"{not json",
                    b"[1, 2, 3]",
                    b'{"op": "push"}',
                    b'{"op": "push", "audio": "zebra"}',
                    b'{"op": "push", "audio": [[0.1], [0.2]]}',
                    b'{"op": "push", "audio": [0.1, NaN, 0.2]}',
                    b'{"op": "frobnicate"}'):
            res = await bad_cmd(client, raw)
            assert "error" in res, (raw, res)
        for off in range(0, len(audio), 4000):
            assert (await client.push(audio[off:off + 4000]))["ok"]
        final = await client.finish()
        m = await _session_counts(server.host, server.port)
        return final, m

    final, m = asyncio.run(_with_server(EngineServer(asr_engine=engine), go))
    ref = _asr_engine(1)[0].open().push(audio).finish()
    _same(_as_result(final), ref)
    assert m["opened"] == m["finalized"] == 1 and m["faulted"] == 0


def test_server_garbage_chunk_framing_ends_stream_with_error():
    """Garbage bytes where a chunk-size line belongs: the server answers
    with a final in-stream error (the byte stream is unrecoverable) and
    reclaims the session instead of leaking an exception."""
    from repro.serving.server import _read_chunk

    engine, _ = _asr_engine(1)

    async def go(server):
        client = await AsrClient.open(server.host, server.port)
        client._writer.write(b"THIS IS NOT HEX\r\n")
        await client._writer.drain()
        err = json.loads(await _read_chunk(client._reader))
        assert "malformed chunk-size" in err["error"] and err["final"]
        assert await _read_chunk(client._reader) is None  # clean terminator
        await client.aclose()
        return await _await_reclaimed(server, opened=1)

    m = asyncio.run(_with_server(EngineServer(asr_engine=engine), go))
    assert m["finalized"] == 1


def test_server_bad_content_length_responds_400():
    """A garbage Content-Length on /lm is a ProtocolError the server
    turns into a 400 response, not an unretrieved task exception."""
    cfg = get_config("mamba2-1.3b").tiny()
    params = LM(cfg).init(jax.random.PRNGKey(0))
    engine = LmEngine(EngineConfig(LmProgram(cfg, cache_len=16, max_new=4),
                                   n_slots=1), params)

    async def go(server):
        reader, writer = await asyncio.open_connection(server.host,
                                                       server.port)
        writer.write((f"POST /lm HTTP/1.1\r\nHost: {server.host}\r\n"
                      "Content-Length: banana\r\n\r\n").encode())
        await writer.drain()
        head = await reader.readuntil(b"\r\n\r\n")
        writer.close()
        return head.decode("latin-1").split("\r\n")[0]

    status_line = asyncio.run(_with_server(EngineServer(lm_engine=engine),
                                           go))
    assert " 400 " in status_line


def test_parse_status_rejects_garbage():
    from repro.serving.server import ProtocolError, _parse_status

    assert _parse_status("HTTP/1.1 200 OK") == 200
    with pytest.raises(ProtocolError, match="malformed status line"):
        _parse_status("complete garbage")


# ---------------------------------------------------------------------------
# abrupt client disconnects: slot + queue reclaimed, metrics consistent
# ---------------------------------------------------------------------------

def test_server_disconnect_mid_push_reclaims_slot():
    """TCP reset in the middle of an admitted stream: the engine frees
    the slot (the session is finished server-side) and the next client
    gets it."""
    engine, words = _asr_engine(1)
    audio = SyntheticASR(words).utterance(1)["audio"]

    async def go(server):
        rude = await AsrClient.open(server.host, server.port)
        await rude.push(audio[:8000])
        rude._writer.transport.abort()         # RST, no clean last-chunk
        await _await_reclaimed(server, opened=1)

        fresh = await AsrClient.open(server.host, server.port)
        await fresh.push(audio)
        final = await fresh.finish()
        m = await _session_counts(server.host, server.port)
        return final, m

    final, m = asyncio.run(_with_server(EngineServer(asr_engine=engine), go))
    ref = _asr_engine(1)[0].open().push(audio).finish()
    _same(_as_result(final), ref)
    assert m["opened"] == m["finalized"] == 2
    assert m["faulted"] == 0


def test_server_disconnect_while_queued_reclaims_queue_entry():
    """A client that vanishes while still WAITING for a slot must not
    wedge the pool: its finished-empty session closes as soon as a slot
    frees, so the active stream and later arrivals are unaffected."""
    engine, words = _asr_engine(1)
    audio = SyntheticASR(words).utterance(0)["audio"]

    async def go(server):
        active = await AsrClient.open(server.host, server.port)
        await active.push(audio[:8000])
        queued = await AsrClient.open(server.host, server.port)
        queued._writer.transport.abort()       # dies in the queue
        await active.push(audio[8000:])
        r_active = await active.finish()
        # active's slot freed -> the dead queued session is admitted
        # empty and harvested with an empty result
        await _await_reclaimed(server, opened=2)

        late = await AsrClient.open(server.host, server.port)
        await late.push(audio)
        r_late = await late.finish()
        m = await _session_counts(server.host, server.port)
        return r_active, r_late, m

    r_active, r_late, m = asyncio.run(
        _with_server(EngineServer(asr_engine=engine), go))
    _same(_as_result(r_active), _as_result(r_late))
    assert m["opened"] == m["finalized"] == 3  # queued one closed empty
    assert m["faulted"] == 0


def test_server_disconnect_between_finish_and_final_chunk():
    """The client sends `finish` but drops before reading the result:
    the engine still finalizes the session (the result exists, the
    write just fails) and the pool stays clean for the next stream."""
    from repro.serving.server import _write_chunk

    engine, words = _asr_engine(1)
    audio = SyntheticASR(words).utterance(3)["audio"]

    async def go(server):
        rude = await AsrClient.open(server.host, server.port)
        await rude.push(audio)
        await _write_chunk(rude._writer,
                           json.dumps({"op": "finish"}).encode())
        rude._writer.transport.abort()         # never reads the result
        await _await_reclaimed(server, opened=1)

        fresh = await AsrClient.open(server.host, server.port)
        await fresh.push(audio)
        final = await fresh.finish()
        m = await _session_counts(server.host, server.port)
        return final, m

    final, m = asyncio.run(_with_server(EngineServer(asr_engine=engine), go))
    ref = _asr_engine(1)[0].open().push(audio).finish()
    _same(_as_result(final), ref)
    assert m["opened"] == m["finalized"] == 2
    assert m["faulted"] == 0
