"""CTC loss vs brute-force path enumeration + end-to-end ASR training:
train the tiny TDS with CTC on synthetic utterances, WER must drop."""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ctc


def _brute_force_ctc(logp, labels, blank=0):
    """Sum probability over all alignments that collapse to `labels`."""
    T, V = logp.shape
    total = -np.inf
    for path in itertools.product(range(V), repeat=T):
        # collapse
        out = []
        prev = -1
        for t in path:
            if t != blank and t != prev:
                out.append(t)
            prev = t
        if out == list(labels):
            lp = sum(logp[i, path[i]] for i in range(T))
            total = np.logaddexp(total, lp)
    return -total


@pytest.mark.parametrize("seed,T,labels", [
    (0, 3, [1]), (1, 4, [1, 2]), (2, 5, [2, 2]), (3, 4, [3, 1, 2]),
    (4, 5, []),
])
def test_ctc_matches_brute_force(seed, T, labels):
    r = np.random.RandomState(seed)
    logp = np.asarray(jax.nn.log_softmax(
        jnp.asarray(r.randn(T, 4).astype(np.float32))))
    lab = jnp.asarray(np.pad(np.asarray(labels, np.int32),
                             (0, 5 - len(labels)), constant_values=-1))
    got = float(ctc.ctc_loss(jnp.asarray(logp), lab))
    want = _brute_force_ctc(logp, labels)
    if np.isinf(want):   # impossible (e.g. repeated label, T too short)
        assert got > 1e10
    else:
        assert abs(got - want) < 1e-3, (got, want)


def test_ctc_grad_finite():
    r = np.random.RandomState(0)
    logp = jax.nn.log_softmax(jnp.asarray(r.randn(2, 8, 6).astype(np.float32)))
    lab = jnp.asarray([[1, 2, -1], [3, -1, -1]], jnp.int32)
    g = jax.grad(lambda lp: ctc.ctc_loss_batch(lp, lab))(logp)
    assert np.isfinite(np.asarray(g)).all()


def test_edit_distance_and_wer():
    assert ctc.edit_distance([1, 2, 3], [1, 2, 3]) == 0
    assert ctc.edit_distance([1, 2, 3], [1, 3]) == 1
    assert ctc.edit_distance([], [1, 2]) == 2
    assert ctc.wer([[1, 2], [3]], [[1, 2], [4]]) == pytest.approx(1 / 3)


def test_train_tds_ctc_end_to_end():
    """The paper's full loop: synthetic utterances -> MFCC -> TDS -> CTC
    training -> beam decode -> WER improves vs the untrained model."""
    from repro.configs.tds_asr import (DecoderConfig, FeatureConfig,
                                       TDSConfig, TDSStage)
    from repro.core import decoder, features, lexicon as lx
    from repro.data.pipeline import SyntheticASR
    from repro.models import tds
    from repro.optim import adamw

    feat_cfg = FeatureConfig(n_mels=16, n_mfcc=16)
    tds_cfg = TDSConfig(
        stages=(TDSStage(1, 3, 16, 5, 2), TDSStage(1, 3, 16, 5, 2),
                TDSStage(1, 4, 16, 5, 2)),
        sub_kernel=6, vocab_size=8)
    words = {"a": [1], "bc": [2, 3], "d": [4]}
    lex = lx.build_lexicon(words, max_children=8)
    lm = lx.uniform_bigram(len(words))
    data = SyntheticASR(words, tok_ms=200.0)

    # dataset: 6 utterances; pad AUDIO to the longest (silence -> blanks),
    # never truncate (labels must stay alignable for CTC)
    utts = [data.utterance(i, n_words=2) for i in range(6)]
    max_audio = max(len(u["audio"]) for u in utts)
    feats, labels, refs = [], [], []
    for u in utts:
        audio = np.zeros((max_audio,), np.float32)
        audio[:len(u["audio"])] = u["audio"]
        f = features.mfcc(jnp.asarray(audio), feat_cfg)
        feats.append(f)
        lab = np.full((8,), -1, np.int32)
        lab[:len(u["tokens"])] = u["tokens"]
        labels.append(lab)
        refs.append(list(u["words"]))
    T = (feats[0].shape[0] // 8) * 8
    X = jnp.stack([f[:T] for f in feats])
    Y = jnp.asarray(np.stack(labels))

    params = tds.init_tds(jax.random.PRNGKey(0), tds_cfg)

    def loss_fn(p):
        lps = jax.vmap(lambda x: tds.forward(p, tds_cfg, x)[0])(X)
        return ctc.ctc_loss_batch(lps, Y)

    ocfg = adamw.AdamWConfig(lr=3e-3, weight_decay=0.0)
    opt = adamw.init(params, ocfg)
    step = jax.jit(lambda p, o: (lambda g: adamw.update(g, o, p, ocfg))(
        jax.grad(loss_fn)(p)))

    def decode_wer(p):
        hyps = []
        dcfg = DecoderConfig(beam_size=16, beam_threshold=1e9,
                             lm_weight=0.5, word_score=0.0)
        for i in range(X.shape[0]):
            lp, _ = tds.forward(p, tds_cfg, X[i])
            st = decoder.decode(lp, lex, lm, dcfg)
            st = decoder.finalize(st, lex, lm, dcfg)
            b = decoder.best(st)
            hyps.append(list(np.asarray(b["words"])[:int(b["n_words"])]))
        return ctc.wer(refs, hyps)

    l0 = float(loss_fn(params))
    wer0 = decode_wer(params)
    for _ in range(60):
        params, opt = step(params, opt)
    l1 = float(loss_fn(params))
    wer1 = decode_wer(params)
    assert l1 < 0.5 * l0, (l0, l1)
    assert wer1 <= wer0, (wer0, wer1)
    assert wer1 < 0.5, f"trained WER {wer1} (untrained {wer0})"
