"""Test fixtures. NOTE: no XLA_FLAGS here — tests see 1 CPU device; only
launch/dryrun.py forces the 512-device host platform."""
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.RandomState(0)
