"""Test fixtures. NOTE: no XLA_FLAGS here — tests see 1 CPU device; only
launch/dryrun.py forces the 512-device host platform."""
import os
import sys

import numpy as np
import pytest

# Property tests import `hypothesis`; on interpreters without it, install
# the deterministic fallback BEFORE test modules are collected so the
# suite still collects and the property tests run a fixed example sweep.
sys.path.insert(0, os.path.dirname(__file__))
import _hypothesis_shim  # noqa: E402

_hypothesis_shim.install()


@pytest.fixture(scope="session")
def rng():
    return np.random.RandomState(0)
