"""Test fixtures. NOTE: no XLA_FLAGS here — tests see 1 CPU device; only
launch/dryrun.py forces the 512-device host platform."""
import os
import sys

import numpy as np
import pytest

# Property tests import `hypothesis`; on interpreters without it, install
# the deterministic fallback BEFORE test modules are collected so the
# suite still collects and the property tests run a fixed example sweep.
sys.path.insert(0, os.path.dirname(__file__))
import _hypothesis_shim  # noqa: E402

_hypothesis_shim.install()


@pytest.fixture(scope="session")
def rng():
    return np.random.RandomState(0)


@pytest.fixture
def compile_budget():
    """`repro.analysis.guards.compilation_budget` as a fixture: wrap a
    block in `with compile_budget(n):` to pin at most n fresh XLA
    compiles inside it (n=0 pins "fully warmed, no retraces").  Counts
    real backend compiles via jax.monitoring, so tracing-cache hits are
    free and the budget survives jit internals changing."""
    from repro.analysis.guards import compilation_budget
    return compilation_budget
