"""Unified serving API (repro.serving): chunked-streaming parity of
`Session.push` against single-shot decoding and against a primitive
(pre-engine) reference, engine admission edge cases, and the per-slot
LM cache-metadata regression (staggered admissions with unequal prompt
lengths)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.tds_asr import (DecoderConfig, FeatureConfig, TDSConfig,
                                   TDSStage)
from repro.core import decoder, features, lexicon as lx
from repro.data.pipeline import SyntheticASR
from repro.models import LM, tds
from repro.serving import (AsrEngine, AsrProgram, EngineConfig, LmEngine,
                           LmProgram)

TINY_TDS = TDSConfig(
    stages=(TDSStage(1, 3, 16, 5, 2), TDSStage(1, 4, 16, 5, 2),
            TDSStage(1, 4, 16, 5, 2)),
    sub_kernel=6, vocab_size=20)
FEAT16 = FeatureConfig(n_mels=16, n_mfcc=16)


def _asr_system():
    words = {f"w{i}": [1 + (i * 3 + j) % 18 for j in range(2 + i % 3)]
             for i in range(8)}
    lex = lx.build_lexicon(words, max_children=16)
    lm = lx.uniform_bigram(len(words))
    dcfg = DecoderConfig(beam_size=16, beam_threshold=30.0)
    params = tds.init_tds(jax.random.PRNGKey(0), TINY_TDS)
    return words, lex, lm, dcfg, params


def _asr_engine(n_slots):
    words, lex, lm, dcfg, params = _asr_system()
    program = AsrProgram(TINY_TDS, lex, lm, FEAT16, dcfg)
    return AsrEngine(EngineConfig(program, n_slots=n_slots), params), words


def _same(a, b, tol=1e-3):
    assert a["words"].tolist() == b["words"].tolist(), (a, b)
    assert a["tokens"].tolist() == b["tokens"].tolist(), (a, b)
    assert abs(a["score"] - b["score"]) <= tol, (a, b)


# ---------------------------------------------------------------------------
# chunked-streaming parity
# ---------------------------------------------------------------------------
def _reference_decode(audio, words, lex, lm, dcfg, params):
    """Pre-engine ground truth: the fused decoding step re-derived from
    the core primitives, with window bookkeeping straight from
    frames_producible/consumed_samples.  Like the engine, end-of-input
    zero-pads and decodes a trailing partial window (samples beyond the
    frame_len - frame_shift framing overlap were never covered by a
    decoded frame).  Returns (best dict, n_steps)."""
    nfr = 8                      # 80 ms / 10 ms shift
    spp = features.consumed_samples(nfr, FEAT16)
    need = FEAT16.frame_len + (nfr - 1) * FEAT16.frame_shift
    ss = tds.init_stream_state(TINY_TDS)
    bm = decoder.init_state(dcfg.beam_size, lm)
    buf = np.asarray(audio, np.float32)
    steps = 0

    def one_step(buf, ss, bm):
        feats = features.mfcc(jnp.asarray(buf[:need]), FEAT16)[:nfr]
        logp, ss = tds.forward(params, TINY_TDS, feats, ss)
        for t in range(logp.shape[0]):
            bm = decoder.expand_step(bm, logp[t], lex, lm, dcfg)
        return ss, bm

    while features.frames_producible(buf.shape[0], FEAT16) >= nfr:
        ss, bm = one_step(buf, ss, bm)
        buf = buf[spp:]
        steps += 1
    if buf.shape[0] > need - spp:        # trailing partial window
        padded = np.zeros((need,), np.float32)
        padded[:buf.shape[0]] = buf
        ss, bm = one_step(padded, ss, bm)
        steps += 1
    return decoder.best_hypothesis(bm, lex, lm, dcfg, final=True), steps


def test_chunked_push_matches_single_shot_and_reference():
    """Pushing an utterance in arbitrary-sized chunks must produce the
    same hypothesis as one single-shot push — and both must match the
    primitive reference decode (same step count included)."""
    engine, words = _asr_engine(1)
    _, lex, lm, dcfg, params = _asr_system()
    audio = SyntheticASR(words).utterance(3)["audio"]
    ref, ref_steps = _reference_decode(audio, words, lex, lm, dcfg, params)
    assert ref_steps > 0

    rng = np.random.RandomState(0)
    irregular = []
    off = 0
    while off < len(audio):
        n = int(rng.randint(1, 4000))
        irregular.append(n)
        off += n
    for sizes in ([len(audio)],            # single shot
                  [1280] * (len(audio) // 1280 + 1),   # one window per push
                  [640] * (len(audio) // 640 + 1),     # half windows
                  irregular):
        session = engine.open()
        off = 0
        for n in sizes:
            session.push(audio[off:off + n])
            off += n
        got = session.finish()
        assert got is not None and session.done
        _same(got, ref)
        assert got["steps"] == ref_steps


def test_poll_is_read_only_on_results():
    """poll() after finish returns the stored result unchanged."""
    engine, words = _asr_engine(1)
    audio = SyntheticASR(words).utterance(1)["audio"]
    session = engine.open().push(audio)
    fin = session.finish()
    again = session.poll()
    _same(fin, again, tol=0.0)
    assert again["steps"] == fin["steps"]


def test_polled_result_mutation_cannot_corrupt_engine():
    """Results handed out by poll()/finish()/serve() are defensive
    copies: mutating a polled payload in place must not change what any
    later poll returns.  The old path returned `dict(result)` — a
    shallow copy whose numpy arrays ALIASED the engine-stored result."""
    engine, words = _asr_engine(1)
    audio = SyntheticASR(words).utterance(3)["audio"]
    session = engine.open().push(audio)
    pristine = session.finish()
    victim = session.poll()
    assert victim["tokens"].size > 0        # something to corrupt
    victim["tokens"][:] = -7
    victim["words"][:] = -7
    fresh = session.poll()
    _same(fresh, pristine, tol=0.0)
    assert not np.array_equal(fresh["tokens"], victim["tokens"])

    # the LM engine's token list is isolated the same way
    cfg = get_config("mamba2-1.3b").tiny()
    lm_engine = LmEngine(
        EngineConfig(LmProgram(cfg, cache_len=16, max_new=4), n_slots=1),
        LM(cfg).init(jax.random.PRNGKey(0)))
    s = lm_engine.open().push(np.arange(1, 6, dtype=np.int32))
    ref_tokens = list(s.poll()["tokens"])
    polled = s.poll()
    polled["tokens"].append(999)
    assert s.poll()["tokens"] == ref_tokens


def test_tail_flush_decodes_final_partial_window():
    """finish() must decode the trailing partial window instead of
    silently dropping it.  Pinned as parity: flushing a truncated
    utterance is bit-identical to explicitly pushing the same audio
    zero-padded to the window boundary (so whatever words end in the
    tail appear exactly as a full-window decode of them would), and an
    utterance ending exactly on the framing overlap is bit-identical
    between flush_tail=True and flush_tail=False engines."""
    engine, words = _asr_engine(1)
    spp, need, overlap = engine._spp, engine._need, engine._overlap
    audio = SyntheticASR(words).utterance(3)["audio"]
    k = 3
    L = k * spp + overlap + 600              # real samples past the overlap
    assert overlap < L - k * spp < need and len(audio) >= k * spp + need
    trunc = audio[:L]
    got = engine.open().push(trunc).finish()
    assert got["steps"] == k + 1             # exactly one extra flush step

    padded = np.concatenate(
        [trunc, np.zeros((k * spp + need - L,), np.float32)])
    ref = engine.open().push(padded).finish()
    assert ref["steps"] == k + 1
    _same(got, ref, tol=0.0)

    # window-boundary utterances (nothing past the overlap) are
    # untouched: bit-identical with flushing disabled
    exact = audio[:k * spp + overlap]
    words_, lex, lm, dcfg, params = _asr_system()
    no_flush = AsrEngine(
        EngineConfig(AsrProgram(TINY_TDS, lex, lm, FEAT16, dcfg,
                                flush_tail=False), n_slots=1), params)
    a = engine.open().push(exact).finish()
    b = no_flush.open().push(exact).finish()
    assert a["steps"] == b["steps"] == k
    _same(a, b, tol=0.0)
    # and the no-flush engine really does drop the tail the flush decodes
    c = no_flush.open().push(trunc).finish()
    assert c["steps"] == k == got["steps"] - 1


# ---------------------------------------------------------------------------
# admission edge cases
# ---------------------------------------------------------------------------
def test_more_sessions_than_slots():
    """5 utterances over 2 slots: queued sessions wait for freed slots;
    every result matches its dedicated single-slot decode."""
    engine, words = _asr_engine(2)
    data = SyntheticASR(words)
    utts = [data.utterance(i)["audio"] for i in range(5)]
    results = engine.serve(utts)

    single, _ = _asr_engine(1)
    for audio, got in zip(utts, results):
        ref = single.open().push(audio).finish()
        _same(got, ref)


def test_finish_while_others_mid_utterance():
    """A session finishing early frees its slot and admits the queued
    session while another stream is still mid-utterance; nobody's
    hypothesis is disturbed."""
    engine, words = _asr_engine(2)
    data = SyntheticASR(words)
    a0, a1, a2 = [data.utterance(i)["audio"] for i in range(3)]

    s0, s1 = engine.open(), engine.open()
    s2 = engine.open()                      # queued: both slots taken
    assert s0.admitted and s1.admitted and not s2.admitted
    s2.push(a2)
    # interleave: s1 streams half its audio, s0 finishes early
    s1.push(a1[:len(a1) // 2])
    s0.push(a0)
    r0 = s0.finish()
    assert r0 is not None and not s2.done
    assert s2.admitted                      # freed slot went to s2
    s1.push(a1[len(a1) // 2:])
    r1 = s1.finish()
    r2 = s2.poll() if s2.done else s2.finish()

    single, _ = _asr_engine(1)
    for audio, got in zip((a0, a1, a2), (r0, r1, r2)):
        ref = single.open().push(audio).finish()
        _same(got, ref)


def test_finish_without_full_window():
    """finish() on a session that never produced a full 80 ms window
    (and one that never pushed at all) returns an empty hypothesis."""
    engine, _ = _asr_engine(2)
    tiny = engine.open().push(np.zeros((100,), np.float32))
    empty = engine.open()
    for sess in (tiny, empty):
        res = sess.finish()
        assert res is not None and sess.done
        assert res["steps"] == 0
        assert res["words"].tolist() == []
        assert np.isfinite(res["score"])    # fresh beam, nothing pruned
    # the pool is fully free again: two new sessions admit immediately
    s2, s3 = engine.open(), engine.open()
    assert s2.admitted and s3.admitted


def test_push_after_finish_rejected():
    engine, _ = _asr_engine(1)
    s = engine.open()
    s.push(np.zeros((100,), np.float32))
    s.finish()
    try:
        s.push(np.zeros((100,), np.float32))
        raise AssertionError("push after finish must raise")
    except RuntimeError:
        pass


def test_engine_reset_detaches_live_sessions():
    """reset() must not leave live session handles silently swallowing
    input: detached sessions raise; completed sessions keep results."""
    engine, words = _asr_engine(1)
    done = engine.open().push(SyntheticASR(words).utterance(0)["audio"])
    done_res = done.finish()
    live = engine.open().push(np.zeros((2000,), np.float32))
    engine.reset()
    for op in (lambda: live.push(np.zeros((100,), np.float32)),
               live.poll, live.finish):
        try:
            op()
            raise AssertionError("detached session must raise")
        except RuntimeError:
            pass
    # a completed session's result survives the reset
    _same(done.poll(), done_res, tol=0.0)
    # and the pool itself is fresh
    assert engine.open().admitted and engine.n_steps == 0


def test_engine_reset_detaches_queued_sessions():
    """reset() detaches sessions still WAITING for a slot, not just the
    active ones — a queued handle must raise afterwards, not silently
    re-enter a zeroed pool."""
    engine, _ = _asr_engine(1)
    active = engine.open().push(np.zeros((2000,), np.float32))
    queued = engine.open()
    assert active.admitted and not queued.admitted
    engine.reset()
    for sess in (active, queued):
        for op in (lambda s=sess: s.push(np.zeros((10,), np.float32)),
                   sess.poll, sess.finish):
            with pytest.raises(RuntimeError, match="detached"):
                op()


def test_finish_while_queued_returns_none_then_poll_collects():
    """finish() on a still-queued session cannot finalize (its slot is
    held by an unfinished stream): it returns None, and the result is
    collected later via poll() once the slot frees — matching the
    dedicated single-slot decode."""
    engine, words = _asr_engine(1)
    data = SyntheticASR(words)
    a0, a1 = data.utterance(0)["audio"], data.utterance(1)["audio"]
    s0 = engine.open().push(a0)              # holds the only slot
    s1 = engine.open().push(a1)
    assert not s1.admitted
    assert s1.finish() is None and not s1.done
    s0.finish()                              # frees the slot
    r1 = s1.poll()
    assert s1.done
    single, _ = _asr_engine(1)
    _same(r1, single.open().push(a1).finish())


def test_admission_rejected_at_max_queue():
    """With every slot busy and the queue at `max_queue`, open() raises
    the typed `AdmissionRejected` (carrying depth and bound) instead of
    queueing unboundedly — and the queue depth never exceeds the bound."""
    from repro.serving import AdmissionRejected

    words, lex, lm, dcfg, params = _asr_system()
    program = AsrProgram(TINY_TDS, lex, lm, FEAT16, dcfg)
    engine = AsrEngine(EngineConfig(program, n_slots=1, max_queue=2),
                       params)
    active = engine.open()                   # takes the slot
    queued = [engine.open(), engine.open()]  # fills the queue
    assert active.admitted and not any(q.admitted for q in queued)
    with pytest.raises(AdmissionRejected) as exc:
        engine.open()
    assert exc.value.queue_depth == 2 and exc.value.max_queue == 2
    assert engine.metrics.rejected == 1
    assert engine.metrics.max_queue_depth <= 2

    # freeing the slot re-opens admission
    active.push(SyntheticASR(words).utterance(0)["audio"])
    active.finish()
    assert queued[0].admitted                # head of the queue moved up
    late = engine.open()                     # depth back under the bound
    assert late is not None

    # max_queue=0 means "never queue": reject unless a slot is free
    strict = AsrEngine(EngineConfig(program, n_slots=1, max_queue=0),
                       params)
    strict.open()
    with pytest.raises(AdmissionRejected):
        strict.open()


def test_session_queue_removal_scales_linearly():
    """Admission removes sessions from the MIDDLE of the queue (LM
    sessions waiting on prompts, the unadmittable-harvest path):
    `SessionQueue.remove` must be O(1), not deque's O(position) — so
    per-removal cost must not grow with queue length."""
    from time import perf_counter

    from repro.serving.engine import SessionQueue

    def per_removal(n):
        q = SessionQueue()
        items = [object() for _ in range(n)]
        for it in items:
            q.append(it)
        victims = items[n // 4: 3 * n // 4]          # all mid-queue
        t0 = perf_counter()
        for it in victims:
            q.remove(it)
        dt = perf_counter() - t0
        assert len(q) == n - len(victims)
        return max(dt / len(victims), 1e-9)

    per_removal(1000)                         # warm up allocator/caches
    small, big = per_removal(2000), per_removal(40000)
    # O(1): ratio ~1 (deque.remove measures ~10-20x here); generous
    # bound + absolute floor keep CI timing noise out
    assert big < small * 8 + 2e-6, (small, big)


def test_engine_metrics_lifecycle_counters():
    """EngineMetrics sees every session event: opened/admitted/finalized
    counters, first-result and finalize latency samples, queue-depth
    high-water mark, and step occupancy."""
    engine, words = _asr_engine(2)
    data = SyntheticASR(words)
    engine.serve([data.utterance(i)["audio"] for i in range(3)])
    m = engine.metrics
    assert m.opened == m.admitted == m.finalized == 3
    assert m.rejected == 0
    assert m.max_queue_depth >= 1            # third utterance had to wait
    assert m.queue_depth == 0                # drained
    assert m.first_result.count == 3 and m.finalize.count == 3
    assert m.e2e.count == 3 and m.queue_wait.count == 3
    assert m.steps == engine.n_steps > 0
    assert 0.0 < m.occupancy() <= 1.0
    snap = m.snapshot()
    assert snap["sessions"]["finalized"] == 3
    assert snap["latency"]["first_result"]["count"] == 3
    assert snap["latency"]["e2e"]["p95_ms"] >= 0.0


# ---------------------------------------------------------------------------
# deprecated command-API shim fidelity (repro.core.scheduler over the engine)
# ---------------------------------------------------------------------------
def test_shim_configure_between_decoding_steps_keeps_state():
    """ConfigureBeamWidth between DecodingStep commands is legal in the
    paper's command API: in-flight buffers/left-context/beam must carry
    over to the reconfigured engine, not silently reset."""
    from repro.core.scheduler import ASRPU

    words, lex, lm, dcfg, params = _asr_system()
    audio = SyntheticASR(words).utterance(2)["audio"]
    pu = ASRPU()
    pu.configure_acoustic_scoring(TINY_TDS, params, FEAT16)
    pu.configure_hyp_expansion(lex, lm, dcfg)
    pu.decoding_step(audio[: len(audio) // 2])
    n1 = pu._n_steps
    assert n1 > 0
    pu.configure_beam_width(25.0)
    assert pu._n_steps == n1            # state survived reconfiguration
    best = pu.decoding_step(audio[len(audio) // 2:])
    assert pu._n_steps > n1
    assert np.isfinite(best["score"])


def test_shim_best_after_partial_first_chunk():
    """decoding_step with less than one window initializes the beam
    (old ASRPU behavior): best() reads a fresh hypothesis — score 0,
    empty words AND a tokens key — not the unconfigured -inf sentinel."""
    from repro.core.scheduler import ASRPU

    _, lex, lm, dcfg, params = _asr_system()
    pu = ASRPU()
    pu.configure_acoustic_scoring(TINY_TDS, params, FEAT16)
    pu.configure_hyp_expansion(lex, lm, dcfg)
    best = pu.decoding_step(np.zeros((100,), np.float32))
    assert pu._n_steps == 0
    assert best["score"] == 0.0
    assert best["words"].tolist() == [] and best["tokens"].tolist() == []


# ---------------------------------------------------------------------------
# LM engine: per-slot cache metadata
# ---------------------------------------------------------------------------
def test_lm_staggered_unequal_prompts_regression():
    """Two concurrent requests with different prompt lengths (slot
    offsets 5 vs 9) plus a queued third admitted into a reused slot:
    every token stream must equal its dedicated single-slot decode.
    The pre-redesign serve_lm admit() overwrote the GLOBAL cache
    kpos/offset on every admission, corrupting concurrent streams."""
    cfg = get_config("chatglm3-6b").tiny()   # attention: positions matter
    params = LM(cfg).init(jax.random.PRNGKey(0))
    program = LmProgram(cfg, cache_len=24, max_new=6)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size, n) for n in (5, 9, 7)]

    engine = LmEngine(EngineConfig(program, n_slots=2), params)
    got = engine.serve(prompts)
    assert engine.n_steps < 3 * (program.max_new - 1)   # batching batched

    for prompt, tokens in zip(prompts, got):
        ref = LmEngine(EngineConfig(program, n_slots=1),
                       params).serve([prompt])[0]
        assert tokens == ref
        assert len(tokens) == program.max_new


def test_lm_session_poll_protocol():
    cfg = get_config("mamba2-1.3b").tiny()
    params = LM(cfg).init(jax.random.PRNGKey(0))
    program = LmProgram(cfg, cache_len=16, max_new=4)
    engine = LmEngine(EngineConfig(program, n_slots=2), params)
    s = engine.open()
    assert s.poll() == {"tokens": [], "done": False}
    prompt = np.arange(1, 6, dtype=np.int32)
    out = s.push(prompt).poll()
    assert out["done"] and len(out["tokens"]) == 4
    # prompt too long for the cache, or empty, is rejected up front
    # (admission would otherwise crash mid-prefill and strand the slot)
    for bad in (np.ones((20,), np.int32), np.zeros((0,), np.int32)):
        try:
            engine.open().push(bad)
            raise AssertionError("invalid prompt must raise")
        except ValueError:
            pass
    # finish() on a session that never pushed a prompt closes it with an
    # empty result instead of queueing forever
    idle = engine.open()
    res = idle.finish()
    assert res == {"tokens": [], "done": True}
    assert idle.poll() == {"tokens": [], "done": True}
    assert idle not in engine._queue


def test_lm_swa_ring_cache_admission():
    """Sliding-window archs clamp the allocated cache ring to
    attn_window < cache_len: admission must size its per-slot kpos rows
    from the real ring (a cache_len-sized row used to crash the set),
    including a prompt longer than the ring (trimmed by prefill)."""
    cfg = get_config("h2o-danube-1.8b").tiny()       # attn_window = 64
    assert cfg.attn_window is not None
    params = LM(cfg).init(jax.random.PRNGKey(0))
    program = LmProgram(cfg, cache_len=128, max_new=4)
    rng = np.random.default_rng(2)
    # 9 / 32: shorter than the ring; 96: longer (prefill trims to the
    # ring) — lengths chosen divisible into prefill's attention chunks
    prompts = [rng.integers(1, cfg.vocab_size, n) for n in (32, 9, 96)]
    engine = LmEngine(EngineConfig(program, n_slots=2), params)
    assert engine._ring == cfg.attn_window
    got = engine.serve(prompts)
    assert all(len(t) == program.max_new for t in got)
    for prompt, tokens in zip(prompts, got):
        ref = LmEngine(EngineConfig(program, n_slots=1),
                       params).serve([prompt])[0]
        assert tokens == ref


def test_lm_single_admission_prefills_one_row():
    """A lone admission into an 8-slot pool must prefill a 1-row batch
    (pad-to-batch-sub-bucket), not n_slots rows — with the same tokens
    as the dedicated single-slot decode.  serve() pushes prompts one at
    a time, so each admission is its own 1-row dispatch; a wider
    admission group (slots freed in bulk) pads to the smallest covering
    sub-bucket."""
    cfg = get_config("chatglm3-6b").tiny()
    params = LM(cfg).init(jax.random.PRNGKey(0))
    program = LmProgram(cfg, cache_len=24, max_new=4)
    engine = LmEngine(EngineConfig(program, n_slots=8), params)
    assert engine._batch_buckets == (1, 2, 4, 8)
    rng = np.random.default_rng(5)
    shapes = []
    orig = engine._jit_prefill
    engine._jit_prefill = (lambda p, t, l:
                           (shapes.append(tuple(t.shape)) or orig(p, t, l)))
    lone = rng.integers(1, cfg.vocab_size, 5)
    got = engine.serve([lone])
    assert shapes == [(1, 8)]
    assert got[0] == LmEngine(EngineConfig(program, n_slots=1),
                              params).serve([lone])[0]
    # sequential pushes admit one by one: three 1-row prefills, never
    # an n_slots-row dispatch; a 3-wide group would pad to bucket 4
    shapes.clear()
    engine.serve([rng.integers(1, cfg.vocab_size, n) for n in (3, 5, 7)])
    assert shapes == [(1, 8)] * 3
    assert next(b for b in engine._batch_buckets if b >= 3) == 4


def test_lm_bucketed_prefill_bounds_jit_entries(compile_budget):
    """Staggered admissions with MANY distinct prompt lengths compile at
    most len(program.buckets()) prefill jit entries (pad-to-bucket +
    batch padded to n_slots), and every token stream still equals its
    dedicated single-slot decode.  Pinned with the compilation-budget
    fixture: once every bucket is warmed, a second wave of NEW lengths
    mapping into the same buckets must compile NOTHING."""
    cfg = get_config("chatglm3-6b").tiny()
    params = LM(cfg).init(jax.random.PRNGKey(0))
    program = LmProgram(cfg, cache_len=24, max_new=6)
    assert program.buckets() == (8, 16, 32)
    rng = np.random.default_rng(3)
    lengths = (3, 5, 7, 9, 12, 17, 18)      # 7 distinct lengths, 3 buckets
    prompts = [rng.integers(1, cfg.vocab_size, n) for n in lengths]

    engine = LmEngine(EngineConfig(program, n_slots=2), params)
    got = engine.serve(prompts)
    for prompt, tokens in zip(prompts, got):
        ref = LmEngine(EngineConfig(program, n_slots=1),
                       params).serve([prompt])[0]
        assert tokens == ref
        assert len(tokens) == program.max_new

    # new lengths, same buckets: 4,6 -> 8; 10 -> 16; 17 -> 32
    # (17 is the longest fresh length fitting cache_len - max_new = 18)
    with compile_budget(0, "warmed bucketed LM serve"):
        again = engine.serve([rng.integers(1, cfg.vocab_size, n)
                              for n in (4, 6, 10, 17)])
    assert all(len(t) == program.max_new for t in again)


@pytest.mark.parametrize("arch", ["chatglm3-6b", "mamba2-1.3b",
                                  "h2o-danube-1.8b"])
def test_masked_prefill_matches_unmasked(arch):
    """LM.prefill(lengths=...) on a right-padded bucket returns the
    same last-token logits and per-position cache state as the unpadded
    prefill (attention exactly; SSM to float error of the chunked
    scan), plus per-row kpos/offset ready for the serving pool."""
    cfg = get_config(arch).tiny()
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    plen, bucket, ring = 9, 16, lm.cache_len(24)
    toks = rng.integers(1, cfg.vocab_size, (1, plen)).astype(np.int32)
    l_ref, c_ref = lm.prefill(params, {"tokens": jnp.asarray(toks)})
    padded = np.zeros((1, bucket), np.int32)
    padded[0, :plen] = toks[0]
    l_got, c_got = lm.prefill(params, {"tokens": jnp.asarray(padded)},
                              lengths=jnp.asarray([plen], jnp.int32),
                              cache_len=ring)
    np.testing.assert_allclose(
        np.asarray(l_got[0, :cfg.vocab_size], np.float32),
        np.asarray(l_ref[0, :cfg.vocab_size], np.float32),
        rtol=1e-5, atol=1e-5)

    def cmp(a, b):
        a, b = np.asarray(a, np.float32), np.asarray(b, np.float32)
        if a.ndim >= 3 and a.shape[2] != b.shape[2]:    # attn cache rows
            n = min(plen, a.shape[2], b.shape[2])
            a, b = a[:, :, :n], b[:, :, :n]
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)

    jax.tree.map(cmp, c_got["layers"], c_ref["layers"])
    kpos = np.asarray(c_got["kpos"])
    assert kpos.shape == (1, ring)
    assert kpos[0, :plen].tolist() == list(range(plen))
    assert (kpos[0, plen:] == -1).all()
    assert np.asarray(c_got["offset"]).tolist() == [plen]


def test_asr_engine_quantizes_weights_exactly_once(monkeypatch):
    """An int8 AsrProgram quantizes its FC/head weights ONCE at engine
    build (`AsrProgram.prepare_params` -> `tds.quantize_params`), and
    the decoding step never re-quantizes a weight: tracing + running the
    step must add zero `prepare_int8_weights` calls (same style as the
    LM bucketed-prefill jit-entry bound).  The old path called
    `quantize_rows(w.T)` inside `ops.int8_matmul` on every step."""
    from repro.kernels import ops

    words, lex, lm, dcfg, params = _asr_system()
    program = AsrProgram(TINY_TDS, lex, lm, FEAT16, dcfg, use_int8=True)
    calls = []
    orig = ops.prepare_int8_weights
    monkeypatch.setattr(ops, "prepare_int8_weights",
                        lambda w: calls.append(w.shape) or orig(w))
    engine = AsrEngine(EngineConfig(program, n_slots=2), params)
    n_fc = sum(s.kind in ("fc", "head")
               for s in tds.build_kernel_specs(TINY_TDS))
    assert len(calls) == n_fc, (len(calls), n_fc)
    data = SyntheticASR(words)
    got = engine.serve([data.utterance(0)["audio"],
                        data.utterance(1)["audio"]])
    assert all(np.isfinite(r["score"]) for r in got)
    assert len(calls) == n_fc, \
        f"weight quantization ran in the serving hot path: {calls[n_fc:]}"

    # and the prepared path decodes exactly like the single-slot engine
    ref = AsrEngine(EngineConfig(program, n_slots=1), params)
    for audio, res in zip([data.utterance(0)["audio"],
                           data.utterance(1)["audio"]], got):
        _same(res, ref.serve([audio])[0])


def test_deprecated_shims_warn_and_still_work():
    """ASRPU / MultiStreamASRPU emit DeprecationWarning at construction
    and keep decoding through the batched-expansion engine."""
    from repro.core.scheduler import ASRPU, MultiStreamASRPU

    words, lex, lm, dcfg, params = _asr_system()
    audio = SyntheticASR(words).utterance(0)["audio"]
    with pytest.warns(DeprecationWarning, match="ASRPU is deprecated"):
        pu = ASRPU()
    pu.configure_acoustic_scoring(TINY_TDS, params, FEAT16)
    pu.configure_hyp_expansion(lex, lm, dcfg)
    best = pu.decoding_step(audio)
    assert np.isfinite(best["score"])
    with pytest.warns(DeprecationWarning, match="MultiStreamASRPU"):
        MultiStreamASRPU(2)


def test_lm_per_slot_cache_matches_scalar_cache():
    """Model-level check of the per-slot decode path: a pooled per-slot
    cache holding two streams at different offsets decodes each row
    exactly as the scalar-offset cache decodes it alone."""
    cfg = get_config("chatglm3-6b").tiny()
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    Sc = 20
    rng = np.random.default_rng(1)
    pA = rng.integers(1, cfg.vocab_size, 4)
    pB = rng.integers(1, cfg.vocab_size, 8)

    def put(dst, src, slot):
        src = src.astype(dst.dtype)
        if dst.ndim >= 3 and src.shape[2] != dst.shape[2]:
            return dst.at[:, slot:slot + 1, :src.shape[2]].set(src)
        return dst.at[:, slot:slot + 1].set(src)

    def ref_decode(prompt, n_new):
        logits, pc = lm.prefill(params, {"tokens": jnp.asarray(prompt)[None]})
        cache = lm.init_cache(1, Sc)
        cache["layers"] = jax.tree.map(lambda d, s: put(d, s, 0),
                                       cache["layers"], pc["layers"])
        L = len(prompt)
        cache["kpos"] = cache["kpos"].at[:L].set(jnp.arange(L))
        cache["offset"] = jnp.full((), L, jnp.int32)
        toks = [int(jnp.argmax(logits[0, :cfg.vocab_size]))]
        for _ in range(n_new - 1):
            _, tok, cache = lm.decode_step(
                params, cache, {"tokens": jnp.asarray([toks[-1:]])})
            toks.append(int(tok[0]))
        return toks

    refA, refB = ref_decode(pA, 5), ref_decode(pB, 5)

    cache = lm.init_cache(2, Sc, per_slot=True)
    assert cache["kpos"].shape == (2, Sc) and cache["offset"].shape == (2,)
    tokens = jnp.zeros((2, 1), jnp.int32)
    gen = {0: [], 1: []}
    for slot, prompt in ((0, pA), (1, pB)):
        logits, pc = lm.prefill(params, {"tokens": jnp.asarray(prompt)[None]})
        cache["layers"] = jax.tree.map(lambda d, s, slot=slot: put(d, s, slot),
                                       cache["layers"], pc["layers"])
        L = len(prompt)
        row = jnp.full((Sc,), -1, jnp.int32).at[:L].set(jnp.arange(L))
        cache["kpos"] = cache["kpos"].at[slot].set(row)
        cache["offset"] = cache["offset"].at[slot].set(L)
        first = int(jnp.argmax(logits[0, :cfg.vocab_size]))
        tokens = tokens.at[slot, 0].set(first)
        gen[slot].append(first)
    for _ in range(4):
        _, tok, cache = lm.decode_step(params, cache, {"tokens": tokens})
        tokens = tok[:, None]
        gen[0].append(int(tok[0]))
        gen[1].append(int(tok[1]))
    assert gen[0] == refA
    assert gen[1] == refB
