"""Elastic re-mesh: checkpoint on one topology, resume on another, with
bit-identical data continuation (subprocess with multi-device host).

The resume path must continue the straight-training trajectory:
  * `mesh_invariant_rng` makes `jax.jit(init, out_shardings=...)` a pure
    function of the key — legacy threefry lowering produced DIFFERENT
    params from the same key on different meshes (~0.5 max delta), so
    the un-interrupted reference run started from other weights than the
    job it was supposed to reproduce (the pre-seed KNOWN-FAILING mode of
    this test).
  * `replace_state` re-places params AND optimizer moments with the
    surviving mesh's shardings (moments via `_opt_shardings_like`, which
    also covers int8 {'q','scale'} moment trees).
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.runtime.elastic import plan_remesh


def test_plan_remesh_preserves_model_axis():
    p = plan_remesh(8, model_parallel=2, global_batch=16)
    assert p.model == 2 and p.data == 4
    # batch not divisible by the naive data axis -> shrink to a divisor
    p = plan_remesh(12, model_parallel=2, global_batch=8)
    assert p.data in (4, 2, 1) and 8 % p.data == 0


def test_replace_state_replaces_params_and_moments():
    """Single-device roundtrip of the elastic restore path: params and
    BOTH moment trees come back with the target mesh's shardings and
    the checkpointed values (the old path placed 'm'/'v' with the raw
    param shardings, which mis-places derived moment layouts)."""
    import tempfile

    from repro.ckpt.checkpoint import Checkpointer
    from repro.configs import get_config
    from repro.launch.steps import build_lm
    from repro.optim import adamw
    from repro.runtime.elastic import replace_state

    cfg = get_config("h2o-danube-1.8b").tiny()
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    lm = build_lm(cfg, mesh)
    ocfg = adamw.AdamWConfig(lr=1e-3)
    params = lm.init(jax.random.PRNGKey(0))
    state = {"params": params, "opt": adamw.init(params, ocfg),
             "step": jnp.zeros((), jnp.int32)}
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d)
        ck.save(3, state)
        got = replace_state(cfg, ck, state, mesh, step=3)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(state)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    for leaf in jax.tree.leaves(got["opt"]):
        assert leaf.sharding.mesh.shape["model"] == 1  # placed on the mesh


SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np, tempfile
    from repro.ckpt.checkpoint import Checkpointer
    from repro.configs import get_config
    from repro.data.pipeline import DataConfig, SyntheticLM
    from repro.launch.steps import build_lm, make_train_step
    from repro.optim import adamw
    from repro.parallel import sharding as shlib
    from repro.runtime.elastic import (build_mesh, mesh_invariant_rng,
                                       plan_remesh, replace_state)

    mesh_invariant_rng()     # same key => same logical init on ANY mesh
    # fp32: the 1e-5 resume-parity bound is a numerics assertion on the
    # restore path; bf16 cross-topology reduction noise would drown it
    cfg = dataclasses.replace(get_config("h2o-danube-1.8b").tiny(),
                              dtype="float32")
    ocfg = adamw.AdamWConfig(lr=3e-4)
    ckdir = tempfile.mkdtemp()

    def run(plan, start, steps, resume):
        mesh = build_mesh(plan)
        lm = build_lm(cfg, mesh)
        p_sh = shlib.param_shardings(cfg, lm.param_shapes(), mesh)
        ck = Checkpointer(ckdir)
        with mesh:
            params = jax.jit(lm.init, out_shardings=p_sh)(jax.random.PRNGKey(0))
            state = {"params": params, "opt": adamw.init(params, ocfg),
                     "step": jnp.zeros((), jnp.int32)}
            if resume:    # elastic restore INTO this mesh's shardings
                state = replace_state(cfg, ck, state, mesh, step=start)
            jstep = jax.jit(make_train_step(lm, ocfg))
            data = SyntheticLM(DataConfig(cfg.vocab_size, 32, 8))
            for s in range(start, start + steps):
                batch = jax.tree.map(jnp.asarray, data.batch(s))
                state, m = jstep(state, batch)
            ck.save(start + steps, state)
            return float(m["loss"]), jax.device_get(state["params"])

    # phase 1: 4x2 mesh, 4 steps
    l1, _ = run(plan_remesh(8, model_parallel=2, global_batch=8), 0, 4, False)
    # phase 2 (elastic: "lost a host"): 2x2 mesh, resume step 4
    l2, p2 = run(plan_remesh(4, model_parallel=2, global_batch=8), 4, 2, True)
    # reference: uninterrupted 6 steps on the small mesh
    import shutil; shutil.rmtree(ckdir); os.makedirs(ckdir)
    l3, p3 = run(plan_remesh(4, model_parallel=2, global_batch=8), 0, 6, False)
    # same data stream + same init => same trajectory modulo topology fp noise
    d = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
            for a, b in zip(jax.tree.leaves(p2), jax.tree.leaves(p3)))
    print("resumed-vs-straight max param delta:", d)
    assert d <= 1e-5, d
    assert abs(l2 - l3) < 1e-4, (l2, l3)
    print("ELASTIC_OK")
""")


@pytest.mark.slow
def test_elastic_resume_subprocess():
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", SUBPROC], env=env,
                       capture_output=True, text=True,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert "ELASTIC_OK" in r.stdout, r.stdout + r.stderr[-3000:]
