"""Elastic re-mesh: checkpoint on one topology, resume on another, with
bit-identical data continuation (subprocess with multi-device host)."""
import os
import subprocess
import sys
import textwrap

import pytest

from repro.runtime.elastic import plan_remesh


def test_plan_remesh_preserves_model_axis():
    p = plan_remesh(8, model_parallel=2, global_batch=16)
    assert p.model == 2 and p.data == 4
    # batch not divisible by the naive data axis -> shrink to a divisor
    p = plan_remesh(12, model_parallel=2, global_batch=8)
    assert p.data in (4, 2, 1) and 8 % p.data == 0


SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np, tempfile
    from repro.ckpt.checkpoint import Checkpointer
    from repro.configs import get_config
    from repro.data.pipeline import DataConfig, SyntheticLM
    from repro.launch.steps import build_lm, make_train_step
    from repro.optim import adamw
    from repro.parallel import sharding as shlib
    from repro.runtime.elastic import build_mesh, plan_remesh

    cfg = get_config("h2o-danube-1.8b").tiny()
    ocfg = adamw.AdamWConfig(lr=1e-3)
    ckdir = tempfile.mkdtemp()

    def run(plan, start, steps, resume):
        mesh = build_mesh(plan)
        lm = build_lm(cfg, mesh)
        p_sh = shlib.param_shardings(cfg, lm.param_shapes(), mesh)
        ck = Checkpointer(ckdir)
        with mesh:
            params = jax.jit(lm.init, out_shardings=p_sh)(jax.random.PRNGKey(0))
            state = {"params": params, "opt": adamw.init(params, ocfg),
                     "step": jnp.zeros((), jnp.int32)}
            if resume:
                state = ck.restore(state)
            jstep = jax.jit(make_train_step(lm, ocfg))
            data = SyntheticLM(DataConfig(cfg.vocab_size, 32, 8))
            for s in range(start, start + steps):
                batch = jax.tree.map(jnp.asarray, data.batch(s))
                state, m = jstep(state, batch)
            ck.save(start + steps, state)
            return float(m["loss"]), jax.device_get(state["params"])

    # phase 1: 4x2 mesh, 4 steps
    l1, _ = run(plan_remesh(8, model_parallel=2, global_batch=8), 0, 4, False)
    # phase 2 (elastic: "lost a host"): 2x2 mesh, resume step 4
    l2, p2 = run(plan_remesh(4, model_parallel=2, global_batch=8), 4, 2, True)
    # reference: uninterrupted 6 steps on the small mesh
    import shutil; shutil.rmtree(ckdir); os.makedirs(ckdir)
    l3, p3 = run(plan_remesh(4, model_parallel=2, global_batch=8), 0, 6, False)
    # same data stream + same init => same trajectory modulo topology fp noise
    d = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
            for a, b in zip(jax.tree.leaves(p2), jax.tree.leaves(p3)))
    print("resumed-vs-straight max param delta:", d)
    assert d < 0.15, d
    print("ELASTIC_OK")
""")


@pytest.mark.slow
@pytest.mark.xfail(reason="KNOWN-FAILING since seed: elastic resume "
                   "diverges from straight training (~0.5 max param "
                   "delta); see ROADMAP.md open items", strict=False)
def test_elastic_resume_subprocess():
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", SUBPROC], env=env,
                       capture_output=True, text=True,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert "ELASTIC_OK" in r.stdout, r.stdout + r.stderr[-3000:]
