"""Per-kernel shape/dtype sweeps: Pallas (interpret) vs ref.py oracles."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

R = np.random.RandomState(7)


def _arr(*shape, dtype=np.float32, scale=1.0):
    return jnp.asarray((R.randn(*shape) * scale).astype(dtype))


# ---------------------------------------------------------------------------
# int8 matmul
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("m,k,n", [(8, 128, 128), (32, 256, 64),
                                   (100, 200, 96), (1, 1200, 600),
                                   (128, 128, 128)])
def test_int8_matmul_matches_ref(m, k, n):
    x, w = _arr(m, k), _arr(k, n)
    out = ops.int8_matmul(x, w)
    xq, xs = ops.quantize_rows(x)
    wqt, ws = ops.quantize_rows(w.T)
    expected = ref.int8_matmul(xq, wqt.T, xs, ws)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                               rtol=1e-5, atol=1e-4)


def test_int8_matmul_prepared_matches_unprepared():
    """prepare_int8_weights + int8_matmul_prepared == int8_matmul exactly
    (the prepared split moves weight quantization out of the call, it
    must not change a single bit of the result)."""
    x, w = _arr(9, 200), _arr(200, 96)
    wq, ws = ops.prepare_int8_weights(w)
    np.testing.assert_array_equal(
        np.asarray(ops.int8_matmul_prepared(x, wq, ws)),
        np.asarray(ops.int8_matmul(x, w)))


@pytest.mark.parametrize("m,k,n", [(16, 512, 256)])
def test_int8_matmul_quant_error_small(m, k, n):
    x, w = _arr(m, k), _arr(k, n)
    out = np.asarray(ops.int8_matmul(x, w))
    exact = np.asarray(x @ w)
    rel = np.abs(out - exact).max() / np.abs(exact).max()
    assert rel < 0.05, rel


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("sq,skv,causal,window", [
    (64, 64, True, None), (64, 128, True, None), (32, 128, False, None),
    (128, 128, True, 48), (64, 256, True, 17),
])
def test_flash_attention(sq, skv, causal, window, dtype):
    q = _arr(2, 3, sq, 32).astype(dtype)
    k = _arr(2, 3, skv, 32).astype(dtype)
    v = _arr(2, 3, skv, 32).astype(dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              block_q=32, block_kv=32)
    expected = ref.flash_attention(q, k, v, causal=causal, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expected, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_matches_model_attention():
    """Pallas kernel vs the pure-JAX chunked attention used by the models."""
    from repro.models import layers
    B, S, H, K, D = 2, 64, 8, 4, 16
    q = _arr(B, S, H, D)
    k = _arr(B, S, K, D)
    v = _arr(B, S, K, D)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    out_jax = layers.attention_chunked(q, k, v, pos, pos, causal=True,
                                       chunk_q=16, chunk_kv=16)
    # expand GQA for the kernel
    kk = jnp.repeat(k, H // K, axis=2)
    vv = jnp.repeat(v, H // K, axis=2)
    out_pl = ops.flash_attention(q.transpose(0, 2, 1, 3),
                                 kk.transpose(0, 2, 1, 3),
                                 vv.transpose(0, 2, 1, 3),
                                 causal=True, block_q=16, block_kv=16)
    np.testing.assert_allclose(np.asarray(out_jax, np.float32),
                               np.asarray(out_pl.transpose(0, 2, 1, 3),
                                          np.float32), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("t,d", [(32, 64), (256, 80), (100, 257),
                                 (37, 80), (300, 129)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_layernorm(t, d, dtype):
    """Includes row counts that are no multiple of the row tile (the
    kernel pads rows, which are independent, and slices the pad off)."""
    x = _arr(t, d).astype(dtype)
    s, b = _arr(d), _arr(d)
    np.testing.assert_allclose(
        np.asarray(ops.layernorm(x, s, b), np.float32),
        np.asarray(ref.layernorm(x, s, b), np.float32),
        rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5, atol=2e-2)


def test_rmsnorm():
    x, s = _arr(128, 96), _arr(96)
    np.testing.assert_allclose(np.asarray(ops.rmsnorm(x, s)),
                               np.asarray(ref.rmsnorm(x, s)),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# logmel / beam prune / tds conv
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("t", [8, 50, 128, 300])
def test_logmel(t):
    p = jnp.abs(_arr(t, 257)) + 1e-3
    fb = jnp.abs(_arr(257, 80))
    dct = _arr(80, 40)
    np.testing.assert_allclose(np.asarray(ops.logmel(p, fb, dct)),
                               np.asarray(ref.logmel(p, fb, dct)),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("n,beam", [(100, 1.0), (1000, 5.0), (8448, 25.0)])
def test_beam_prune(n, beam):
    s = _arr(n, scale=10.0)
    np.testing.assert_array_equal(np.asarray(ops.beam_prune(s, beam)),
                                  np.asarray(ref.beam_prune(s, beam)))


@pytest.mark.parametrize("k,stride,t,w,cin,cout", [
    (9, 1, 32, 16, 5, 7), (9, 2, 32, 16, 5, 7), (10, 2, 64, 80, 15, 19),
    (21, 1, 64, 8, 3, 3),
    # t_out not divisible by the default bt=32 tile: the kernel used to
    # hard-assert here; now bt halves until it divides (40 -> 8, 48 -> 16)
    (9, 1, 48, 16, 5, 7), (9, 1, 40, 8, 3, 3), (5, 2, 72, 8, 3, 3),
])
def test_tds_conv(k, stride, t, w, cin, cout):
    x = _arr(k - 1 + t, w, cin)
    wgt = _arr(k, cin, cout, scale=0.3)
    b = _arr(cout)
    np.testing.assert_allclose(
        np.asarray(ops.tds_conv(x, wgt, b, stride=stride)),
        np.asarray(ref.tds_conv(x, wgt, b, stride=stride)),
        rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("batch,relu,residual", [
    (1, True, False), (3, True, True), (2, False, True), (4, False, False),
])
def test_tds_conv_batched_fused_epilogue(batch, relu, residual):
    """Slot-batched conv with the fused bias+ReLU+residual epilogue
    (interpret) vs the epilogue applied around the unbatched ref conv."""
    k, t, w, cin = 9, 24, 8, 6
    cout = cin if residual else 7
    x = _arr(batch, k - 1 + t, w, cin)
    wgt = _arr(k, cin, cout, scale=0.3)
    b = _arr(cout)
    res = _arr(batch, t, w, cout) if residual else None
    got = ops.tds_conv(x, wgt, b, relu=relu, res=res,
                       policy=ops.KernelPolicy("interpret"))
    assert got.shape == (batch, t, w, cout)
    for i in range(batch):
        want = ref.tds_conv(x[i], wgt, b)
        if relu:
            want = jnp.maximum(want, 0.0)
        if residual:
            want = want + res[i]
        np.testing.assert_allclose(np.asarray(got[i]), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)
