"""Mamba2/SSD: chunked scan vs exact recurrence (property-based)."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.configs.base import SSMSpec
from repro.models import mamba


def _naive_recurrence(x, dt, A, Bm, Cm):
    """Exact per-step recurrence: h = h*exp(dt*A) + dt*B(x); y = C.h."""
    B, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    h = np.zeros((B, H, P, N), np.float64)
    ys = np.zeros((B, S, H, P), np.float64)
    for t in range(S):
        for b in range(B):
            for hh in range(H):
                g = hh // rep
                dec = np.exp(float(dt[b, t, hh]) * float(A[hh]))
                h[b, hh] = h[b, hh] * dec + float(dt[b, t, hh]) * np.outer(
                    x[b, t, hh], Bm[b, t, g])
                ys[b, t, hh] = h[b, hh] @ Cm[b, t, g]
    return ys, h


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10**6), st.sampled_from([2, 4, 8]),
       st.sampled_from([1, 2]))
def test_ssd_chunked_matches_recurrence(seed, chunk, groups):
    r = np.random.RandomState(seed)
    B, S, H, P, N = 2, 16, 4, 3, 5
    x = r.randn(B, S, H, P).astype(np.float32)
    dt = np.abs(r.randn(B, S, H)).astype(np.float32) * 0.5
    A = -np.abs(r.randn(H)).astype(np.float32)
    Bm = r.randn(B, S, groups, N).astype(np.float32)
    Cm = r.randn(B, S, groups, N).astype(np.float32)
    y, hT = mamba.ssd_chunked(jnp.asarray(x), jnp.asarray(dt),
                              jnp.asarray(A), jnp.asarray(Bm),
                              jnp.asarray(Cm), chunk)
    y_ref, h_ref = _naive_recurrence(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(hT), h_ref, rtol=2e-3, atol=2e-3)


def test_ssd_streaming_state_carry():
    """ssd over [a;b] == ssd(a) then ssd(b, h0=state(a))."""
    r = np.random.RandomState(0)
    B, S, H, P, N = 1, 32, 4, 4, 8
    x = jnp.asarray(r.randn(B, S, H, P).astype(np.float32))
    dt = jnp.asarray(np.abs(r.randn(B, S, H)).astype(np.float32))
    A = jnp.asarray(-np.abs(r.randn(H)).astype(np.float32))
    Bm = jnp.asarray(r.randn(B, S, 1, N).astype(np.float32))
    Cm = jnp.asarray(r.randn(B, S, 1, N).astype(np.float32))
    y_full, h_full = mamba.ssd_chunked(x, dt, A, Bm, Cm, 8)
    y1, h1 = mamba.ssd_chunked(x[:, :16], dt[:, :16], A, Bm[:, :16],
                               Cm[:, :16], 8)
    y2, h2 = mamba.ssd_chunked(x[:, 16:], dt[:, 16:], A, Bm[:, 16:],
                               Cm[:, 16:], 8, h0=h1)
    np.testing.assert_allclose(np.asarray(y_full[:, 16:]), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h_full), np.asarray(h2),
                               rtol=1e-4, atol=1e-4)


def test_mamba_block_decode_equals_prefill():
    spec = SSMSpec(d_state=8, expand=2, head_dim=8, conv_kernel=4,
                   chunk_size=8)
    d_model = 32
    p = mamba.init_mamba(jax.random.PRNGKey(0), d_model, spec,
                         dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, d_model))
    y_full, _ = mamba.apply_mamba(p, x, spec)
    cache = mamba.init_cache(2, d_model, spec, jnp.float32)
    ys = []
    for t in range(16):
        y, cache = mamba.apply_mamba(p, x[:, t:t + 1], spec, cache)
        ys.append(y)
    y_steps = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_steps),
                               rtol=2e-3, atol=2e-3)
