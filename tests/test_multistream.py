"""Multi-stream (batched) ASR serving: parity against the single-stream
decoder, slot by slot — batched decode, staggered admission through the
MultiStreamASRPU slot pool, masking of inactive slots, per-slot reset."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.tds_asr import (DecoderConfig, FeatureConfig, TDSConfig,
                                   TDSStage)
from repro.core import decoder, lexicon as lx
from repro.core.scheduler import ASRPU, MultiStreamASRPU
from repro.data.pipeline import SyntheticASR
from repro.models import tds

WORDS = {"ab": [1, 2], "a": [1], "cd": [3, 4], "ac": [1, 3], "b": [2]}

TINY_TDS = TDSConfig(
    stages=(TDSStage(1, 3, 16, 5, 2), TDSStage(1, 4, 16, 5, 2),
            TDSStage(1, 4, 16, 5, 2)),
    sub_kernel=6, vocab_size=20)
FEAT16 = FeatureConfig(n_mels=16, n_mfcc=16)


def _asr_words():
    return {f"w{i}": [1 + (i * 3 + j) % 18 for j in range(2 + i % 3)]
            for i in range(8)}


def _best_tuple(beam_or_dict):
    if isinstance(beam_or_dict, dict) and "n_words" in beam_or_dict:
        b = beam_or_dict
        return (float(b["score"]),
                tuple(np.asarray(b["words"])[:int(b["n_words"])].tolist()),
                tuple(np.asarray(b["tokens"])[:int(b["n_tokens"])].tolist()))
    b = beam_or_dict
    return (b["score"], tuple(b["words"].tolist()),
            tuple(b["tokens"].tolist()))


# ---------------------------------------------------------------------------
# batched decoder primitives
# ---------------------------------------------------------------------------
def test_expand_step_batched_matches_loop():
    r = np.random.RandomState(0)
    lex = lx.build_lexicon(WORDS, max_children=4)
    lm = lx.uniform_bigram(len(WORDS))
    cfg = DecoderConfig(beam_size=16, beam_threshold=1e9)
    B = 3
    lp = jax.nn.log_softmax(jnp.asarray(r.randn(B, 5).astype(np.float32)))
    st = decoder.init_batched_state(B, cfg.beam_size, lm)
    out = decoder.expand_step_batched(st, lp, lex, lm, cfg)
    for b in range(B):
        single = decoder.expand_step(decoder.slot_state(st, b), lp[b],
                                     lex, lm, cfg)
        for got, want in zip(decoder.slot_state(out, b), single):
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("seed,T", [(0, 6), (1, 8)])
def test_decode_batched_matches_single(seed, T):
    r = np.random.RandomState(seed)
    lex = lx.build_lexicon(WORDS, max_children=4)
    lm = lx.uniform_bigram(len(WORDS))
    cfg = DecoderConfig(beam_size=32, beam_threshold=1e9,
                        lm_weight=1.0, word_score=0.5)
    B = 4
    lp = jax.nn.log_softmax(jnp.asarray(r.randn(B, T, 5).astype(np.float32)))
    batched = decoder.decode_batched(lp, lex, lm, cfg)
    fin = decoder.finalize_batched(batched, lex, lm, cfg)
    for b in range(B):
        ref = decoder.decode(lp[b], lex, lm, cfg)
        got = decoder.best(decoder.slot_state(batched, b))
        want = decoder.best(ref)
        gs, gw, gt = _best_tuple({k: np.asarray(v) for k, v in got.items()})
        ws, ww, wt = _best_tuple({k: np.asarray(v) for k, v in want.items()})
        assert abs(gs - ws) < 1e-4
        assert gw == ww and gt == wt
        # finalize commutes with batching too
        fref = decoder.finalize(ref, lex, lm, cfg)
        fgot = decoder.slot_state(fin, b)
        np.testing.assert_allclose(np.asarray(fgot.pb), np.asarray(fref.pb),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(fgot.pnb), np.asarray(fref.pnb),
                                   rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# MultiStreamASRPU slot pool
# ---------------------------------------------------------------------------
def _make(cls, *args):
    words = _asr_words()
    lex = lx.build_lexicon(words, max_children=16)
    lm = lx.uniform_bigram(len(words))
    dcfg = DecoderConfig(beam_size=16, beam_threshold=30.0)
    params = tds.init_tds(jax.random.PRNGKey(0), TINY_TDS)
    pu = cls(*args)
    pu.configure_acoustic_scoring(TINY_TDS, params, FEAT16)
    pu.configure_hyp_expansion(lex, lm, dcfg)
    return pu, words


def test_serve_parity_staggered_admission_and_slot_reuse():
    """4 utterances over 2 slots: admission is staggered (utterance 2/3
    enter when a slot frees => per-slot reset) and every slot's result
    must match the single-stream ASRPU decode of the same utterance."""
    single, words = _make(ASRPU)
    multi, _ = _make(MultiStreamASRPU, 2)
    data = SyntheticASR(words)
    utts = [data.utterance(i) for i in range(4)]

    refs, single_steps = [], 0
    for u in utts:
        single.clean_decoding()
        single.decoding_step(u["audio"])
        refs.append(single.best(final=True))
        single_steps += single._n_steps

    results = multi.serve([u["audio"] for u in utts])
    for i, (ref, got) in enumerate(zip(refs, results)):
        rs, rw, rt = _best_tuple(ref)
        gs, gw, gt = _best_tuple(got)
        assert gw == rw and gt == rt, i
        assert abs(gs - rs) < 1e-3, i
    # batching must actually batch: fewer vmapped steps than the
    # sequential total of per-utterance steps
    assert multi._n_steps < single_steps


def test_streaming_decoding_step_parity_per_slot():
    """Chunked streaming into two slots == single-stream chunked decode."""
    single, words = _make(ASRPU)
    multi, _ = _make(MultiStreamASRPU, 2)
    data = SyntheticASR(words)
    utts = [data.utterance(10), data.utterance(11)]

    refs = []
    for u in utts:
        single.clean_decoding()
        for off in range(0, len(u["audio"]), 640):   # 40ms chunks
            single.decoding_step(u["audio"][off:off + 640])
        refs.append(single.best(final=True))

    for s, u in enumerate(utts):
        for off in range(0, len(u["audio"]), 640):
            multi.decoding_step(u["audio"][off:off + 640], slot=s)
    for s, ref in enumerate(refs):
        got = multi.best(slot=s, final=True)
        assert _best_tuple(got)[1:] == _best_tuple(ref)[1:], s
        assert abs(_best_tuple(got)[0] - _best_tuple(ref)[0]) < 1e-3


def test_inactive_slot_state_passes_through_unchanged():
    """A step that only slot 0 can take must leave slot 1's beam and
    left-context exactly at init (the mask keeps old state bitwise)."""
    multi, _ = _make(MultiStreamASRPU, 2)
    audio = np.random.RandomState(0).randn(4000).astype(np.float32)
    multi.decoding_step(audio, slot=0)
    assert multi._n_steps >= 1
    lm = multi._lm
    init_beam = decoder.init_state(multi._dec_cfg.beam_size, lm)
    got_beam = decoder.slot_state(multi._beam, 1)
    for got, want in zip(got_beam, init_beam):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    init_ss = tds.init_stream_state(TINY_TDS)
    for name, want in init_ss.items():
        got = np.asarray(multi._stream_state[name][1])
        np.testing.assert_array_equal(got, np.asarray(want))
    # ...and slot 0 did advance
    assert float(decoder.best(decoder.slot_state(multi._beam, 0))["score"]) \
        > -1e29


def test_per_slot_clean_decoding_resets_only_that_slot():
    multi, words = _make(MultiStreamASRPU, 2)
    data = SyntheticASR(words)
    u0, u1 = data.utterance(20), data.utterance(21)
    # pollute slot 0 with garbage audio; decode u1 into slot 1
    garbage = np.random.RandomState(1).randn(len(u0["audio"])) \
        .astype(np.float32)
    multi.decoding_step(garbage, slot=0)
    multi.decoding_step(u1["audio"], slot=1)
    beam1_before = jax.tree.map(np.asarray, decoder.slot_state(multi._beam, 1))
    # utterance boundary in slot 0 only
    multi.clean_decoding(slot=0)
    b0 = multi.best(slot=0)
    assert b0["score"] == 0.0 and len(b0["words"]) == 0
    beam1_after = jax.tree.map(np.asarray, decoder.slot_state(multi._beam, 1))
    for b, a in zip(beam1_before, beam1_after):
        np.testing.assert_array_equal(b, a)
    # slot 0 decodes the next utterance from scratch == fresh single stream
    multi.decoding_step(u0["audio"], slot=0)
    single, _ = _make(ASRPU)
    single.decoding_step(u0["audio"])
    ref = single.best(final=True)
    got = multi.best(slot=0, final=True)
    assert _best_tuple(got)[1:] == _best_tuple(ref)[1:]
    assert abs(_best_tuple(got)[0] - _best_tuple(ref)[0]) < 1e-3
