"""Loop-corrected HLO cost walker: validation against cost_analysis and
hand counts (the §Roofline extraction depends on this)."""
import jax
import jax.numpy as jnp
from jax import lax

from repro.launch.hlo_cost import analyze_hlo, parse_computations


def _compile(f, *shapes):
    args = [jax.ShapeDtypeStruct(s, jnp.float32) for s in shapes]
    return jax.jit(f).lower(*args).compile()


def _xla_flops(c):
    ca = c.cost_analysis()
    if isinstance(ca, list):         # jax 0.4.x returns [dict]
        ca = ca[0]
    return float(ca.get("flops"))


def test_loop_free_matches_cost_analysis():
    def f(x, w1, w2):
        return jnp.tanh(x @ w1) @ w2
    c = _compile(f, (256, 256), (256, 256), (256, 256))
    cost = analyze_hlo(c.as_text(), 1)
    assert cost.flops == _xla_flops(c)
    assert cost.flops == 2 * 2 * 256 ** 3


def test_scan_multiplies_by_trip_count():
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        return lax.scan(body, x, None, length=8)[0]
    c = _compile(f, (128, 128), (128, 128))
    cost = analyze_hlo(c.as_text(), 1)
    assert cost.flops == 8 * 2 * 128 ** 3
    # raw cost_analysis counts the body once — the reason the walker exists
    assert _xla_flops(c) < cost.flops / 4


def test_nested_scan():
    def f(x, w):
        def outer(c, _):
            def inner(d, _):
                return d @ w, None
            return lax.scan(inner, c, None, length=4)[0], None
        return lax.scan(outer, x, None, length=3)[0]
    c = _compile(f, (128, 128), (128, 128))
    cost = analyze_hlo(c.as_text(), 1)
    assert cost.flops == 12 * 2 * 128 ** 3


def test_tuple_typed_while_parsed():
    """Big tuple carries get /*index=N*/ comments — the regex must not
    choke (this dropped every real model's while ops once)."""
    def f(x, w):
        def body(carry, _):
            a, b, c, d, e, f2, g = carry
            return (a @ w, b, c, d, e, f2, g), None
        init = (x,) + tuple(jnp.zeros((4, 4)) for _ in range(6))
        return lax.scan(body, init, None, length=5)[0][0]
    c = _compile(f, (128, 128), (128, 128))
    comps, entry = parse_computations(c.as_text())
    assert entry is not None
    has_while = any(i["op"] == "while"
                    for instrs in comps.values() for i in instrs)
    assert has_while
    cost = analyze_hlo(c.as_text(), 1)
    assert cost.flops == 5 * 2 * 128 ** 3


def test_bytes_slices_counted_as_slices():
    """dynamic-slice of a big stack inside a loop must count slice bytes,
    not whole-operand bytes."""
    def f(stack, x):
        def body(c, i):
            w = lax.dynamic_index_in_dim(stack, i, 0, keepdims=False)
            return c @ w, None
        return lax.scan(body, x, jnp.arange(16))[0]
    c = _compile(f, (16, 128, 128), (128, 128))
    cost = analyze_hlo(c.as_text(), 1)
    assert cost.flops == 16 * 2 * 128 ** 3
    # traffic should be O(16 * slice) = ~16*(3*128*128*4) ~ 3MB, far below
    # 16 * full stack (16MB each) = 270MB
    assert cost.bytes < 40e6, cost.bytes


def test_collective_ring_factors():
    hlo = """
HloModule m
ENTRY %main (p: f32[64,64]) -> f32[64,64] {
  %p = f32[64,64] parameter(0)
  ROOT %ar = f32[64,64] all-reduce(%p), replica_groups=[1,8]<=[8], to_apply=%add
}
%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(%a, %b)
}
"""
    cost = analyze_hlo(hlo, 8)
    expected = 64 * 64 * 4 * 2 * (8 - 1) / 8
    assert abs(cost.coll["all-reduce"] - expected) < 1
