"""Substrate tests: optimizer, quantization, checkpointing, fault
tolerance, data pipeline, gradient compression."""
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.ckpt.checkpoint import Checkpointer
from repro.core import quant
from repro.data.pipeline import DataConfig, SyntheticASR, SyntheticLM
from repro.optim import adamw
from repro.parallel import compress
from repro.runtime import fault


# ---------------------------------------------------------------------------
# quantization
# ---------------------------------------------------------------------------
@settings(max_examples=40, deadline=None)
@given(st.integers(0, 10**6), st.integers(1, 300), st.floats(0.1, 100.0))
def test_quant_roundtrip_error_bound(seed, d, scale):
    r = np.random.RandomState(seed)
    x = jnp.asarray((r.randn(3, d) * scale).astype(np.float32))
    qs = quant.quantize(x)
    y = quant.dequantize(qs)[..., :d]
    # symmetric int8: error <= scale_block/2 <= max|block|/254 * 2
    err = np.abs(np.asarray(x) - np.asarray(y))
    bound = np.abs(np.asarray(x)).max() / 127.0 + 1e-6
    assert err.max() <= bound


def test_quant_preserves_zero():
    x = jnp.zeros((4, 256))
    assert np.all(np.asarray(quant.dequantize(quant.quantize(x))) == 0)


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------
def _toy_problem():
    key = jax.random.PRNGKey(0)
    w_true = jax.random.normal(key, (16, 4))
    X = jax.random.normal(jax.random.PRNGKey(1), (64, 16))
    y = X @ w_true

    def loss(p):
        return jnp.mean((X @ p["w"] - y) ** 2)
    params = {"w": jnp.zeros((16, 4))}
    return loss, params


@pytest.mark.parametrize("mdt", ["float32", "int8"])
def test_adamw_converges(mdt):
    loss, params = _toy_problem()
    cfg = adamw.AdamWConfig(lr=0.05, weight_decay=0.0, moment_dtype=mdt)
    opt = adamw.init(params, cfg)
    l0 = float(loss(params))
    for _ in range(60):
        g = jax.grad(loss)(params)
        params, opt = adamw.update(g, opt, params, cfg)
    l1 = float(loss(params))
    assert l1 < 0.05 * l0, (l0, l1)


def test_adamw_grad_clip():
    loss, params = _toy_problem()
    cfg = adamw.AdamWConfig(lr=1.0, grad_clip=1e-9, weight_decay=0.0)
    opt = adamw.init(params, cfg)
    g = jax.grad(loss)(params)
    new_p, _ = adamw.update(g, opt, params, cfg)
    # with a tiny clip the effective step stays minuscule... step is
    # m/sqrt(v) which normalizes; check no explosion instead
    assert np.isfinite(np.asarray(new_p["w"])).all()


def test_int8_moments_track_fp32():
    """int8-moment AdamW reaches the same loss basin as fp32 (individual
    weight trajectories diverge chaotically; the optimization quality is
    the invariant that matters)."""
    loss, params = _toy_problem()
    finals = {}
    for m in ("float32", "int8"):
        c = adamw.AdamWConfig(lr=0.05, weight_decay=0.0, moment_dtype=m)
        p, o = dict(params), adamw.init(params, c)
        for _ in range(80):
            g = jax.grad(loss)(p)
            p, o = adamw.update(g, o, p, c)
        finals[m] = float(loss(p))
    l0 = float(loss(params))
    assert finals["int8"] < 0.05 * l0
    assert finals["int8"] < 10 * finals["float32"] + 1e-4


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------
@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10**6))
def test_error_feedback_unbiased_over_time(seed):
    """With error feedback, the accumulated compressed signal tracks the
    accumulated true gradient (EF-SGD property)."""
    r = np.random.RandomState(seed)
    g_true = jnp.asarray(r.randn(8, 200).astype(np.float32))
    err = jnp.zeros_like(g_true)
    acc = jnp.zeros_like(g_true)
    for _ in range(20):
        qs, err = compress.compress(g_true, err)
        acc = acc + compress.decompress(qs)[..., :200]
    drift = np.abs(np.asarray(acc / 20) - np.asarray(g_true)).max()
    assert drift < np.abs(np.asarray(g_true)).max() / 127 + 1e-5


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------
def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(tmp_path, keep=2)
    state = {"params": {"w": jnp.arange(12.0).reshape(3, 4)},
             "opt": {"m": jnp.ones((3, 4)), "count": jnp.int32(7)},
             "step": jnp.int32(7)}
    ck.save(7, state)
    ck.save(9, state)
    assert ck.latest_step() == 9
    tmpl = jax.tree.map(jnp.zeros_like, state)
    out = ck.restore(tmpl, step=7)
    np.testing.assert_array_equal(np.asarray(out["params"]["w"]),
                                  np.asarray(state["params"]["w"]))
    assert int(out["step"]) == 7


def test_checkpoint_gc_and_async(tmp_path):
    ck = Checkpointer(tmp_path, keep=2)
    st_ = {"w": jnp.ones((4,))}
    for s in (1, 2, 3, 4):
        ck.save_async(s, st_)
    ck.wait()
    assert ck.all_steps() == [3, 4]


def test_checkpoint_atomic_no_partial(tmp_path):
    ck = Checkpointer(tmp_path)
    ck.save(1, {"w": jnp.ones((4,))})
    # a stale tmp dir (crashed save) is ignored
    (pathlib.Path(tmp_path) / "step_000000002.tmp").mkdir()
    assert ck.latest_step() == 1


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------
def test_run_resilient_retry_and_restore(tmp_path):
    ck = Checkpointer(tmp_path)
    calls = {"n": 0, "fails": 0}

    def step_fn(state, step):
        calls["n"] += 1
        if step == 5 and calls["fails"] < 3:
            calls["fails"] += 1
            raise fault.TransientError("simulated node loss")
        return {"x": state["x"] + 1}, {"loss": 0.0}

    state = {"x": jnp.zeros(())}
    state, stats = fault.run_resilient(step_fn, state, 0, 10,
                                       checkpointer=ck, ckpt_every=2,
                                       max_retries=2)
    assert stats["retries"] == 3
    assert stats["restores"] >= 1
    assert float(state["x"]) == 10.0 or float(state["x"]) >= 6.0


def test_watchdog_flags_stragglers():
    wd = fault.StepWatchdog(threshold=2.0)
    assert not wd.observe(1.0)
    assert not wd.observe(1.1)
    assert wd.observe(5.0)
    assert wd.stragglers == 1
    assert not wd.observe(1.0)      # baseline not poisoned by straggler


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------
def test_data_deterministic_and_resumable():
    cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=8, seed=3)
    d1 = SyntheticLM(cfg)
    d2 = SyntheticLM(cfg)
    np.testing.assert_array_equal(d1.batch(5)["tokens"], d2.batch(5)["tokens"])
    assert not np.array_equal(d1.batch(5)["tokens"], d1.batch(6)["tokens"])


def test_data_sharding_partitions_global_batch():
    cfg = DataConfig(vocab_size=100, seq_len=8, global_batch=8, seed=0)
    full = SyntheticLM(cfg).batch(2)["tokens"]
    parts = [SyntheticLM(DataConfig(100, 8, 8, 0, n_shards=4, shard=s)
                         ).batch(2)["tokens"] for s in range(4)]
    np.testing.assert_array_equal(full, np.concatenate(parts, axis=0))


def test_data_labels_are_shifted_tokens():
    cfg = DataConfig(vocab_size=50, seq_len=12, global_batch=2)
    b = SyntheticLM(cfg).batch(0)
    assert b["tokens"].shape == (2, 12) and b["labels"].shape == (2, 12)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_synthetic_asr_utterance():
    words = {"ab": [1, 2], "cd": [3, 4]}
    data = SyntheticASR(words)
    utt = data.utterance(0)
    assert utt["audio"].ndim == 1 and len(utt["audio"]) > 1000
    assert len(utt["tokens"]) >= len(utt["words"])
