"""Distribution tests: sharding-rule divisibility for every arch, tiny-mesh
compile in a subprocess (multi-device host platform), pipeline parallelism."""
import os
import subprocess
import sys
import textwrap

import jax
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.models import LM


def _axsize(shape_map, axes):
    n = 1
    for a in (axes if isinstance(axes, tuple) else (axes,)):
        n *= shape_map[a]
    return n


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
@pytest.mark.parametrize("mesh_shape", [
    {"data": 16, "model": 16},
    {"pod": 2, "data": 16, "model": 16},
])
def test_param_rules_divisible(arch, mesh_shape):
    """Every sharded dim of every parameter divides its mesh axes —
    the production meshes never hit uneven-partition fallbacks on params."""
    from repro.parallel.sharding import _param_rule

    class FakeMesh:
        axis_names = tuple(mesh_shape)
        shape = mesh_shape

    cfg = get_config(arch)
    shapes = LM(cfg).param_shapes()
    flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
    n_sharded = 0
    for path, leaf in flat:
        spec = _param_rule(path, leaf.shape, cfg, FakeMesh())
        assert len(spec) == len(leaf.shape), (path, spec, leaf.shape)
        for size, ax in zip(leaf.shape, spec):
            if ax is None:
                continue
            n_sharded += 1
            assert size % _axsize(mesh_shape, ax) == 0, (path, size, ax)
    # the big matrices must actually be sharded (no silent replication)
    assert n_sharded > 3 * cfg.n_layers / LM(cfg).R


@pytest.mark.parametrize("arch", ["qwen2-72b", "jamba-v0.1-52b",
                                  "mamba2-1.3b", "llama4-maverick-400b-a17b"])
def test_cache_rules_divisible(arch):
    from repro.launch.steps import cache_specs
    from repro.parallel.sharding import cache_shardings

    from repro.compat import abstract_mesh
    FakeMesh = abstract_mesh((16, 16), ("data", "model"))

    cfg = get_config(arch)
    lm = LM(cfg)
    for shape in cfg.shapes():
        if not shape.is_decode:
            continue
        cs = cache_specs(lm, shape)
        sh = cache_shardings(cfg, cs, FakeMesh, shape.global_batch)
        for (path, leaf), s in zip(
                jax.tree_util.tree_flatten_with_path(cs)[0],
                jax.tree.leaves(sh, is_leaf=lambda x: hasattr(x, "spec"))):
            for size, ax in zip(leaf.shape, s.spec):
                if ax is None:
                    continue
                assert size % _axsize(FakeMesh.shape, ax) == 0, (
                    arch, shape.name, path, size, ax)


SUBPROC_COMPILE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses, jax, jax.numpy as jnp
    from repro.configs import get_config
    from repro.configs.base import ShapeSpec
    from repro.launch.steps import build_cell

    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    cfg = get_config("jamba-v0.1-52b").tiny()
    for shape in (ShapeSpec("t", 64, 4, "train"), ShapeSpec("p", 64, 4, "prefill"),
                  ShapeSpec("d", 64, 4, "decode")):
        jfn, args = build_cell(cfg, shape, mesh)
        with mesh:
            compiled = jfn.lower(*args).compile()
            ca = compiled.cost_analysis()
            assert float((ca[0] if isinstance(ca, list) else ca).get("flops", 0)) > 0
    print("SUBPROC_OK")
""")


@pytest.mark.slow
def test_multidevice_compile_subprocess():
    """lower+compile on an 8-device (pod,data,model) mesh in a subprocess
    (keeps this test process at 1 device)."""
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", SUBPROC_COMPILE], env=env,
                       capture_output=True, text=True, cwd=os.path.dirname(
                           os.path.dirname(os.path.abspath(__file__))))
    assert "SUBPROC_OK" in r.stdout, r.stdout + r.stderr


SUBPROC_PIPELINE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from repro.parallel.pipeline import pipeline_apply

    mesh = jax.make_mesh((4,), ("stage",))
    S, M, mb, d = 4, 8, 2, 16
    key = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(key, (S, d, d)) * 0.1}
    x = jax.random.normal(jax.random.PRNGKey(1), (M, mb, d))

    def stage_fn(p, h):
        return jnp.tanh(h @ p["w"])

    out = pipeline_apply(stage_fn, params, x, mesh, axis="stage")
    # sequential reference
    ref = x
    for s in range(S):
        ref = jnp.tanh(ref @ params["w"][s])
    err = float(jnp.abs(out - ref).max())
    assert err < 1e-5, err
    # autodiff through the pipeline
    def loss(pp):
        return jnp.sum(pipeline_apply(stage_fn, pp, x, mesh, axis="stage") ** 2)
    g = jax.grad(loss)(params)
    gref = jax.grad(lambda pp: jnp.sum(
        jnp.tanh(jnp.tanh(jnp.tanh(jnp.tanh(x @ pp["w"][0]) @ pp["w"][1])
                          @ pp["w"][2]) @ pp["w"][3]) ** 2))(params)
    gerr = float(jnp.abs(g["w"] - gref["w"]).max())
    assert gerr < 1e-4, gerr
    print("PIPELINE_OK")
""")


@pytest.mark.slow
def test_pipeline_parallel_subprocess():
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", SUBPROC_PIPELINE], env=env,
                       capture_output=True, text=True, cwd=os.path.dirname(
                           os.path.dirname(os.path.abspath(__file__))))
    assert "PIPELINE_OK" in r.stdout, r.stdout + r.stderr


def test_input_specs_cover_all_cells():
    from repro.launch.steps import input_specs
    n = 0
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        for shape in cfg.shapes():
            specs = input_specs(cfg, shape)
            n += 1
            if cfg.embed_inputs:
                assert specs["tokens"].shape[0] == shape.global_batch
            else:
                assert specs["embeds"].shape[-1] == cfg.d_model
            if shape.is_decode:
                key = "tokens" if cfg.embed_inputs else "embeds"
                assert specs[key].shape[1] == 1
    assert n == 30   # 9*3 + 3 long_500k (sub-quadratic archs)


def test_long_500k_skips_documented():
    skips = [(a, s.name) for a in ASSIGNED_ARCHS
             for s in get_config(a).skipped_shapes()]
    assert len(skips) == 6
    assert all(s == "long_500k" for _, s in skips)
    assert ("mamba2-1.3b", "long_500k") not in skips
    assert ("jamba-v0.1-52b", "long_500k") not in skips
    assert ("h2o-danube-1.8b", "long_500k") not in skips
