"""Mesh-sharded serving step: sharded-vs-unsharded parity, mesh=None
no-op parity, slot-gather step scheduling, and KernelPolicy/shard_map
composition.

Multi-device cases run in-process when the host exposes >= 2 devices
(CI's multi-device-tests job runs this file under
XLA_FLAGS=--xla_force_host_platform_device_count=8; see ci.yml) and are
skipped on a 1-device host — the slow subprocess test always exercises
them by forcing the flag itself.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.policy import KernelPolicy
from repro.launch.serve import asr_demo_engine, asr_demo_system
from repro.serving import AsrEngine, AsrProgram, EngineConfig

multi_device = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs >= 2 devices (XLA_FLAGS=--xla_force_host_platform_"
           "device_count=N)")
multi_device4 = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs >= 4 devices (XLA_FLAGS=--xla_force_host_platform_"
           "device_count=N)")


def _utts(words, n=3):
    from repro.data.pipeline import SyntheticASR
    data = SyntheticASR(words)
    return [data.utterance(i)["audio"] for i in range(n)]


# ---------------------------------------------------------------------------
# mesh=None stays the exact current path
# ---------------------------------------------------------------------------
def test_mesh_none_noop_parity():
    """EngineConfig(mesh=None) is the default and must decode exactly
    like an engine built without any mesh argument (bitwise scores)."""
    tds_cfg, words, lex, lm, params, dec_cfg = asr_demo_system()
    program = AsrProgram(tds_cfg, lex, lm, dec_cfg=dec_cfg)
    a = AsrEngine(EngineConfig(program, n_slots=2), params)
    b = AsrEngine(EngineConfig(program, n_slots=2, mesh=None), params)
    assert b.config.mesh is None
    utts = _utts(words, 2)
    for ra, rb in zip(a.serve(utts), b.serve(utts)):
        assert ra["words"].tolist() == rb["words"].tolist()
        assert ra["score"] == rb["score"]


def test_engine_config_rejects_mesh_without_model_axis():
    tds_cfg, words, lex, lm, params, dec_cfg = asr_demo_system()
    program = AsrProgram(tds_cfg, lex, lm, dec_cfg=dec_cfg)
    mesh = jax.make_mesh((1,), ("data",))
    with pytest.raises(ValueError, match="model"):
        EngineConfig(program, mesh=mesh)


def test_lm_engine_rejects_mesh():
    from repro.configs import get_config
    from repro.serving import LmEngine, LmProgram

    cfg = get_config("mamba2-1.3b").tiny()
    program = LmProgram(cfg, cache_len=24, max_new=8)
    mesh = jax.make_mesh((1,), ("model",))
    with pytest.raises(NotImplementedError, match="ASR"):
        LmEngine(EngineConfig(program, mesh=mesh), params=None)


def test_mesh_1_shard_map_wrapper_matches_unsharded():
    """A 1-device ('model',) mesh exercises the whole shard_map wrapper
    (specs, gather/scatter, psum over a size-1 axis) on any host and
    must reproduce the unsharded engine bitwise — the machinery itself
    is a no-op at width 1."""
    mesh = jax.make_mesh((1,), ("model",))
    ref, words = asr_demo_engine(2)
    shd, _ = asr_demo_engine(2, mesh=mesh)
    utts = _utts(words, 2)
    for ra, rb in zip(ref.serve(utts), shd.serve(utts)):
        assert ra["words"].tolist() == rb["words"].tolist()
        assert ra["tokens"].tolist() == rb["tokens"].tolist()
        assert abs(ra["score"] - rb["score"]) < 1e-5


def test_warmed_engine_serves_under_zero_compile_budget(compile_budget):
    """A warmed engine re-serving the same workload (same slot/window
    buckets) must compile NOTHING: the gathered sub-batch step is
    shape-stable across waves, so a retrace here means a (b, w) bucket
    or readout shape silently varied."""
    engine, words = asr_demo_engine(2)
    utts = _utts(words, 2)
    first = engine.serve(utts)
    engine.serve(utts)      # wave 2 also warms the slot-reset path
    # (re-admission resets slots; wave 1 ran on fresh state and never
    # compiled reset, so only wave 3 runs with everything warmed)
    with compile_budget(0, "warmed AsrEngine.serve wave"):
        again = engine.serve(utts)
    for ra, rb in zip(first, again):
        assert ra["words"].tolist() == rb["words"].tolist()
        assert ra["tokens"].tolist() == rb["tokens"].tolist()


# ---------------------------------------------------------------------------
# slot-gather scheduling (the batched-serve regression fix)
# ---------------------------------------------------------------------------
def test_lone_active_slot_steps_at_subbatch_one():
    """One busy slot in a 4-slot pool must step at b=1, not at a masked
    b=4 (the full-pool masked step made the batched engine 1.25x SLOWER
    than sequential on ragged utterance tails)."""
    engine, words = asr_demo_engine(4)
    engine.serve(_utts(words, 1))
    assert engine.step_shapes, "no steps ran"
    assert all(b == 1 for (_, b, _) in engine.step_shapes), \
        engine.step_shapes


def test_window_bucket_maximizes_retired_windows():
    """avail=[3,3,3,5]: stepping w=4 would advance ONE slot (4 windows);
    the scheduler must take w=2 across all four slots (8 windows)."""
    engine, _ = asr_demo_engine(4)
    for s, k in enumerate((3, 3, 3, 5)):
        n = engine._need + (k - 1) * engine._spp
        engine.feed_slot(s, np.zeros((n,), np.float32))
        assert engine.slot_windows(s) == k
    assert engine._step()
    n_active, b, w = engine.step_shapes[0]
    assert (n_active, b, w) == (4, 4, 2), engine.step_shapes


def test_gathered_step_results_match_full_pool_reference():
    """Ragged per-slot feeds through the gathered sub-batch steps must
    decode every utterance exactly like a lone 1-slot engine (per-slot
    trajectories are schedule-independent)."""
    multi, words = asr_demo_engine(3)
    single, _ = asr_demo_engine(1)
    utts = _utts(words, 5)                 # 5 utts over 3 slots: reuse
    got = multi.serve(utts)
    assert {b for (_, b, _) in multi.step_shapes} != {multi.n_slots} \
        or len(utts) <= multi.n_slots      # sub-batching actually engaged
    for audio, res in zip(utts, got):
        ref = single.serve([audio])[0]
        assert res["words"].tolist() == ref["words"].tolist()
        assert res["tokens"].tolist() == ref["tokens"].tolist()
        assert abs(res["score"] - ref["score"]) < 1e-4


# ---------------------------------------------------------------------------
# KernelPolicy dispatch composes with shard_map
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("mode", ["ref", "interpret"])
def test_kernel_policy_modes_under_shard_map(mode):
    """Hot-path ops resolve and lower inside a shard_map body in every
    CPU mode — the sharded engine step wraps the whole kernel sequence
    in one per-device program."""
    from repro import compat
    from repro.kernels import ops

    mesh = jax.make_mesh((1,), ("model",))
    policy = KernelPolicy(mode)
    x = jnp.asarray(np.random.RandomState(0).randn(8, 32), jnp.float32)
    s = jnp.ones((32,), jnp.float32)
    b = jnp.zeros((32,), jnp.float32)

    def body(x):
        return ops.layernorm(x, s, b, policy=policy, hot=True)

    from jax.sharding import PartitionSpec as P
    f = jax.jit(compat.shard_map(body, mesh=mesh, in_specs=(P(),),
                                 out_specs=P(), check_vma=False))
    np.testing.assert_allclose(np.asarray(f(x)),
                               np.asarray(ops.layernorm(x, s, b,
                                                        policy=policy)),
                               rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# >= 2 device parity (in-process under the CI multi-device run)
# ---------------------------------------------------------------------------
@multi_device
def test_forward_batched_sharded_matches_unsharded_fp32():
    from repro.models import tds
    from repro.parallel import sharding as shlib
    from repro import compat
    from jax.sharding import PartitionSpec as P

    tds_cfg, words, lex, lm, params, dec_cfg = asr_demo_system()
    mesh = jax.make_mesh((2,), ("model",))
    feats = jnp.asarray(np.random.RandomState(0).randn(3, 8, 80),
                        jnp.float32)
    st = tds.init_batched_stream_state(tds_cfg, 3)
    ref, ref_st = tds.forward_batched(params, tds_cfg, feats, st)
    pspecs = shlib.tds_param_specs(tds_cfg, mesh)
    placed = shlib.place_tree(params, pspecs, mesh)

    def body(p, f, s):
        return tds.forward_batched(p, tds_cfg, f, s, axis="model")

    f = jax.jit(compat.shard_map(body, mesh=mesh,
                                 in_specs=(pspecs, P(), P()),
                                 out_specs=(P(), P()), check_vma=False))
    got, got_st = f(placed, feats, st)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5),
        got_st, ref_st)


@multi_device
def test_sharded_engine_transcript_parity_d2():
    mesh = jax.make_mesh((2,), ("model",))
    ref, words = asr_demo_engine(2)
    shd, _ = asr_demo_engine(2, mesh=mesh)
    utts = _utts(words, 3)
    for ra, rb in zip(ref.serve(utts), shd.serve(utts)):
        assert ra["words"].tolist() == rb["words"].tolist()
        assert ra["tokens"].tolist() == rb["tokens"].tolist()
        assert abs(ra["score"] - rb["score"]) < 1e-3


@multi_device
def test_sharded_engine_prepared_int8_parity_d2():
    """The int8 program shards its PREPARED weights (wq on the feature
    axis, scales replicated): activation quantization runs on full
    rows, so the sharded step matches the unsharded int8 engine."""
    tds_cfg, words, lex, lm, params, dec_cfg = asr_demo_system()
    program = AsrProgram(tds_cfg, lex, lm, dec_cfg=dec_cfg,
                         use_int8=True).with_beam_width(25.0)
    mesh = jax.make_mesh((2,), ("model",))
    ref = AsrEngine(EngineConfig(program, n_slots=2), params)
    shd = AsrEngine(EngineConfig(program, n_slots=2, mesh=mesh), params)
    wq = shd._prepared["s0b0_fc1"]["wq"]
    assert wq.sharding.spec[0] == "model"     # weight shard, not a copy
    utts = _utts(words, 2)
    for ra, rb in zip(ref.serve(utts), shd.serve(utts)):
        assert ra["words"].tolist() == rb["words"].tolist()
        assert abs(ra["score"] - rb["score"]) < 1e-3


# ---------------------------------------------------------------------------
# 2D ('data','model') mesh: slot pool sharded on 'data'
# ---------------------------------------------------------------------------
def test_engine_config_rejects_unknown_mesh_axes():
    tds_cfg, words, lex, lm, params, dec_cfg = asr_demo_system()
    program = AsrProgram(tds_cfg, lex, lm, dec_cfg=dec_cfg)
    mesh = jax.make_mesh((1, 1), ("replica", "model"))
    with pytest.raises(ValueError, match="axes"):
        EngineConfig(program, mesh=mesh)


def test_mesh_1x1_2d_wrapper_matches_unsharded_bitwise():
    """A 1x1 ('data','model') mesh runs the ENTIRE 2D machinery on a
    1-device host — shard-aligned grouped assembly, -1 pad rows,
    axis_index slot localization, drop-mode scatter-back — and both
    axes are width 1, so it must reproduce the unsharded engine bitwise
    (scores included) with the same step schedule."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    ref, words = asr_demo_engine(2)
    shd, _ = asr_demo_engine(2, mesh=mesh)
    assert shd._data_axis == "data" and shd._n_data == 1
    utts = _utts(words, 3)
    for ra, rb in zip(ref.serve(utts), shd.serve(utts)):
        assert ra["words"].tolist() == rb["words"].tolist()
        assert ra["tokens"].tolist() == rb["tokens"].tolist()
        assert ra["score"] == rb["score"]
    assert ref.step_shapes == shd.step_shapes


@multi_device
def test_engine_config_rejects_indivisible_data_axis():
    """n_slots must split evenly over the data axis: each shard owns
    n_slots/n_data slots end-to-end."""
    tds_cfg, words, lex, lm, params, dec_cfg = asr_demo_system()
    program = AsrProgram(tds_cfg, lex, lm, dec_cfg=dec_cfg)
    mesh = jax.make_mesh((2, 1), ("data", "model"))
    with pytest.raises(ValueError, match="divide evenly"):
        EngineConfig(program, n_slots=3, mesh=mesh)


@multi_device
def test_assemble_batch_is_shard_aligned():
    """With a 2-wide data axis over 4 slots (2 slots/shard), eligible
    slots {0,1,3} must assemble into per-shard row blocks: shard 0's
    slots at rows [0, bloc), shard 1's at [bloc, 2*bloc), pad rows
    zero-filled with index -1 (dropped on scatter-back).  Assembly is
    non-destructive (a faulted step must be replayable on the surviving
    halves); `_retire` is what consumes the buffered samples."""
    mesh = jax.make_mesh((2, 1), ("data", "model"))
    engine, _ = asr_demo_engine(4, mesh=mesh)
    assert engine._slots_per_shard == 2
    for s in (0, 1, 3):
        engine.feed_slot(s, np.full((engine._need,), s + 1.0, np.float32))
        assert engine.slot_windows(s) == 1
    batch, idx = engine._assemble_batch([0, 1, 3], 1)
    # largest group (shard 0) has 2 slots -> bloc=2 -> b = 2*2
    assert batch.shape == (4, 1, engine._need)
    assert idx.tolist() == [0, 1, 3, -1]
    np.testing.assert_array_equal(batch[0], 1.0)
    np.testing.assert_array_equal(batch[1], 2.0)
    np.testing.assert_array_equal(batch[2], 4.0)      # slot 3 -> row bloc+0
    np.testing.assert_array_equal(batch[3], 0.0)      # pad row: zeros
    for s in (0, 1, 3):                               # NOT yet consumed
        assert engine.slot_windows(s) == 1
    engine._retire([0, 1, 3], 1)                      # commit consumes
    for s in (0, 1, 3):
        assert engine.slot_windows(s) == 0


@multi_device
def test_slot_buckets_are_per_shard_sizes():
    """Slot buckets bucket the LOCAL per-shard group size, so every
    global sub-batch b = bloc * n_data is a multiple of n_data."""
    mesh = jax.make_mesh((2, 1), ("data", "model"))
    engine, words = asr_demo_engine(4, mesh=mesh)
    assert engine._slot_buckets[-1] == engine._slots_per_shard
    engine.serve(_utts(words, 3))
    assert all(b % 2 == 0 for (_, b, _) in engine.step_shapes), \
        engine.step_shapes


@multi_device
def test_data_sharded_engine_transcript_parity_d2():
    """Data-only sharding (2x1) re-partitions identical per-slot compute
    across devices — transcripts AND scores stay bitwise."""
    mesh = jax.make_mesh((2, 1), ("data", "model"))
    ref, words = asr_demo_engine(4)
    shd, _ = asr_demo_engine(4, mesh=mesh)
    utts = _utts(words, 4)
    for ra, rb in zip(ref.serve(utts), shd.serve(utts)):
        assert ra["words"].tolist() == rb["words"].tolist()
        assert ra["tokens"].tolist() == rb["tokens"].tolist()


@multi_device4
def test_2d_mesh_engine_transcript_parity_d4():
    """The issue's acceptance case: a (2,2) mesh over 4 devices decodes
    bitwise-identical transcripts to mesh=None (scores shift within
    float tolerance from the model-axis psum reduction order)."""
    mesh = jax.make_mesh((2, 2), ("data", "model"))
    ref, words = asr_demo_engine(4)
    shd, _ = asr_demo_engine(4, mesh=mesh)
    utts = _utts(words, 4)
    for ra, rb in zip(ref.serve(utts), shd.serve(utts)):
        assert ra["words"].tolist() == rb["words"].tolist()
        assert ra["tokens"].tolist() == rb["tokens"].tolist()
        assert abs(ra["score"] - rb["score"]) < 1e-3


@multi_device
def test_warmed_2d_step_runs_under_strict_transfer_guard():
    """Once warmed, the data-sharded step's only host<->device traffic
    is the explicit batch/idx device_put (placed with the step's
    in_specs shardings): under no_implicit_transfers(strict=True) —
    which also disallows the device-to-device reshard-on-dispatch that
    bounces through the host on CPU — a second same-shape step must
    dispatch with zero hidden per-step round-trips."""
    from repro.analysis.guards import no_implicit_transfers

    mesh = jax.make_mesh((2, 1), ("data", "model"))
    engine, _ = asr_demo_engine(4, mesh=mesh)
    assert engine._input_shardings is not None

    def feed_all():
        for s in range(4):
            engine.feed_slot(s, np.zeros((engine._need,), np.float32))

    feed_all()
    assert engine._step()       # cold: compiles, places state + params
    feed_all()
    with no_implicit_transfers(strict=True):
        assert engine._step()   # warmed same-bucket step: no transfers
    assert engine.step_shapes[0] == engine.step_shapes[1]


@multi_device
def test_overlap_psum_matches_sync_engine():
    """The latency-hiding chunked-psum FC path must decode the same
    transcripts as the sync psum reference (chunking splits the output
    columns, so only reduction order can differ)."""
    mesh = jax.make_mesh((1, 2), ("data", "model"))
    sync, words = asr_demo_engine(2, mesh=mesh)
    ovl, _ = asr_demo_engine(2, mesh=mesh, overlap_psum=True)
    utts = _utts(words, 3)
    for ra, rb in zip(sync.serve(utts), ovl.serve(utts)):
        assert ra["words"].tolist() == rb["words"].tolist()
        assert ra["tokens"].tolist() == rb["tokens"].tolist()
        assert abs(ra["score"] - rb["score"]) < 1e-3


@multi_device
def test_psum_overlap_matmul_matches_sync():
    from repro import compat
    from repro.kernels import ops
    from jax.sharding import PartitionSpec as P

    mesh = jax.make_mesh((2,), ("model",))
    R = np.random.RandomState(0)
    x = jnp.asarray(R.randn(4, 32), jnp.float32)
    w = jnp.asarray(R.randn(32, 24), jnp.float32)

    def body(x, wloc):
        xloc = ops.shard_local_cols(x, wloc.shape[0], "model")
        sync = jax.lax.psum(xloc @ wloc, "model")
        ovl = ops.psum_overlap_matmul(xloc, wloc, "model")
        return sync, ovl

    f = jax.jit(compat.shard_map(body, mesh=mesh,
                                 in_specs=(P(), P("model", None)),
                                 out_specs=(P(), P()), check_vma=False))
    sync, ovl = f(x, w)
    np.testing.assert_allclose(np.asarray(ovl), np.asarray(sync),
                               rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# subprocess: full parity sweep on a forced 8-device host (slow suite)
# ---------------------------------------------------------------------------
SUBPROC_SHARDED = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np
    from repro.data.pipeline import SyntheticASR
    from repro.launch.serve import asr_demo_engine, serve_mesh

    ref, words = asr_demo_engine(4)
    data = SyntheticASR(words)
    utts = [data.utterance(i)["audio"] for i in range(4)]
    want = ref.serve(utts)
    for d in (2, 4, "2x2", "2x4", "4x2"):
        shd, _ = asr_demo_engine(4, mesh=serve_mesh(d))
        got = shd.serve(utts)
        for i, (a, b) in enumerate(zip(want, got)):
            assert a["words"].tolist() == b["words"].tolist(), (d, i)
            assert a["tokens"].tolist() == b["tokens"].tolist(), (d, i)
            assert abs(a["score"] - b["score"]) < 1e-3, (d, i)
    ovl, _ = asr_demo_engine(4, mesh=serve_mesh("2x2"), overlap_psum=True)
    got = ovl.serve(utts)
    for i, (a, b) in enumerate(zip(want, got)):
        assert a["words"].tolist() == b["words"].tolist(), ("ovl", i)
        assert abs(a["score"] - b["score"]) < 1e-3, ("ovl", i)
    print("SHARDED_OK")
""")


@pytest.mark.slow
def test_sharded_serve_parity_subprocess():
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", SUBPROC_SHARDED], env=env,
                       capture_output=True, text=True,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert "SHARDED_OK" in r.stdout, r.stdout + r.stderr[-3000:]
