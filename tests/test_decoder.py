"""CTC beam decoder + hypothesis unit: exact-reference and property tests."""
import collections
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.tds_asr import DecoderConfig
from repro.core import decoder, hypothesis as hyp
from repro.core import lexicon as lx

WORDS = {"ab": [1, 2], "a": [1], "cd": [3, 4], "ac": [1, 3], "b": [2]}


def _exact_reference(logp, lex, lm, cfg):
    """Unbounded-beam exact prefix search mirroring the decoder semantics."""
    def lae(a, b):
        if a == -math.inf:
            return b
        if b == -math.inf:
            return a
        m = max(a, b)
        return m + math.log(math.exp(a - m) + math.exp(b - m))

    lp = np.asarray(logp)
    ch = np.asarray(lex.children)
    ct = np.asarray(lex.child_token)
    wid = np.asarray(lex.word_id)
    init = ((), 0, lm.start_state, -1, ())
    beams = {init: [0.0, -math.inf]}
    for t in range(lp.shape[0]):
        new = collections.defaultdict(lambda: [-math.inf, -math.inf])
        for (toks, node, lms, last, ws), (pb, pnb) in beams.items():
            tot = lae(pb, pnb)
            e = new[(toks, node, lms, last, ws)]
            e[0] = lae(e[0], tot + lp[t, cfg.blank_id])
            if last >= 0:
                e[1] = lae(e[1], pnb + lp[t, last])
            for c, tok in zip(ch[node], ct[node]):
                if c < 0:
                    continue
                c, tok = int(c), int(tok)
                base = pb if tok == last else tot
                sc = base + lp[t, tok]
                e2 = new[(toks + (tok,), c, lms, tok, ws)]
                e2[1] = lae(e2[1], sc)
                w = int(wid[c])
                if w >= 0:
                    sc2 = sc + cfg.lm_weight * float(lm.table[lms, w]) \
                        + cfg.word_score
                    e3 = new[(toks + (tok,), 0, w, tok, ws + (w,))]
                    e3[1] = lae(e3[1], sc2)
        beams = dict(new)
    key, (pb, pnb) = max(beams.items(), key=lambda kv: lae(*kv[1]))
    return lae(pb, pnb), key


@pytest.mark.parametrize("seed,T", [(0, 4), (1, 6), (2, 8), (3, 5)])
def test_beam_decode_matches_exact_reference(seed, T):
    r = np.random.RandomState(seed)
    lex = lx.build_lexicon(WORDS, max_children=4)
    lm = lx.uniform_bigram(len(WORDS))
    cfg = DecoderConfig(beam_size=128, beam_threshold=1e9,
                        lm_weight=1.0, word_score=0.5)
    logp = jax.nn.log_softmax(jnp.asarray(r.randn(T, 5).astype(np.float32)))
    ref_score, ref_key = _exact_reference(logp, lex, lm, cfg)
    st_final = decoder.decode(logp, lex, lm, cfg)
    b = decoder.best(st_final)
    assert abs(float(b["score"]) - ref_score) < 1e-3
    assert tuple(np.asarray(b["words"])[:int(b["n_words"])]) == ref_key[4]
    assert tuple(np.asarray(b["tokens"])[:int(b["n_tokens"])]) == ref_key[0]


def test_lm_and_word_score_affect_ranking():
    r = np.random.RandomState(0)
    lex = lx.build_lexicon(WORDS, max_children=4)
    counts = np.zeros((len(WORDS) + 1, len(WORDS)))
    counts[-1, 0] = 100.0    # <s> strongly prefers word 0 ("ab")
    lm = lx.bigram_from_counts(counts, alpha=0.01)
    logp = jax.nn.log_softmax(jnp.asarray(r.randn(6, 5).astype(np.float32)))
    cfg_no = DecoderConfig(beam_size=64, beam_threshold=1e9, lm_weight=0.0)
    cfg_lm = DecoderConfig(beam_size=64, beam_threshold=1e9, lm_weight=8.0)
    b_no = decoder.best(decoder.decode(logp, lex, lm, cfg_no))
    b_lm = decoder.best(decoder.decode(logp, lex, lm, cfg_lm))
    # with a hard LM prior, committed words must be word 0 if any
    w = np.asarray(b_lm["words"])[:int(b_lm["n_words"])]
    assert all(x == 0 for x in w)
    assert float(b_no["score"]) != float(b_lm["score"])


def _greedy_reference(lp, blank_id):
    """Pure-Python CTC greedy: best token/frame, collapse repeats of the
    previous FRAME (blank separates repeats), drop blanks."""
    ids = np.argmax(np.asarray(lp), axis=-1)
    out, prev = [], -1
    for i in ids:
        if i != blank_id and i != prev:
            out.append(int(i))
        prev = i
    return out


@pytest.mark.parametrize("seed,T,V,blank", [(0, 12, 5, 0), (1, 40, 8, 0),
                                            (2, 7, 3, 2), (3, 100, 30, 0)])
def test_greedy_decode_matches_python_reference(seed, T, V, blank):
    r = np.random.RandomState(seed)
    lp = jax.nn.log_softmax(jnp.asarray(r.randn(T, V).astype(np.float32)))
    out = np.asarray(decoder.greedy_decode(lp, blank_id=blank))
    got = [int(t) for t in out if t >= 0]
    assert got == _greedy_reference(lp, blank)
    # -1 padding sits strictly after the emitted prefix
    assert np.all(out[len(got):] == -1)


def test_greedy_decode_all_blanks_is_empty():
    lp = jnp.log(jnp.asarray([[0.9, 0.05, 0.05]] * 6))
    out = np.asarray(decoder.greedy_decode(lp, blank_id=0))
    assert np.all(out == -1)


def test_greedy_decode_collapses():
    lp = jnp.log(jnp.asarray([
        [.9, .1, 0], [.1, .9, 0], [.05, .9, .05], [.9, .05, .05],
        [.1, .8, .1], [0, .1, .9]]) + 1e-9)
    out = np.asarray(decoder.greedy_decode(lp, blank_id=0))
    got = [t for t in out if t >= 0]
    assert got == [1, 1, 2]     # repeat collapsed, blank separates


# ---------------------------------------------------------------------------
# finalize: pending word-final commit
# ---------------------------------------------------------------------------
def _state_on_node(lex, lm, node, tokens, k=4):
    """Beam state whose hyp 0 sits on `node` having emitted `tokens`."""
    st = decoder.init_state(k, lm)
    tok_arr = st.tokens.at[0, :len(tokens)].set(jnp.asarray(tokens))
    return st._replace(
        pb=st.pb.at[0].set(-1.0), pnb=st.pnb.at[0].set(-0.5),
        node=st.node.at[0].set(node),
        last_token=st.last_token.at[0].set(tokens[-1]),
        tokens=tok_arr, n_tokens=st.n_tokens.at[0].set(len(tokens)))


def test_finalize_commits_pending_word_with_lm_score_once():
    """A hypothesis sitting on a word-final trie node gets its word and
    LM score applied by finalize exactly once (idempotent thereafter)."""
    lex = lx.build_lexicon(WORDS, max_children=4)
    lm = lx.uniform_bigram(len(WORDS))
    cfg = DecoderConfig(beam_size=4, beam_threshold=1e9,
                        lm_weight=2.0, word_score=0.75)
    # node reached by token path [1] is word-final for "a" (wid=1)
    node_a = int(np.asarray(lex.children)[lex.root,
                 list(np.asarray(lex.child_token)[lex.root]).index(1)])
    assert int(np.asarray(lex.word_id)[node_a]) == 1
    st = _state_on_node(lex, lm, node_a, [1])

    fin = decoder.finalize(st, lex, lm, cfg)
    bonus = cfg.lm_weight * float(np.asarray(lm.table)[lm.start_state, 1]) \
        + cfg.word_score
    assert abs(float(fin.pb[0]) - (-1.0 + bonus)) < 1e-5
    assert abs(float(fin.pnb[0]) - (-0.5 + bonus)) < 1e-5
    assert int(fin.n_words[0]) == 1 and int(fin.words[0, 0]) == 1
    assert int(fin.node[0]) == lex.root
    assert int(fin.lm_state[0]) == 1          # LM advanced past "a"
    # exactly once: a second finalize is a no-op (node is back at root)
    fin2 = decoder.finalize(fin, lex, lm, cfg)
    for a, b in zip(fin, fin2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_finalize_ignores_non_word_final_and_dead_hypotheses():
    lex = lx.build_lexicon(WORDS, max_children=4)
    lm = lx.uniform_bigram(len(WORDS))
    cfg = DecoderConfig(beam_size=4, beam_threshold=1e9)
    # node for token path [3] ("cd" prefix "c") is not word-final
    node_c = int(np.asarray(lex.children)[lex.root,
                 list(np.asarray(lex.child_token)[lex.root]).index(3)])
    assert int(np.asarray(lex.word_id)[node_c]) == -1
    st = _state_on_node(lex, lm, node_c, [3])
    fin = decoder.finalize(st, lex, lm, cfg)
    for a, b in zip(st, fin):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # dead (-inf) hypotheses stay dead even on a word-final node
    dead = decoder.init_state(4, lm)
    dead = dead._replace(node=dead.node.at[1].set(1))
    fdead = decoder.finalize(dead, lex, lm, cfg)
    assert float(hyp.total_score(fdead.pb, fdead.pnb)[1]) < hyp.NEG_INF / 2


# ---------------------------------------------------------------------------
# hypothesis unit properties
# ---------------------------------------------------------------------------
@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(2, 40), st.integers(1, 12),
       st.floats(0.5, 30.0))
def test_hypothesis_unit_invariants(seed, n, k, beam):
    r = np.random.RandomState(seed % (2**31 - 1))
    hashes = jnp.asarray(r.randint(0, 8, n).astype(np.int32))
    pb = jnp.asarray(r.randn(n).astype(np.float32))
    pnb = jnp.asarray(r.randn(n).astype(np.float32))
    cand = hyp.Candidates(hashes, pb, pnb,
                          {"node": jnp.arange(n, dtype=jnp.int32)})
    sel = hyp.hypothesis_unit_step(cand, k, beam)
    tot = np.asarray(hyp.total_score(sel["pb"], sel["pnb"]))
    valid = np.asarray(sel["valid"])
    # 1. scores sorted descending over valid slots
    tv = tot[valid]
    assert np.all(np.diff(tv) <= 1e-5)
    # 2. beam threshold respected
    if valid.any():
        assert np.all(tv >= tv.max() - beam - 1e-4)
    # 3. no duplicate hashes among valid
    hv = np.asarray(sel["hash"])[valid]
    assert len(set(hv.tolist())) == len(hv)
    # 4. merged mass conservation: total prob mass of each hash preserved
    ref_mass = {}
    for h, a, b in zip(np.asarray(hashes), np.asarray(pb), np.asarray(pnb)):
        ref_mass[int(h)] = np.logaddexp(ref_mass.get(int(h), -np.inf),
                                        np.logaddexp(a, b))
    for h, t in zip(hv, tv):
        assert abs(ref_mass[int(h)] - t) < 1e-3


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10**6))
def test_merge_is_score_preserving(seed):
    r = np.random.RandomState(seed)
    n = 16
    c = hyp.Candidates(
        jnp.asarray(r.randint(0, 4, n).astype(np.int32)),
        jnp.asarray(r.randn(n).astype(np.float32)),
        jnp.asarray(r.randn(n).astype(np.float32)), {})
    m = hyp.merge_duplicates(c)
    tot_before = np.logaddexp.reduce(
        np.logaddexp(np.asarray(c.pb), np.asarray(c.pnb)))
    after = np.asarray(hyp.total_score(m.pb, m.pnb))
    tot_after = np.logaddexp.reduce(after[after > hyp.NEG_INF / 2])
    assert abs(tot_before - tot_after) < 1e-4
