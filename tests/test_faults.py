"""Chaos suite for the fault-isolated serving stack (PR 9).

Drives the deterministic fault-injection harness
(`repro.serving.faults.FaultPolicy`) against the real engines and the
real network front-end: per-session quarantine (poison input isolated
by bisection, co-batched survivors bitwise identical), whole-pool
quarantine on unattributable pump failures, session deadlines on an
injected clock, worker supervision (dead + wedged threads detected via
heartbeat and restarted, `/healthz` flipping 200 -> 503 -> 200),
graceful drain under load, idle timeouts, and client-side retry.

Every injection is counter-driven (`FaultSpec.nth/count/match`), never
wall-clock-driven, so each scenario replays identically.
"""
import asyncio
import json
import time

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.pipeline import SyntheticASR
from repro.models import LM
from repro.serving import (AsrEngine, AsrProgram, DeadlineExceeded,
                           EngineConfig, EngineMetrics, FaultPolicy,
                           FaultSpec, InjectedFault, LmEngine, LmProgram,
                           SessionFaulted, WorkerKilled)
from repro.serving.server import (AsrClient, EngineServer, ServerRejected,
                                  _read_chunk, fetch_healthz,
                                  fetch_metrics)
from test_serving import FEAT16, TINY_TDS, _asr_system, _same
from test_serving_server import _as_result, _with_server


def _asr_engine(n_slots, **cfg):
    words, lex, lm, dcfg, params = _asr_system()
    program = AsrProgram(TINY_TDS, lex, lm, FEAT16, dcfg)
    engine = AsrEngine(EngineConfig(program, n_slots=n_slots, **cfg),
                       params)
    return engine, words


def _lm_engine(n_slots, **cfg):
    mcfg = get_config("mamba2-1.3b").tiny()
    params = LM(mcfg).init(jax.random.PRNGKey(0))
    program = LmProgram(mcfg, cache_len=16, max_new=4)
    return LmEngine(EngineConfig(program, n_slots=n_slots, **cfg),
                    params), program


async def _poll_until(pred, timeout=10.0, interval=0.02):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        res = await pred()
        if res:
            return res
        await asyncio.sleep(interval)
    raise AssertionError(f"condition not reached within {timeout}s")


# ---------------------------------------------------------------------------
# the injection harness itself: deterministic, replayable
# ---------------------------------------------------------------------------

def test_fault_policy_counters_are_deterministic():
    """nth/count/match arithmetic over per-site counters: two identical
    policies driven by the same check sequence produce the same firings
    and the same log — no wall clock, no RNG."""
    def build():
        return FaultPolicy([
            FaultSpec("s", nth=1, count=2, message="mid"),
            FaultSpec("t", match=lambda ctx: ctx.get("sid") == 7,
                      count=None, message="sid7"),
        ])

    def drive(policy):
        fired = []
        for i in range(5):
            try:
                policy.check("s", i=i)
                fired.append(False)
            except InjectedFault:
                fired.append(True)
        for sid in (5, 7, 7, 6):
            try:
                policy.check("t", sid=sid)
                fired.append(False)
            except InjectedFault:
                fired.append(True)
        return fired

    a, b = build(), build()
    fired = drive(a)
    # "s": skips the 0th matching check, fires the next two, disarms;
    # "t": fires on every sid==7 forever (count=None), never on others
    assert fired == [False, True, True, False, False,
                     False, True, True, False]
    assert drive(b) == fired
    assert [e["site"] for e in a.log] == ["s", "s", "t", "t"]
    assert a.log == b.log
    with pytest.raises(ValueError, match="unknown fault action"):
        FaultSpec("s", action="explode")


def test_fault_spec_match_does_not_advance_nth():
    """A non-matching check neither fires nor consumes the spec's nth
    budget — matching is a filter over the invocation stream."""
    policy = FaultPolicy([FaultSpec(
        "s", nth=1, match=lambda ctx: ctx["hot"], message="x")])
    policy.check("s", hot=False)       # ignored entirely
    policy.check("s", hot=True)        # first MATCHING check: skipped (nth=1)
    with pytest.raises(InjectedFault):
        policy.check("s", hot=True)    # second matching check: fires
    policy.check("s", hot=True)        # count=1 exhausted


# ---------------------------------------------------------------------------
# input validation: poison rejected at push, before anything is buffered
# ---------------------------------------------------------------------------

def test_asr_push_rejects_poison_before_buffering():
    engine, words = _asr_engine(1)
    audio = SyntheticASR(words).utterance(2)["audio"]
    sess = engine.open()
    with pytest.raises(ValueError, match="NaN/Inf"):
        sess.push(np.array([0.1, np.nan, 0.2], np.float32))
    with pytest.raises(ValueError, match="1-D"):
        sess.push(np.zeros((4, 4), np.float32))
    with pytest.raises(ValueError, match="max_push_samples"):
        sess.push(np.zeros((engine.program.max_push_samples + 1,),
                           np.float32))
    # nothing was buffered and the session is still healthy: a clean
    # push decodes exactly like a fresh session
    res = sess.push(audio).finish()
    ref = _asr_engine(1)[0].open().push(audio).finish()
    _same(res, ref, tol=0.0)
    assert engine.metrics.faulted_sessions == 0


def test_lm_push_rejects_poison_prompts():
    engine, program = _lm_engine(1)
    vocab = program.model_cfg.vocab_size
    sess = engine.open()
    with pytest.raises(ValueError, match="integer token ids"):
        sess.push(np.array([1.5, 2.5]))
    with pytest.raises(ValueError, match="1-D"):
        sess.push(np.array([[1, 2]], np.int32))
    with pytest.raises(ValueError, match=r"in \[0,"):
        sess.push(np.array([1, vocab + 3], np.int32))
    with pytest.raises(ValueError, match="cache_len"):
        sess.push(np.arange(1, 40, dtype=np.int32))
    out = sess.push(np.array([1, 2, 3], np.int32)).poll()
    assert out["done"] and len(out["tokens"]) == program.max_new


# ---------------------------------------------------------------------------
# per-session quarantine: bisection pins the poison slot
# ---------------------------------------------------------------------------

def test_poison_session_in_full_pool_quarantined_survivors_bitwise():
    """The tentpole acceptance scenario: 8 co-batched sessions, one
    poisoned (every fused step containing its sid faults).  Bisection
    retry pins the fault to that one session; the other 7 finish with
    results BITWISE identical to a fault-free engine's."""
    poison_sid = 3
    policy = FaultPolicy([FaultSpec(
        "asr_step", count=None,
        match=lambda ctx: poison_sid in ctx.get("sids", ()),
        message="poison slot")])
    engine, words = _asr_engine(8, faults=policy)
    data = SyntheticASR(words)
    utts = [data.utterance(i % 4)["audio"] for i in range(8)]

    sessions = [engine.open() for _ in utts]
    for sess, audio in zip(sessions, utts):
        sess.push(audio)
    for sess in sessions:
        sess.finish(wait=False)        # end-of-input without driving yet
    with pytest.raises(SessionFaulted, match="decoding step failed"):
        sessions[poison_sid].finish()

    # fault-free reference: the SAME push-all/finish-all flow (serve()
    # staggers admissions, which legally reorders step buckets — the
    # bitwise claim is about identical schedules, fault vs no fault)
    ref_engine, _ = _asr_engine(8)
    ref_sessions = [ref_engine.open() for _ in utts]
    for sess, audio in zip(ref_sessions, utts):
        sess.push(audio)
    for sess in ref_sessions:
        sess.finish(wait=False)
    refs = [sess.finish() for sess in ref_sessions]
    for i, sess in enumerate(sessions):
        if i == poison_sid:
            assert sess.faulted
            with pytest.raises(SessionFaulted):
                sess.poll()
            continue
        res = sess.finish()
        _same(res, refs[i], tol=0.0)   # bitwise: same trajectory
        assert res["steps"] == refs[i]["steps"]

    # bisection narrowed every firing batch down to the lone poison sid
    assert len(policy.log) >= 2        # at least one split happened
    assert all(poison_sid in e["ctx"]["sids"] for e in policy.log)
    assert tuple(policy.log[-1]["ctx"]["sids"]) == (poison_sid,)
    assert engine.metrics.faulted_sessions == 1
    assert engine._fault_log[0]["sid"] == poison_sid
    # the freed slot is reusable after quarantine (solo decode: the
    # step-bucket schedule legally differs from the co-batched refs,
    # so default tolerance, not bitwise)
    late = engine.open().push(utts[0]).finish()
    _same(late, refs[0])


def test_slot_level_api_has_no_session_to_evict():
    """The deprecated slot-level API (feed_slot/pump) has no session to
    attribute a singleton fault to: the raise propagates."""
    policy = FaultPolicy([FaultSpec("asr_step", message="boom")])
    engine, words = _asr_engine(1, faults=policy)
    engine.feed_slot(0, SyntheticASR(words).utterance(0)["audio"])
    with pytest.raises(InjectedFault, match="boom"):
        engine.pump()


def test_worker_killed_escapes_session_quarantine():
    """`WorkerKilled` is a BaseException by design: the per-session and
    per-pump quarantine (`except Exception`) must NOT contain it — it
    models thread death only the supervisor may handle."""
    policy = FaultPolicy([FaultSpec("asr_step", action="die")])
    engine, words = _asr_engine(1, faults=policy)
    sess = engine.open().push(SyntheticASR(words).utterance(0)["audio"])
    with pytest.raises(WorkerKilled):
        sess.finish()


def test_lm_prefill_poison_isolated_from_cobatched_prompt():
    """Two prompts admitted in ONE bucketed prefill batch, one poisoned:
    bisection evicts only it; the co-batched prompt generates exactly
    the clean reference tokens."""
    poison_sid = 2
    policy = FaultPolicy([FaultSpec(
        "lm_prefill", count=None,
        match=lambda ctx: poison_sid in ctx.get("sids", ()))])
    engine, program = _lm_engine(2, faults=policy)
    p2, p3 = (np.array([1, 2, 3], np.int32),
              np.array([4, 5, 6, 7], np.int32))

    # occupy both slots so the next two prompts queue and are admitted
    # together (one bucket group) when the blockers drain
    blockers = [engine.open().push(np.array([9, 8], np.int32))
                for _ in range(2)]
    s2 = engine.open()
    s3 = engine.open()
    s2.push(p2)                        # queued: no free slot yet
    s3.push(p3)
    for b in blockers:
        assert b.poll()["done"]        # drains -> batched admit of s2+s3

    with pytest.raises(SessionFaulted, match="prefill failed"):
        s2.poll()
    out = s3.poll()
    assert out["done"]
    ref_engine, _ = _lm_engine(1)
    assert out["tokens"] == ref_engine.serve([p3])[0]
    assert engine.metrics.faulted_sessions == 1
    # the bisected group: pair -> each singleton -> only sid 2 evicted
    assert [sorted(e["ctx"]["sids"]) for e in policy.log] == [[2, 3], [2]]


# ---------------------------------------------------------------------------
# whole-pool quarantine: unattributable pump failure
# ---------------------------------------------------------------------------

def test_unattributable_pump_failure_quarantines_pool_and_recovers():
    engine, words = _asr_engine(2)
    audio = SyntheticASR(words).utterance(1)["audio"]
    s_active = engine.open().push(audio)

    orig = engine._harvest
    state = {"armed": False}

    def corrupt_harvest():
        if state["armed"]:
            state["armed"] = False
            raise RuntimeError("synthetic pool corruption")
        return orig()

    engine._harvest = corrupt_harvest
    state["armed"] = True
    with pytest.raises(SessionFaulted, match="pool quarantined"):
        s_active.poll()
    assert s_active.faulted
    assert s_active.fault.__cause__.args == ("synthetic pool corruption",)
    assert engine.metrics.faulted_sessions == 1
    assert engine.n_steps == 0         # pool rebuilt from scratch

    # the rebuilt pool serves new sessions exactly like a fresh engine
    res = engine.open().push(audio).finish()
    ref = _asr_engine(1)[0].open().push(audio).finish()
    _same(res, ref, tol=0.0)


# ---------------------------------------------------------------------------
# deadlines on the injected metrics clock
# ---------------------------------------------------------------------------

def test_session_deadline_reaps_active_and_queued():
    engine, words = _asr_engine(1, session_deadline=10.0)
    clk = [100.0]
    engine.metrics = EngineMetrics(clock=lambda: clk[0])
    audio = SyntheticASR(words).utterance(0)["audio"]

    active = engine.open().push(audio[:2000])
    queued = engine.open()             # 1 slot: waits in the queue
    clk[0] += 11.0
    with pytest.raises(DeadlineExceeded, match="session_deadline"):
        active.poll()
    with pytest.raises(DeadlineExceeded):
        queued.poll()
    assert engine.metrics.deadline_evictions == 2
    snap = engine.metrics.snapshot()["sessions"]
    assert snap["deadline_evicted"] == 2 and snap["faulted"] == 0

    # slot + queue entry were reclaimed; a fresh session fits the
    # deadline and decodes normally
    res = engine.open().push(audio).finish()
    ref = _asr_engine(1)[0].open().push(audio).finish()
    _same(res, ref, tol=0.0)


# ---------------------------------------------------------------------------
# worker supervision over the wire: dead + wedged threads
# ---------------------------------------------------------------------------

async def _suspend_supervisor(server):
    """Deterministic 503-window observation: park the supervisor so a
    dead/wedged worker stays unrestarted exactly until the test resumes
    supervision."""
    server._supervisor.cancel()
    try:
        await server._supervisor
    except asyncio.CancelledError:
        pass


def _resume_supervisor(server):
    server._supervisor = asyncio.get_running_loop().create_task(
        server._supervise())


async def _healthz_ok(server):
    status, payload = await fetch_healthz(server.host, server.port)
    return (status, payload) if status == 200 else None


def test_server_dead_worker_healthz_flips_and_restart_serves():
    """Kill the engine worker mid-service: /healthz flips 200 -> 503
    (dead, pre-restart) -> 200 (supervisor restarted it), the in-flight
    session resolves with a typed error instead of hanging, and the
    restarted worker completes new sessions."""
    arm = {"on": False}
    policy = FaultPolicy([FaultSpec(
        "pump", action="die", count=1,
        match=lambda ctx: arm["on"], message="killed by test")])
    engine, words = _asr_engine(1, faults=policy)
    audio = SyntheticASR(words).utterance(1)["audio"]

    async def go(server):
        status, payload = await fetch_healthz(server.host, server.port)
        assert status == 200 and payload["ok"]

        inflight = await AsrClient.open(server.host, server.port)
        assert (await inflight.push(audio[:4000]))["ok"]

        await _suspend_supervisor(server)
        arm["on"] = True               # next pump iteration dies
        await _poll_until(
            lambda: asyncio.sleep(0, not server._asr_worker.is_alive()))
        arm["on"] = False
        status, payload = await fetch_healthz(server.host, server.port)
        assert status == 503
        assert not payload["engines"]["asr"]["alive"]

        # the in-flight session must observe a typed failure, not hang
        res = await inflight.push(audio[4000:8000])
        assert "error" in res
        await inflight.aclose()

        _resume_supervisor(server)
        status, payload = await _poll_until(
            lambda: _healthz_ok(server), timeout=15.0)
        assert payload["engines"]["asr"]["restarts"] == 1
        assert server._asr_worker.name == "asr-worker-r1"

        fresh = await AsrClient.open(server.host, server.port)
        await fresh.push(audio)
        final = await fresh.finish()
        metrics = await fetch_metrics(server.host, server.port)
        return final, metrics

    final, metrics = asyncio.run(_with_server(
        EngineServer(asr_engine=engine, watch_interval=0.05), go))
    ref = _asr_engine(1)[0].open().push(audio).finish()
    _same(_as_result(final), ref)     # wire pump schedule vs in-process
    assert metrics["asr"]["workers"]["restarts"] == 1
    assert metrics["asr"]["sessions"]["faulted"] >= 1   # the in-flight one


def test_server_wedged_worker_watchdog_restart():
    """A stalled (not dead) worker thread: heartbeat stops aging the
    watchdog out, /healthz reports alive-but-unhealthy 503, the
    supervisor restarts, and the released zombie thread is fenced off
    the pool by the ownership reclaim."""
    arm = {"on": False}
    policy = FaultPolicy(
        [FaultSpec("pump", action="stall", count=1,
                   match=lambda ctx: arm["on"])],
        stall_timeout=30.0)
    engine, words = _asr_engine(1, faults=policy,
                                worker_watchdog=0.4)
    audio = SyntheticASR(words).utterance(2)["audio"]

    async def go(server):
        old = server._asr_worker
        await _suspend_supervisor(server)
        # warm every jit step bucket through the server first: the
        # tight 0.4s watchdog must measure a wedged pump, not a
        # first-use compile, once supervision resumes after the restart
        warm = await AsrClient.open(server.host, server.port)
        await warm.push(audio)
        warm_res = await warm.finish()
        assert not warm_res.get("error"), warm_res
        arm["on"] = True               # next pump iteration blocks
        await _poll_until(lambda: asyncio.sleep(
            0, old.heartbeat_age() > 0.4))
        arm["on"] = False
        status, payload = await fetch_healthz(server.host, server.port)
        eng_h = payload["engines"]["asr"]
        assert status == 503           # wedged: alive but unhealthy
        assert eng_h["alive"] and not eng_h["healthy"]

        _resume_supervisor(server)
        await _poll_until(lambda: asyncio.sleep(
            0, server._asr_worker is not old))
        policy.release()               # wake the zombie: worker_only fences it

        status, payload = await _poll_until(
            lambda: _healthz_ok(server), timeout=15.0)
        assert payload["engines"]["asr"]["restarts"] >= 1

        fresh = await AsrClient.open(server.host, server.port)
        await fresh.push(audio)
        return await fresh.finish()

    final = asyncio.run(_with_server(
        EngineServer(asr_engine=engine, watch_interval=0.1), go))
    ref = _asr_engine(1)[0].open().push(audio).finish()
    _same(_as_result(final), ref)     # wire pump schedule vs in-process
    assert engine.metrics.worker_restarts >= 1


def test_server_poison_session_errors_in_stream_others_unaffected():
    """Over the wire: the poisoned session's command gets an in-stream
    `faulted` error chunk, the co-batched session completes with the
    clean reference transcript, the worker thread survives (quarantine,
    not crash), and /healthz stays 200."""
    poison_sid = 0
    policy = FaultPolicy([FaultSpec(
        "asr_step", count=None,
        match=lambda ctx: poison_sid in ctx.get("sids", ()))])
    engine, words = _asr_engine(2, faults=policy)
    data = SyntheticASR(words)
    bad_audio = data.utterance(0)["audio"]
    good_audio = data.utterance(3)["audio"]

    async def go(server):
        bad = await AsrClient.open(server.host, server.port)
        good = await AsrClient.open(server.host, server.port)
        await bad.push(bad_audio)
        await good.push(good_audio)
        # drive until the quarantine lands: the bad session's poll (or
        # finish) comes back as a faulted error chunk
        res = await bad.finish()
        assert res.get("faulted") and "faulted" in res["error"]
        final = await good.finish()
        status, _ = await fetch_healthz(server.host, server.port)
        assert status == 200           # worker survived the poison
        assert server._asr_worker.is_alive()
        metrics = await fetch_metrics(server.host, server.port)
        return final, metrics

    final, metrics = asyncio.run(_with_server(
        EngineServer(asr_engine=engine), go))
    ref = _asr_engine(1)[0].open().push(good_audio).finish()
    _same(_as_result(final), ref)     # co-batched wire vs solo in-process
    assert metrics["asr"]["sessions"]["faulted"] == 1
    assert metrics["asr"]["workers"]["restarts"] == 0


# ---------------------------------------------------------------------------
# graceful drain, idle timeout, client retry
# ---------------------------------------------------------------------------

def test_server_drain_under_load_returns_every_result():
    """aclose(drain=True) while sessions are mid-stream: every active
    session still gets its final transcript (no result loss), and the
    listener refuses new connections."""
    engine, words = _asr_engine(2)
    data = SyntheticASR(words)
    utts = [data.utterance(i)["audio"] for i in range(4)]

    async def stream(server, audio, started: asyncio.Event):
        client = await AsrClient.open(server.host, server.port)
        chunks = [audio[off:off + 4000]
                  for off in range(0, len(audio), 4000)]
        await client.push(chunks[0])
        started.set()
        for chunk in chunks[1:]:
            await client.push(chunk)
            await asyncio.sleep(0.01)  # keep the stream mid-flight
        return await client.finish()

    async def go(server):
        started = [asyncio.Event() for _ in utts]
        tasks = [asyncio.create_task(stream(server, a, ev))
                 for a, ev in zip(utts, started)]
        for ev in started:
            await ev.wait()            # every session is open + pushing
        await server.aclose(drain=True, timeout=60.0)
        finals = await asyncio.gather(*tasks)
        with pytest.raises((ConnectionError, OSError)):
            await AsrClient.open(server.host, server.port)
        return finals

    async def run():
        server = EngineServer(asr_engine=engine)
        await server.start()
        try:
            return await go(server)
        finally:
            await server.aclose()      # idempotent cleanup
    finals = asyncio.run(run())

    ref_engine, _ = _asr_engine(1)
    for audio, final in zip(utts, finals):
        ref = ref_engine.open().push(audio).finish()
        # default tolerance: concurrent co-batched streams legally run
        # a different step-bucket schedule than the solo reference —
        # the drain claim is "no result lost", not bitwise parity
        _same(_as_result(final), ref)
    assert engine.metrics.finalized == len(utts)


def test_server_idle_timeout_frees_slot():
    """A silent client gets an in-stream idle-timeout error and its slot
    back in the pool; the next session decodes normally."""
    engine, words = _asr_engine(1)
    audio = SyntheticASR(words).utterance(1)["audio"]

    async def go(server):
        quiet = await AsrClient.open(server.host, server.port)
        await quiet.push(audio[:4000])
        await asyncio.sleep(0.8)       # exceed the 0.25 s idle timeout
        # the server already wrote the in-stream timeout error and
        # terminated the response: read it without sending anything
        res = json.loads(await _read_chunk(quiet._reader))
        assert "idle timeout" in res.get("error", "")
        await quiet.aclose()

        fresh = await AsrClient.open(server.host, server.port)
        await fresh.push(audio)
        return await fresh.finish()

    final = asyncio.run(_with_server(
        EngineServer(asr_engine=engine, asr_idle_timeout=0.25), go))
    ref = _asr_engine(1)[0].open().push(audio).finish()
    _same(_as_result(final), ref)     # wire pump schedule vs in-process


def test_client_retry_rides_out_backpressure():
    """With retries armed, a 503 backpressure rejection is retried with
    jittered backoff until the busy slot frees — the caller sees a
    session, not a ServerRejected."""
    engine, words = _asr_engine(1, max_queue=0)
    audio = SyntheticASR(words).utterance(0)["audio"]

    async def go(server):
        first = await AsrClient.open(server.host, server.port)
        await first.push(audio)
        with pytest.raises(ServerRejected):
            await AsrClient.open(server.host, server.port)   # no retries

        retry_task = asyncio.create_task(AsrClient.open(
            server.host, server.port, retries=40, backoff=0.02, seed=7))
        await asyncio.sleep(0.1)
        assert not retry_task.done()   # still backing off against 503
        r1 = await first.finish()      # frees the slot
        second = await retry_task
        await second.push(audio)
        r2 = await second.finish()
        metrics = await fetch_metrics(server.host, server.port)
        return r1, r2, metrics

    r1, r2, metrics = asyncio.run(_with_server(
        EngineServer(asr_engine=engine), go))
    _same(_as_result(r1), _as_result(r2))
    assert metrics["asr"]["sessions"]["rejected"] >= 2
    assert metrics["asr"]["sessions"]["finalized"] == 2
