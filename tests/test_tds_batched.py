"""Slot-native TDS acoustic scoring: batched-forward parity, prepared
int8 weights, and the Pallas conv/LN kernel routing (interpret vs ref)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.tds_asr import TDSConfig, TDSStage
from repro.core import features
from repro.kernels.policy import KernelPolicy
from repro.models import tds

TINY_TDS = TDSConfig(
    stages=(TDSStage(1, 3, 16, 5, 2), TDSStage(1, 4, 16, 5, 2),
            TDSStage(1, 4, 16, 5, 2)),
    sub_kernel=6, vocab_size=20)


def _warm_state(params, B, seed=2):
    """Batched stream state with NONZERO per-slot left context (each slot
    advanced through a different prior chunk)."""
    st = tds.init_batched_stream_state(TINY_TDS, B)
    warm = jax.random.normal(jax.random.PRNGKey(seed), (B, 8, 16))
    _, st = tds.forward_batched(params, TINY_TDS, warm, st)
    return st


def test_forward_batched_bitexact_vs_per_slot_forward():
    """The natively batched forward IS the per-slot forward, bit for bit:
    every slot of forward_batched equals a dedicated single-stream
    `tds.forward` call on that slot's feats + carried state (the old
    serving path vmapped exactly that per-slot function)."""
    params = tds.init_tds(jax.random.PRNGKey(0), TINY_TDS)
    B = 3
    feats = jax.random.normal(jax.random.PRNGKey(1), (B, 16, 16))
    st = _warm_state(params, B)
    logp_b, ns_b = tds.forward_batched(params, TINY_TDS, feats, st)
    for i in range(B):
        st_i = jax.tree.map(lambda a, i=i: a[i], st)
        logp_i, ns_i = tds.forward(params, TINY_TDS, feats[i], st_i)
        np.testing.assert_array_equal(np.asarray(logp_b[i]),
                                      np.asarray(logp_i))
        jax.tree.map(lambda a, b, i=i: np.testing.assert_array_equal(
            np.asarray(a[i]), np.asarray(b)), ns_b, ns_i)


def test_forward_batched_matches_vmap_forward():
    """forward_batched == jax.vmap(forward) — the literal pre-refactor
    per-slot vmap of the acoustic function."""
    params = tds.init_tds(jax.random.PRNGKey(0), TINY_TDS)
    B = 2
    feats = jax.random.normal(jax.random.PRNGKey(3), (B, 8, 16))
    st = _warm_state(params, B, seed=4)
    logp_b, _ = tds.forward_batched(params, TINY_TDS, feats, st)
    logp_v, _ = jax.vmap(
        lambda f, s: tds.forward(params, TINY_TDS, f, s))(feats, st)
    np.testing.assert_allclose(np.asarray(logp_b), np.asarray(logp_v),
                               rtol=1e-5, atol=1e-5)


def test_forward_batched_compilation_budget(compile_budget):
    """One jit entry serves repeated batched forwards: the first call
    compiles (the counter must see it), then fresh same-shape inputs
    run under a ZERO compile budget — any retrace means the batched
    forward bakes a data-dependent shape into its trace."""
    from repro.analysis.guards import count_compilations
    params = tds.init_tds(jax.random.PRNGKey(0), TINY_TDS)
    B = 2
    st = _warm_state(params, B, seed=5)
    step = jax.jit(lambda f, s: tds.forward_batched(params, TINY_TDS, f, s))
    feats = jax.random.normal(jax.random.PRNGKey(6), (B, 8, 16))
    with count_compilations() as warm:
        logp, st2 = step(feats, st)
        jax.block_until_ready(logp)
    assert warm.count >= 1, "counter missed the warmup compile"
    feats2 = jax.random.normal(jax.random.PRNGKey(7), (B, 8, 16))
    with compile_budget(0, "warmed tds.forward_batched"):
        logp2, _ = step(feats2, st2)
        jax.block_until_ready(logp2)
    assert logp2.shape == logp.shape


def test_prepared_int8_bitexact_vs_on_the_fly():
    """Pre-quantized weights (quantize_params + int8_matmul_prepared)
    produce exactly the per-call use_int8 path's output — preparation
    moves the weight quantization, it does not change it."""
    params = tds.init_tds(jax.random.PRNGKey(0), TINY_TDS)
    feats = jax.random.normal(jax.random.PRNGKey(1), (8, 16))
    prepared = tds.quantize_params(params, TINY_TDS)
    fc_specs = [s for s in tds.build_kernel_specs(TINY_TDS)
                if s.kind in ("fc", "head")]
    assert sorted(prepared) == sorted(s.name for s in fc_specs)
    a, _ = tds.forward(params, TINY_TDS, feats, use_int8=True)
    b, _ = tds.forward(params, TINY_TDS, feats, use_int8=True,
                       prepared=prepared)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_forward_interpret_kernels_match_ref():
    """The full kernel-backed forward (Pallas conv with fused
    bias+ReLU+residual epilogue, Pallas LayerNorm, under the
    interpreter) matches the pure-jnp ref dispatch on shapes that are
    no multiple of any kernel tile."""
    params = tds.init_tds(jax.random.PRNGKey(0), TINY_TDS)
    B = 2
    feats = jax.random.normal(jax.random.PRNGKey(5), (B, 24, 16))
    st = _warm_state(params, B, seed=6)
    ref, _ = tds.forward_batched(params, TINY_TDS, feats, st,
                                 kernels=KernelPolicy("ref"))
    itp, _ = tds.forward_batched(params, TINY_TDS, feats, st,
                                 kernels=KernelPolicy("interpret"))
    np.testing.assert_allclose(np.asarray(itp), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_streaming_equals_offline_through_batched_forward():
    """The PR 0 property — chunked streaming == offline, bit for bit up
    to float tolerance — must survive the batched/kernel-backed rewrite
    at B > 1 with per-slot carried context."""
    params = tds.init_tds(jax.random.PRNGKey(0), TINY_TDS)
    B, T = 2, 32
    feats = jax.random.normal(jax.random.PRNGKey(7), (B, T, 16))
    full, _ = tds.forward_batched(params, TINY_TDS, feats,
                                  tds.init_batched_stream_state(TINY_TDS, B))
    state = tds.init_batched_stream_state(TINY_TDS, B)
    outs = []
    for i in range(0, T, 8):
        o, state = tds.forward_batched(params, TINY_TDS,
                                       feats[:, i:i + 8], state)
        outs.append(o)
    np.testing.assert_allclose(np.asarray(full),
                               np.asarray(jnp.concatenate(outs, axis=1)),
                               rtol=1e-4, atol=1e-4)


def test_mfcc_batched_matches_per_row():
    """features.mfcc folds leading batch axes; each row equals the 1-D
    call (the engine feeds every slot's window in one batched call)."""
    sig = jnp.asarray(np.random.RandomState(0).randn(3, 4000)
                      .astype(np.float32))
    batched = features.mfcc(sig)
    for i in range(3):
        np.testing.assert_array_equal(np.asarray(batched[i]),
                                      np.asarray(features.mfcc(sig[i])))
    fused = features.mfcc(sig, use_pallas=True, hot=True)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(batched),
                               rtol=1e-4, atol=1e-4)
