"""Deterministic fallback for the `hypothesis` package.

The property tests in this suite use a small slice of hypothesis:
``@settings(...) @given(st.integers/floats/sampled_from)``.  When the real
package is installed (see requirements-dev.txt) it is used untouched; when
it is missing, `install()` registers this shim as the ``hypothesis``
module so the suite still *collects and runs*: each ``@given`` test is
executed over a fixed number of deterministic examples (boundary values
first, then seeded draws) instead of being skipped.

This is NOT a hypothesis reimplementation — no shrinking, no database,
no `assume` filtering beyond skip-the-example — just enough to keep the
tier-1 suite green on a bare interpreter.
"""
from __future__ import annotations

import random
import sys
import types
import zlib

_MAX_EXAMPLES = 10       # cap: fast deterministic sweep, not a fuzz run


class _Example(Exception):
    """Raised by assume(False): abandon the current example."""


class _Strategy:
    def __init__(self, draw, boundary=()):
        self._draw = draw
        self.boundary = tuple(boundary)

    def examples(self, rng, n):
        out = list(self.boundary[:n])
        while len(out) < n:
            out.append(self._draw(rng))
        return out


def integers(min_value, max_value):
    return _Strategy(lambda r: r.randint(min_value, max_value),
                     boundary=(min_value, max_value))


def floats(min_value, max_value, **_kw):
    return _Strategy(lambda r: r.uniform(min_value, max_value),
                     boundary=(min_value, max_value))


def booleans():
    return _Strategy(lambda r: bool(r.getrandbits(1)), boundary=(False, True))


def sampled_from(elements):
    elements = list(elements)
    return _Strategy(lambda r: r.choice(elements), boundary=elements)


def just(value):
    return _Strategy(lambda r: value, boundary=(value,))


def lists(elements, min_size=0, max_size=8):
    def draw(r):
        n = r.randint(min_size, max_size)
        return [elements._draw(r) for _ in range(n)]
    return _Strategy(draw)


def assume(condition):
    if not condition:
        raise _Example()
    return True


def given(*strategies):
    def deco(fn):
        def wrapper():
            n = min(getattr(wrapper, "_shim_max_examples", _MAX_EXAMPLES),
                    _MAX_EXAMPLES)
            rng = random.Random(zlib.adler32(fn.__qualname__.encode()))
            cols = [s.examples(rng, n) for s in strategies]
            for ex in zip(*cols):
                try:
                    fn(*ex)
                except _Example:
                    continue
        # NOTE: no functools.wraps — pytest would follow __wrapped__ and
        # treat the strategy parameters as fixtures.
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper
    return deco


def settings(**kw):
    def deco(fn):
        fn._shim_max_examples = kw.get("max_examples", _MAX_EXAMPLES)
        return fn
    return deco


settings.register_profile = lambda *a, **k: None
settings.load_profile = lambda *a, **k: None


def install():
    """Register the shim as `hypothesis` if the real package is absent."""
    if "hypothesis" in sys.modules:
        return
    try:
        import hypothesis                        # noqa: F401  (real package)
        return
    except ImportError:
        pass
    mod = types.ModuleType("hypothesis")
    st_mod = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "booleans", "sampled_from", "just",
                 "lists"):
        setattr(st_mod, name, globals()[name])
    mod.given = given
    mod.settings = settings
    mod.assume = assume
    mod.strategies = st_mod
    mod.HealthCheck = types.SimpleNamespace(all=lambda: [])
    mod.__is_repro_shim__ = True
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st_mod
