"""Paper-system tests: features, TDS, scheduler, streaming (paper §2-§4)."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.configs.tds_asr import (ASRPU_HW, FEATURE_CONFIG, TDS_CONFIG,
                                   DecoderConfig, FeatureConfig, TDSConfig,
                                   TDSStage)
from repro.core import features, lexicon as lx
from repro.core.scheduler import ASRPU, make_step_plan
from repro.models import tds

TINY_TDS = TDSConfig(
    stages=(TDSStage(1, 3, 16, 5, 2), TDSStage(1, 4, 16, 5, 2),
            TDSStage(1, 4, 16, 5, 2)),
    sub_kernel=6, vocab_size=20)


# ---------------------------------------------------------------------------
# features
# ---------------------------------------------------------------------------
def test_mfcc_shapes_and_finite():
    sig = jnp.asarray(np.random.RandomState(0).randn(16000).astype(np.float32))
    out = features.mfcc(sig)
    assert out.shape == (features.frames_producible(16000, FEATURE_CONFIG),
                         FEATURE_CONFIG.n_mfcc)
    assert np.isfinite(np.asarray(out)).all()


@settings(max_examples=50, deadline=None)
@given(st.integers(0, 20000))
def test_frames_producible_setup_arithmetic(n):
    """The setup-thread property: frames fit exactly in the signal."""
    cfg = FEATURE_CONFIG
    f = features.frames_producible(n, cfg)
    if f > 0:
        assert (f - 1) * cfg.frame_shift + cfg.frame_len <= n
        assert f * cfg.frame_shift + cfg.frame_len > n
    else:
        assert n < cfg.frame_len


def test_mel_filterbank_covers_band():
    fb = features.mel_filterbank(FEATURE_CONFIG)
    assert fb.shape == (257, 80)
    assert (fb.sum(axis=1) >= 0).all()
    assert fb.max() <= 1.0 + 1e-6


def test_mfcc_pallas_path_matches():
    sig = jnp.asarray(np.random.RandomState(1).randn(4000).astype(np.float32))
    a = features.mfcc(sig, use_pallas=False)
    b = features.mfcc(sig, use_pallas=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                               atol=1e-4)


# ---------------------------------------------------------------------------
# TDS
# ---------------------------------------------------------------------------
def test_kernel_census_matches_paper():
    """Paper §4.2: 79 kernels = 18 CONV + 29 FC + 32 LayerNorm."""
    c = tds.kernel_census(TDS_CONFIG)
    assert c == {"conv": 18, "fc": 29, "layernorm": 32}
    assert sum(c.values()) == 79


def test_interstep_state_near_paper_claim():
    """Paper §5.2: ~275KB of intermediate data between decoding steps."""
    b = tds.state_bytes(TDS_CONFIG, bytes_per_el=1)
    assert 150_000 < b < 400_000, b


def test_fc_partitioning_under_model_memory():
    """Paper §5.2: FC layers partition into <=1MB model-memory kernels."""
    for spec in tds.build_kernel_specs(TDS_CONFIG):
        if spec.kind in ("fc", "head"):
            per = spec.weight_bytes / spec.n_subkernels
            assert per <= ASRPU_HW.model_mem_bytes
    head = [s for s in tds.build_kernel_specs(TDS_CONFIG)
            if s.name == "head"][0]
    assert head.n_subkernels > 1          # 1840x9000 must be partitioned


def test_tds_streaming_equals_offline():
    params = tds.init_tds(jax.random.PRNGKey(0), TINY_TDS)
    T = 32
    feats = jax.random.normal(jax.random.PRNGKey(1), (T, 16))
    full, _ = tds.forward(params, TINY_TDS, feats)
    state = tds.init_stream_state(TINY_TDS)
    outs = []
    for i in range(0, T, 8):
        o, state = tds.forward(params, TINY_TDS, feats[i:i + 8], state)
        outs.append(o)
    np.testing.assert_allclose(np.asarray(full),
                               np.asarray(jnp.concatenate(outs)),
                               rtol=1e-4, atol=1e-4)


def test_tds_int8_path_close_to_fp32():
    params = tds.init_tds(jax.random.PRNGKey(0), TINY_TDS)
    feats = jax.random.normal(jax.random.PRNGKey(1), (8, 16))
    a, _ = tds.forward(params, TINY_TDS, feats, use_int8=False)
    b, _ = tds.forward(params, TINY_TDS, feats, use_int8=True)
    # log-softmax outputs; int8 quantization noise stays bounded
    assert np.abs(np.asarray(a) - np.asarray(b)).max() < 0.5


# ---------------------------------------------------------------------------
# scheduler / plan
# ---------------------------------------------------------------------------
def test_step_plan_fig6_structure():
    plan = make_step_plan(TDS_CONFIG, FEATURE_CONFIG, step_ms=80.0)
    assert plan.samples_per_step == 1280
    assert plan.feat_frames_per_step == 8
    assert plan.acoustic_frames_per_step == 1     # 8x subsample
    # kernel sequence = mfcc + 79 TDS kernels
    assert len(plan.kernels) == 80
    # head kernel: one thread per neuron (paper: "9000 threads")
    head = plan.kernels[-1]
    assert head.n_threads == 9000


def test_asrpu_end_to_end_streaming():
    """Full command flow: configure -> DecodingStep* -> CleanDecoding."""
    words = {"ab": [1, 2], "cd": [3, 4], "e": [5]}
    lex = lx.build_lexicon(words, max_children=8)
    lm = lx.uniform_bigram(len(words))
    params = tds.init_tds(jax.random.PRNGKey(0), TINY_TDS)

    asrpu = ASRPU()
    feat_cfg = FeatureConfig(n_mels=16, n_mfcc=16)
    asrpu.configure_acoustic_scoring(TINY_TDS, params, feat_cfg)
    asrpu.configure_hyp_expansion(lex, lm, DecoderConfig(
        beam_size=16, beam_threshold=30.0))
    asrpu.configure_beam_width(20.0)

    rng = np.random.RandomState(0)
    audio = rng.randn(16000).astype(np.float32)   # 1s
    # stream in 40ms chunks: decoding steps trigger once 80ms accumulate
    for off in range(0, 16000, 640):
        best = asrpu.decoding_step(audio[off:off + 640])
    assert asrpu._n_steps >= 11                   # ~12 steps of 80ms
    assert np.isfinite(best["score"])
    n1 = asrpu._n_steps
    # CleanDecoding resets
    asrpu.clean_decoding()
    assert asrpu._n_steps == 0
    assert asrpu.best()["score"] == -np.inf
    # second utterance decodes from scratch
    asrpu.decoding_step(audio[:3200])
    assert asrpu._n_steps == 2


def test_setup_thread_zero_returns_stops_step():
    """Insufficient samples => no decoding step runs (setup returns 0)."""
    words = {"ab": [1, 2]}
    lex = lx.build_lexicon(words, max_children=4)
    lm = lx.uniform_bigram(1)
    params = tds.init_tds(jax.random.PRNGKey(0), TINY_TDS)
    asrpu = ASRPU()
    asrpu.configure_acoustic_scoring(TINY_TDS, params,
                                     FeatureConfig(n_mels=16, n_mfcc=16))
    asrpu.configure_hyp_expansion(lex, lm, DecoderConfig(beam_size=8))
    asrpu.decoding_step(np.zeros(100, np.float32))
    assert asrpu._n_steps == 0


def test_delta_features():
    """Paper §2.1: delta / delta-delta dynamic features."""
    r = np.random.RandomState(0)
    f = jnp.asarray(r.randn(20, 5).astype(np.float32))
    d = features.deltas(f)
    assert d.shape == f.shape
    # delta of a constant signal is zero
    c = jnp.ones((10, 4))
    assert np.allclose(np.asarray(features.deltas(c)), 0.0)
    # delta of a linear ramp is the slope
    ramp = jnp.arange(12.0)[:, None] * jnp.ones((1, 3))
    dr = np.asarray(features.deltas(ramp))
    assert np.allclose(dr[3:-3], 1.0, atol=1e-5)
    # stacked features triple the dim
    sig = jnp.asarray(r.randn(4000).astype(np.float32))
    out = features.mfcc_with_deltas(sig)
    assert out.shape[1] == 3 * features.FeatureConfig().n_mfcc
    assert np.isfinite(np.asarray(out)).all()
