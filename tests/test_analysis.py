"""repro-lint (src/repro/analysis): per-rule fixture snippets
(positive + suppressed + clean, including minimized reproductions of
the PR 5 mesh-dependent-RNG bug, the PR 6 poll-aliasing bug, the PR 8
partial-psum bug, and the PR 9 half-committed-slot bug), the
suppression syntax (including the interprocedural related-location
form), the baseline / GitHub-annotation CLI modes, the runtime guards,
and a self-run over src/repro pinning the tree clean."""
import pathlib
import textwrap
import threading
from types import SimpleNamespace

import numpy as np
import pytest

from repro.analysis import run_paths
from repro.analysis.core import RULE_DOCS

REPO = pathlib.Path(__file__).resolve().parents[1]


def lint_snippet(tmp_path, source, name="snippet.py", rules=None):
    path = tmp_path / name
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(textwrap.dedent(source))
    findings, suppressed = run_paths([str(path)], rules=rules,
                                     root=tmp_path)
    return findings, suppressed


def codes(findings):
    return [f.code for f in findings]


# ---------------------------------------------------------------------------
# RPL001 — jit hazards
# ---------------------------------------------------------------------------

def test_rpl001_fires_on_tracer_branch_and_coercion(tmp_path):
    findings, _ = lint_snippet(tmp_path, """
        import jax

        @jax.jit
        def f(x):
            if x > 0:                 # tracer branch
                return x
            return -x

        @jax.jit
        def g(x):
            for i in range(x):        # tracer loop bound
                pass
            return float(x)           # tracer coercion
    """)
    assert codes(findings).count("RPL001") == 3


def test_rpl001_fires_on_name_passed_to_jit_and_item(tmp_path):
    # the AsrEngine pattern: a nested def jitted BY NAME, not decorator
    findings, _ = lint_snippet(tmp_path, """
        import jax

        def build():
            def step(state, x):
                s = x.sum()
                return s.item()       # coercion inside the traced fn
            return jax.jit(step)
    """)
    assert codes(findings) == ["RPL001"]


def test_rpl001_fires_on_mutable_static_default(tmp_path):
    findings, _ = lint_snippet(tmp_path, """
        import functools
        import jax

        @functools.partial(jax.jit, static_argnames=("opts",))
        def f(x, opts=[]):
            return x
    """)
    assert codes(findings) == ["RPL001"]


def test_rpl001_clean_on_shape_branches_static_args_and_none_checks(
        tmp_path):
    findings, _ = lint_snippet(tmp_path, """
        import functools
        import jax
        import jax.numpy as jnp

        @functools.partial(jax.jit, static_argnames=("mode",))
        def f(x, bias=None, *, mode="ref"):
            T, D = x.shape
            pad = (-T) % 4
            if pad:                      # shape-derived: static
                x = jnp.pad(x, ((0, pad), (0, 0)))
            if mode == "ref":            # static arg
                x = x * 2
            if bias is not None:         # structural None check
                x = x + bias
            if len(x.shape) == 2:        # len() of static
                x = x[None]
            return x
    """)
    assert findings == []


def test_rpl001_suppressed(tmp_path):
    findings, suppressed = lint_snippet(tmp_path, """
        import jax

        @jax.jit
        def f(x):
            if x > 0:  # repro-lint: disable=RPL001
                return x
            return -x
    """)
    assert findings == []
    assert codes(suppressed) == ["RPL001"]


# ---------------------------------------------------------------------------
# RPL002 — kernel contract
# ---------------------------------------------------------------------------

def _kernel_tree(tmp_path, registry_body, kernel_body=None):
    kdir = tmp_path / "kernels"
    kdir.mkdir(exist_ok=True)
    (kdir / "ref.py").write_text("def foo(x):\n    return x\n")
    (kdir / "policy.py").write_text(registry_body)
    (kdir / "foo.py").write_text(kernel_body or textwrap.dedent("""
        from jax.experimental import pallas as pl

        def run(x, bt=8):
            T = x.shape[0]
            assert T % bt == 0
            return pl.pallas_call(lambda r, o: None, grid=(T // bt,))(x)
    """))
    (tmp_path / "tests").mkdir(exist_ok=True)
    (tmp_path / "tests" / "test_foo.py").write_text(
        "from kernels import foo  # parity: foo vs ref\n")
    findings, suppressed = run_paths([str(kdir)], rules=["RPL002"],
                                     root=tmp_path)
    return findings, suppressed


def test_rpl002_fires_on_unregistered_pallas_call(tmp_path):
    findings, _ = _kernel_tree(tmp_path, "KERNEL_REGISTRY = {}\n")
    assert codes(findings) == ["RPL002"]
    assert "no KERNEL_REGISTRY entry" in findings[0].message


def test_rpl002_fires_on_missing_ref_twin_and_guard(tmp_path):
    findings, _ = _kernel_tree(tmp_path, textwrap.dedent("""
        KERNEL_REGISTRY = {
            "foo": {"ref": "nope", "test": "tests/test_foo.py",
                    "shape_guard": "checked"},
        }
    """))
    assert "not defined in kernels/ref.py" in findings[0].message

    findings, _ = _kernel_tree(tmp_path, textwrap.dedent("""
        KERNEL_REGISTRY = {
            "foo": {"ref": "foo", "test": "tests/test_foo.py",
                    "shape_guard": "checked"},
        }
    """), kernel_body=textwrap.dedent("""
        from jax.experimental import pallas as pl

        def run(x):
            return pl.pallas_call(lambda r, o: None, grid=(4,))(x)
    """))
    assert codes(findings) == ["RPL002"]
    assert "divisibility" in findings[0].message


def test_rpl002_clean_with_full_contract(tmp_path):
    findings, _ = _kernel_tree(tmp_path, textwrap.dedent("""
        KERNEL_REGISTRY = {
            "foo": {"ref": "foo", "test": "tests/test_foo.py",
                    "shape_guard": "checked"},
        }
    """))
    assert findings == []


def test_rpl002_live_registry_covers_every_kernel_module():
    """The real KERNEL_REGISTRY names every pallas_call module, its ref
    twins exist, and its parity tests reference it — i.e. RPL002 is
    green on the tree it was built for."""
    findings, _ = run_paths([str(REPO / "src" / "repro" / "kernels")],
                            rules=["RPL002"], root=REPO)
    assert findings == []


# ---------------------------------------------------------------------------
# RPL003 — aliasing (minimized PR 6 bug)
# ---------------------------------------------------------------------------

PR6_BUG = """
    class Eng:
        def _poll(self, session):
            if session.admitted:
                res = self.slot_best(session.slot)
                res["steps"] = 1
                return res
            return {"steps": 0}
"""


def test_rpl003_fires_on_pr6_poll_aliasing_repro(tmp_path):
    findings, _ = lint_snippet(tmp_path, PR6_BUG)
    assert codes(findings) == ["RPL003"]


def test_rpl003_fires_on_state_attr_in_dict_and_set_result(tmp_path):
    findings, _ = lint_snippet(tmp_path, """
        class Eng:
            def snapshot(self, slot):
                return {"beam": self._beam, "n": 3}

            def resolve(self, fut, sess):
                fut.set_result(sess.result)
    """)
    assert codes(findings) == ["RPL003", "RPL003"]


def test_rpl003_clean_when_routed_through_copy_result(tmp_path):
    findings, _ = lint_snippet(tmp_path, """
        from repro.serving.engine import copy_result

        class Eng:
            def _poll(self, session):
                res = self.slot_best(session.slot)
                res["steps"] = 1
                return copy_result(res)

            def tokens(self, slot):
                return list(self._gen[slot])
    """)
    assert findings == []


def test_rpl003_suppressed_file_wide(tmp_path):
    findings, suppressed = lint_snippet(
        tmp_path, "# repro-lint: disable-file=RPL003\n"
        + textwrap.dedent(PR6_BUG))
    assert findings == []
    assert codes(suppressed) == ["RPL003"]


# ---------------------------------------------------------------------------
# RPL004 — thread discipline
# ---------------------------------------------------------------------------

THREADED = """
    def worker_only(fn):
        return fn

    class Eng:
        @worker_only
        def _advance_pool(self):
            pass

    async def handler(eng, worker):
        {call}
"""


def test_rpl004_fires_on_direct_async_call(tmp_path):
    findings, _ = lint_snippet(
        tmp_path, THREADED.format(call="eng._advance_pool()"))
    assert codes(findings) == ["RPL004"]


def test_rpl004_clean_through_worker_thunk(tmp_path):
    findings, _ = lint_snippet(
        tmp_path,
        THREADED.format(call="await worker.call("
                             "lambda eng: eng._advance_pool())"))
    assert findings == []


def test_rpl004_suppressed(tmp_path):
    findings, suppressed = lint_snippet(
        tmp_path, THREADED.format(
            call="eng._advance_pool()  # repro-lint: disable=RPL004"))
    assert findings == []
    assert codes(suppressed) == ["RPL004"]


SUPERVISED = """
    def worker_only(fn):
        return fn

    class Eng:
        @worker_only
        def _fail_all(self, exc):
            pass

    class Server:
        def {name}(self, eng, worker, exc):
            {call}
"""


def test_rpl004_fires_in_sync_watchdog_entry_point(tmp_path):
    """Supervisor/watchdog restart paths are sync defs running on the
    event-loop thread; a direct @worker_only call there is the same
    cross-thread race as one in an async handler."""
    findings, _ = lint_snippet(
        tmp_path, SUPERVISED.format(name="_watchdog_restart",
                                    call="eng._fail_all(exc)"))
    assert codes(findings) == ["RPL004"]
    assert "supervisor/watchdog" in findings[0].message


def test_rpl004_clean_watchdog_through_worker_thunk(tmp_path):
    """The blessed restart idiom — submitting the quarantine as a thunk
    the NEW worker runs — stays clean (lambdas are exempt)."""
    findings, _ = lint_snippet(
        tmp_path, SUPERVISED.format(
            name="_supervise_restart",
            call="worker.submit(lambda e: e._fail_all(exc))"))
    assert findings == []


def test_rpl004_ignores_unrelated_sync_functions(tmp_path):
    """Plain sync helpers (in-process drivers, tests) may call
    @worker_only methods directly — only supervisor/watchdog-named
    entry points are loop-side by contract."""
    findings, _ = lint_snippet(
        tmp_path, SUPERVISED.format(name="drive_inprocess",
                                    call="eng._fail_all(exc)"))
    assert findings == []


# ---------------------------------------------------------------------------
# RPL005 — RNG discipline (minimized PR 5 bug)
# ---------------------------------------------------------------------------

PR5_BUG = """
    import jax

    def init_params(mesh, spec):
        key = jax.random.PRNGKey(0)
        w = jax.random.normal(key, (8, 8))
        place = jax.jit(lambda x: x, out_shardings=spec)
        return place(w)
"""


def test_rpl005_fires_on_pr5_mesh_dependent_init_repro(tmp_path):
    findings, _ = lint_snippet(tmp_path, PR5_BUG)
    assert codes(findings) == ["RPL005"]
    assert "mesh_invariant_rng" in findings[0].message


def test_rpl005_clean_with_mesh_invariant_rng(tmp_path):
    findings, _ = lint_snippet(tmp_path, """
        import jax
        from repro.runtime.elastic import mesh_invariant_rng

        def init_params(mesh, spec):
            with mesh_invariant_rng():
                key = jax.random.PRNGKey(0)
                w = jax.random.normal(key, (8, 8))
            place = jax.jit(lambda x: x, out_shardings=spec)
            return place(w)
    """)
    assert findings == []


def test_rpl005_clean_without_sharded_jit(tmp_path):
    findings, _ = lint_snippet(tmp_path, """
        import jax

        def make_key():
            return jax.random.PRNGKey(0)
    """)
    assert findings == []


def test_rpl005_fires_on_shard_map_module(tmp_path):
    # the 2D ('data','model') serving-mesh class: shard_map compute
    # plus PRNGKey init — mesh-dependent RNG would fork per data shard
    findings, _ = lint_snippet(tmp_path, """
        import jax
        from repro import compat
        from jax.sharding import PartitionSpec as P

        def build(mesh):
            key = jax.random.PRNGKey(0)
            w = jax.random.normal(key, (8, 8))
            step = compat.shard_map(lambda x: x, mesh=mesh,
                                    in_specs=(P(),), out_specs=P())
            return step(w)
    """)
    assert codes(findings) == ["RPL005"]
    assert "shard_map" in findings[0].message


def test_rpl005_clean_shard_map_with_mesh_invariant_rng(tmp_path):
    findings, _ = lint_snippet(tmp_path, """
        import jax
        from repro import compat
        from repro.runtime.elastic import mesh_invariant_rng
        from jax.sharding import PartitionSpec as P

        def build(mesh):
            with mesh_invariant_rng():
                key = jax.random.PRNGKey(0)
                w = jax.random.normal(key, (8, 8))
            step = compat.shard_map(lambda x: x, mesh=mesh,
                                    in_specs=(P(),), out_specs=P())
            return step(w)
    """)
    assert findings == []


# ---------------------------------------------------------------------------
# RPL006 — collective/axis discipline (interprocedural; minimized PR 8 bug)
# ---------------------------------------------------------------------------

def test_rpl006_fires_on_undeclared_collective_axis(tmp_path):
    # psum over "model" inside a function traced by a shard_map whose
    # PartitionSpecs only declare "data" — fails at trace time on the
    # real mesh, and the finding carries the binder as a related site
    findings, _ = lint_snippet(tmp_path, """
        import jax
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        def step(x):
            return jax.lax.psum(x, "model")

        def build(mesh):
            return shard_map(step, mesh=mesh,
                             in_specs=(P("data"),), out_specs=P("data"))
    """, rules=["RPL006"])
    assert codes(findings) == ["RPL006"]
    assert "psum" in findings[0].message and "'model'" in findings[0].message
    assert findings[0].related            # binder call site attached


def test_rpl006_clean_on_declared_axis(tmp_path):
    findings, _ = lint_snippet(tmp_path, """
        import jax
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        def step(x):
            return jax.lax.psum(x, "data")

        def build(mesh):
            return shard_map(step, mesh=mesh,
                             in_specs=(P("data"),), out_specs=P("data"))
    """, rules=["RPL006"])
    assert findings == []


PR8_BUG = """
    import jax
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    W = None

    def shard_cols(w):
        i = jax.lax.axis_index("model")
        return jax.lax.dynamic_slice(w, (0, i * 4), (8, 4))

    def step(x):
        wl = shard_cols(W)
        return {ret}

    def build(mesh):
        return shard_map(step, mesh=mesh,
                         in_specs=(P("model"),), out_specs=P())
"""


def test_rpl006_fires_on_pr8_partial_matmul_repro(tmp_path):
    # the PR 8 silent-wrong-numerics class: each shard returns its
    # DIFFERENT partial product because the psum is missing
    findings, _ = lint_snippet(
        tmp_path, PR8_BUG.format(ret="x @ wl"), rules=["RPL006"])
    assert codes(findings) == ["RPL006"]
    assert "partial sum" in findings[0].message


def test_rpl006_clean_with_dominating_psum(tmp_path):
    findings, _ = lint_snippet(
        tmp_path, PR8_BUG.format(ret='jax.lax.psum(x @ wl, "model")'),
        rules=["RPL006"])
    assert findings == []


def test_rpl006_fires_on_unguarded_mesh_shape_lookup(tmp_path):
    findings, _ = lint_snippet(tmp_path, """
        def param_spec(mesh, size):
            return size // mesh.shape["model"]
    """, rules=["RPL006"])
    assert codes(findings) == ["RPL006"]
    assert "axis_names" in findings[0].message


def test_rpl006_clean_on_guarded_mesh_shape_lookup(tmp_path):
    # regression fixture for the sharding.py fix: the guarded helper
    # form (membership test before the lookup) is clean, and callers
    # that route through it never touch mesh.shape directly
    findings, _ = lint_snippet(tmp_path, """
        def axis_size(mesh, name):
            return mesh.shape[name] if name in mesh.axis_names else None

        def param_spec(mesh, size):
            nm = axis_size(mesh, "model")
            return size // nm if nm and size % nm == 0 else size
    """, rules=["RPL006"])
    assert findings == []


def test_rpl006_suppressed_at_collective_line(tmp_path):
    findings, suppressed = lint_snippet(tmp_path, """
        import jax
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        def step(x):
            return jax.lax.psum(x, "model")  # repro-lint: disable=RPL006

        def build(mesh):
            return shard_map(step, mesh=mesh,
                             in_specs=(P("data"),), out_specs=P("data"))
    """, rules=["RPL006"])
    assert findings == []
    assert codes(suppressed) == ["RPL006"]


def test_rpl006_suppressed_at_related_binder_line(tmp_path):
    """Interprocedural findings carry related locations: a disable at
    the shard_map BINDER call silences the finding inside the root
    function too (the binder owns the axis declaration)."""
    findings, suppressed = lint_snippet(tmp_path, """
        import jax
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        def step(x):
            return jax.lax.psum(x, "model")

        def build(mesh):
            # repro-lint: disable=RPL006
            return shard_map(step, mesh=mesh,
                             in_specs=(P("data"),), out_specs=P("data"))
    """, rules=["RPL006"])
    assert findings == []
    assert codes(suppressed) == ["RPL006"]


# ---------------------------------------------------------------------------
# RPL007 — Pallas block contract
# ---------------------------------------------------------------------------

REGISTRY_FULL = """
    KERNEL_REGISTRY = {{
        "foo": {{"ref": "foo", "test": "tests/test_foo.py",
                "shape_guard": "checked"{extra}}},
    }}
"""

KERNEL_OK = """
    from jax.experimental import pallas as pl

    def run(x, bt=8):
        T = x.shape[0]
        assert T % bt == 0
        return pl.pallas_call(lambda r, o: None, grid=(T // bt,))(x)
"""


def _rpl007_tree(tmp_path, registry_body, kernel_body=KERNEL_OK,
                 ref_body="def foo(x):\n    return x\n"):
    kdir = tmp_path / "kernels"
    kdir.mkdir(exist_ok=True)
    (kdir / "ref.py").write_text(ref_body)
    (kdir / "policy.py").write_text(textwrap.dedent(registry_body))
    (kdir / "foo.py").write_text(textwrap.dedent(kernel_body))
    return run_paths([str(kdir)], rules=["RPL007"], root=tmp_path)


def test_rpl007_fires_on_missing_entry_metadata(tmp_path):
    findings, _ = _rpl007_tree(
        tmp_path, REGISTRY_FULL.format(extra=""))
    assert codes(findings) == ["RPL007"]
    assert "'entry'" in findings[0].message


def test_rpl007_fires_on_undefined_entry_wrapper(tmp_path):
    findings, _ = _rpl007_tree(
        tmp_path, REGISTRY_FULL.format(extra=', "entry": "nope"'))
    assert codes(findings) == ["RPL007"]
    assert "not defined" in findings[0].message


def test_rpl007_fires_on_signature_parity_break(tmp_path):
    # ref twin requires (x, scale); the entry wrapper only takes (x):
    # policy dispatch between kernel and ref would TypeError
    findings, _ = _rpl007_tree(
        tmp_path, REGISTRY_FULL.format(extra=', "entry": "run"'),
        ref_body="def foo(x, scale):\n    return x * scale\n")
    assert codes(findings) == ["RPL007"]
    assert "scale" in findings[0].message


def test_rpl007_fires_on_index_map_closure(tmp_path):
    findings, _ = _rpl007_tree(
        tmp_path, REGISTRY_FULL.format(extra=', "entry": "run"'),
        kernel_body="""
            from jax.experimental import pallas as pl

            OFFSET = 3

            def run(x, bt=8):
                T = x.shape[0]
                assert T % bt == 0
                spec = pl.BlockSpec((bt,),
                                    index_map=lambda i: (i + OFFSET,))
                return pl.pallas_call(lambda r, o: None, grid=(T // bt,),
                                      in_specs=[spec])(x)
        """)
    assert codes(findings) == ["RPL007"]
    assert "closes over `OFFSET`" in findings[0].message


def test_rpl007_fires_on_unenforced_shape_guard(tmp_path):
    findings, _ = _rpl007_tree(
        tmp_path, REGISTRY_FULL.format(extra=', "entry": "run"'),
        kernel_body="""
            from jax.experimental import pallas as pl

            def run(x, bt=8):
                return pl.pallas_call(lambda r, o: None, grid=(4,))(x)
        """)
    assert codes(findings) == ["RPL007"]
    assert "divisibility" in findings[0].message


def test_rpl007_clean_with_full_contract(tmp_path):
    findings, _ = _rpl007_tree(
        tmp_path, REGISTRY_FULL.format(extra=', "entry": "run"'))
    assert findings == []


def test_rpl007_suppressed_above_decorated_entry(tmp_path):
    """The parity finding anchors on the `def` line; a disable comment
    ABOVE the decorator stack must still reach it (comment suppression
    propagates through decorator lines)."""
    findings, suppressed = _rpl007_tree(
        tmp_path, REGISTRY_FULL.format(extra=', "entry": "run"'),
        kernel_body="""
            import functools
            from jax.experimental import pallas as pl

            # repro-lint: disable=RPL007
            @functools.lru_cache(maxsize=None)
            def run(x, bt=8):
                T = x.shape[0]
                assert T % bt == 0
                return pl.pallas_call(lambda r, o: None,
                                      grid=(T // bt,))(x)
        """,
        ref_body="def foo(x, scale):\n    return x * scale\n")
    assert findings == []
    assert codes(suppressed) == ["RPL007"]


# ---------------------------------------------------------------------------
# RPL008 — commit discipline (minimized PR 9 bug)
# ---------------------------------------------------------------------------

PR9_BUG = """
    class Eng:
        def reset_slot(self, slot):
            self._slot_bufs[slot] = None
            self._stream_state = self._jit_reset(self._stream_state, slot)
"""


def test_rpl008_fires_on_pr9_half_committed_reset_repro(tmp_path):
    findings, _ = lint_snippet(tmp_path, PR9_BUG, rules=["RPL008"])
    assert codes(findings) == ["RPL008"]
    assert "_slot_bufs" in findings[0].message
    assert findings[0].related            # mutation line attached


def test_rpl008_clean_dispatch_then_commit(tmp_path):
    # regression fixture for the asr.py reset_slot fix: run the
    # may-raise jit dispatch FIRST, commit engine state only after
    findings, _ = lint_snippet(tmp_path, """
        class Eng:
            def reset_slot(self, slot):
                new_state = self._jit_reset(self._stream_state, slot)
                self._stream_state = new_state
                self._slot_bufs[slot] = None
    """, rules=["RPL008"])
    assert findings == []


def test_rpl008_clean_with_restoring_handler(tmp_path):
    findings, _ = lint_snippet(tmp_path, """
        class Eng:
            def reset_slot(self, slot):
                saved = self._slot_bufs[slot]
                self._slot_bufs[slot] = None
                try:
                    self._jit_reset(slot)
                except Exception:
                    self._slot_bufs[slot] = saved
                    raise
    """, rules=["RPL008"])
    assert findings == []


def test_rpl008_fires_on_mutator_method_before_fault_probe(tmp_path):
    findings, _ = lint_snippet(tmp_path, """
        class Eng:
            def admit(self, sess):
                self._beam.append(sess)
                self._faults.check("admit")
    """, rules=["RPL008"])
    assert codes(findings) == ["RPL008"]
    assert "fault injector" in findings[0].message


def test_rpl008_suppressed_at_related_callee_hazard_line(tmp_path):
    """The hazard sits two files away: eng.py mutates state and calls
    disp.dispatch(), whose body dispatches a jitted step.  A disable at
    the CALLEE hazard line suppresses the caller-side finding (the
    callee owns the raise contract)."""
    (tmp_path / "disp.py").write_text(textwrap.dedent("""
        def dispatch(eng, slot):
            return eng._jit_step(slot)  # repro-lint: disable=RPL008
    """))
    (tmp_path / "eng.py").write_text(textwrap.dedent("""
        from disp import dispatch

        class Eng:
            def reset(self, slot):
                self._slot_bufs[slot] = None
                dispatch(self, slot)
    """))
    findings, suppressed = run_paths(
        [str(tmp_path / "eng.py"), str(tmp_path / "disp.py")],
        rules=["RPL008"], root=tmp_path)
    assert findings == []
    assert codes(suppressed) == ["RPL008"]


def test_rpl008_fires_through_unsuppressed_callee_hazard(tmp_path):
    # same two-file shape without the disable: the interprocedural
    # propagation itself must fire, and related must point at both the
    # mutation line and the callee hazard line
    (tmp_path / "disp.py").write_text(textwrap.dedent("""
        def dispatch(eng, slot):
            return eng._jit_step(slot)
    """))
    (tmp_path / "eng.py").write_text(textwrap.dedent("""
        from disp import dispatch

        class Eng:
            def reset(self, slot):
                self._slot_bufs[slot] = None
                dispatch(self, slot)
    """))
    findings, _ = run_paths(
        [str(tmp_path / "eng.py"), str(tmp_path / "disp.py")],
        rules=["RPL008"], root=tmp_path)
    assert codes(findings) == ["RPL008"]
    rel_paths = {p for p, _ in findings[0].related}
    assert "eng.py" in rel_paths and "disp.py" in rel_paths


# ---------------------------------------------------------------------------
# driver mechanics + self-run
# ---------------------------------------------------------------------------

def test_rule_docs_cover_all_eight_rules():
    assert sorted(RULE_DOCS) == ["RPL001", "RPL002", "RPL003",
                                 "RPL004", "RPL005", "RPL006",
                                 "RPL007", "RPL008"]


def test_preceding_line_suppression(tmp_path):
    findings, suppressed = lint_snippet(tmp_path, """
        import jax

        @jax.jit
        def f(x):
            # repro-lint: disable=RPL001
            if x > 0:
                return x
            return -x
    """)
    assert findings == []
    assert codes(suppressed) == ["RPL001"]


def test_cli_exit_codes(tmp_path):
    from repro.analysis.__main__ import main
    bad = tmp_path / "bad.py"
    bad.write_text("import jax\n\n@jax.jit\ndef f(x):\n"
                   "    return float(x)\n")
    assert main([str(bad)]) == 1
    good = tmp_path / "good.py"
    good.write_text("x = 1\n")
    assert main([str(good)]) == 0
    assert main(["--list-rules"]) == 0


BAD_SNIPPET = ("import jax\n\n@jax.jit\ndef f(x):\n"
               "    return float(x)\n")


def test_cli_file_wide_disable_with_show_suppressed(tmp_path, capsys):
    from repro.analysis.__main__ import main
    bad = tmp_path / "bad.py"
    bad.write_text("# repro-lint: disable-file=RPL001\n" + BAD_SNIPPET)
    assert main([str(bad)]) == 0
    # without the flag only the summary counts it; no finding line
    assert "[suppressed] " not in capsys.readouterr().out
    assert main([str(bad), "--show-suppressed"]) == 0
    out = capsys.readouterr().out
    assert "[suppressed] " in out and "RPL001" in out
    assert "1 suppressed" in out


def test_cli_github_format(tmp_path, capsys):
    from repro.analysis.__main__ import main
    bad = tmp_path / "bad.py"
    bad.write_text(BAD_SNIPPET)
    assert main([str(bad), "--format", "github"]) == 1
    out = capsys.readouterr().out
    assert "::error file=" in out
    assert "title=repro-lint RPL001" in out

    sup = tmp_path / "sup.py"
    sup.write_text("# repro-lint: disable-file=RPL001\n" + BAD_SNIPPET)
    assert main([str(sup), "--format", "github",
                 "--show-suppressed"]) == 0
    out = capsys.readouterr().out
    assert "::notice file=" in out          # suppressed demoted
    assert "::error" not in out


def test_cli_baseline_round_trip(tmp_path, capsys):
    from repro.analysis.__main__ import main
    bad = tmp_path / "bad.py"
    bad.write_text(BAD_SNIPPET)
    baseline = tmp_path / "baseline.json"

    # recording the current findings turns the run green...
    assert main([str(bad), "--baseline", str(baseline),
                 "--update-baseline"]) == 0
    assert baseline.exists()
    assert main([str(bad), "--baseline", str(baseline)]) == 0
    out = capsys.readouterr().out
    assert "1 baselined" in out and "[baseline] " in out

    # ...but a NEW finding (second float() coercion, distinct message
    # context) still gates: the baseline is a per-key count budget
    bad.write_text(BAD_SNIPPET +
                   "\n@jax.jit\ndef g(y):\n    return int(y)\n")
    assert main([str(bad), "--baseline", str(baseline)]) == 1
    assert main([str(bad), "--baseline", str(baseline),
                 "--update-baseline"]) == 0
    assert main([str(bad), "--baseline", str(baseline)]) == 0


def test_self_run_over_src_repro_is_clean():
    """The gating CI contract: zero unsuppressed findings on the tree."""
    findings, _ = run_paths([str(REPO / "src" / "repro")], root=REPO)
    assert findings == [], "\n".join(f.format() for f in findings)


def test_config_registry_has_no_dead_modules():
    """Every config module is either imported outside archs.py's blanket
    registration or named by a test/launcher (the import-graph check
    that cleared deepseek_coder_33b for deletion)."""
    from repro.analysis.imports import config_usage
    dead = [u.module for u in config_usage(REPO) if u.dead]
    assert dead == [], dead


# ---------------------------------------------------------------------------
# runtime guards
# ---------------------------------------------------------------------------

def test_worker_only_runtime_guard():
    from repro.serving.engine import Engine
    eng = Engine(SimpleNamespace(n_slots=1, max_queue=None))
    assert eng._admit() is False          # unowned engine: any thread

    eng._owner_thread = threading.Thread(name="fake-worker")
    with pytest.raises(RuntimeError, match="owned by worker thread"):
        eng._admit()
    eng._owner_thread = None
    assert eng._admit() is False


def test_compilation_budget_counts_and_raises():
    import jax
    import jax.numpy as jnp

    from repro.analysis.guards import compilation_budget, count_compilations

    @jax.jit
    def f(x):
        return x * 2 + 1

    x7, x7b, x9 = jnp.arange(7.0), jnp.arange(7.0) + 1, jnp.arange(9.0)
    with count_compilations() as c:
        jax.block_until_ready(f(x7))
    assert c.count >= 1                   # fresh shape: really compiled

    with compilation_budget(0, "warmed f"):
        jax.block_until_ready(f(x7b))

    with pytest.raises(AssertionError, match="compilation budget"):
        with compilation_budget(0, "cold shape"):
            jax.block_until_ready(f(x9))


def test_no_implicit_transfers_blocks_scalar_readback():
    import jax
    import jax.numpy as jnp

    from repro.analysis.guards import no_implicit_transfers

    x = jnp.arange(4.0)
    with no_implicit_transfers():
        y = x + x                         # device-only work: fine
    with pytest.raises(jax.errors.JaxRuntimeError, match="[Dd]isallow"):
        with no_implicit_transfers():
            float(x[0])                   # implicit device->host readback


# ---------------------------------------------------------------------------
# regression tests for the true positives fixed in this PR
# ---------------------------------------------------------------------------

def test_asr_poll_results_are_owned_writable_copies():
    """PR 6 follow-up (found by RPL003): mid-stream poll results were
    zero-copy READ-ONLY views over the engine's jitted readout buffers.
    Callers must receive owned, writable arrays, and mutating them must
    not leak into later polls."""
    from repro.launch.serve import asr_demo_engine
    from repro.data.pipeline import SyntheticASR

    engine, words = asr_demo_engine(1)
    audio = SyntheticASR(words).utterance(0)["audio"]
    sess = engine.open().push(audio)
    res = sess.poll()
    assert sess.admitted and engine.n_steps > 0
    for key in ("words", "tokens"):
        arr = res[key]
        assert isinstance(arr, np.ndarray) and arr.flags.writeable, key
        arr.fill(-1)                      # caller scribbles on its copy
    res2 = sess.poll()                    # ...and the engine never sees it
    assert not (len(res2["tokens"]) and (res2["tokens"] == -1).all())
    final = sess.finish()
    assert final["words"].flags.writeable
    assert final["tokens"].flags.writeable
