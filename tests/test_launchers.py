"""Launcher integration: train loop (loss decreases, ckpt resume) and the
ASRPU serving path, exercised end-to-end on tiny configs."""
import numpy as np


def test_train_launcher_tiny(tmp_path):
    from repro.launch import train
    losses = train.main(["--arch", "mamba2-1.3b", "--tiny", "--steps", "30",
                         "--batch", "4", "--seq", "32", "--lr", "3e-3",
                         "--ckpt", str(tmp_path), "--ckpt-every", "10",
                         "--log-every", "100"])
    assert len(losses) == 30
    assert losses[-1] < losses[0]
    # resume
    losses2 = train.main(["--arch", "mamba2-1.3b", "--tiny", "--steps", "5",
                          "--batch", "4", "--seq", "32", "--ckpt",
                          str(tmp_path), "--resume", "--log-every", "100"])
    assert len(losses2) == 5
    assert np.isfinite(losses2).all()


def test_train_launcher_moe_tiny():
    from repro.launch import train
    losses = train.main(["--arch", "qwen2-moe-a2.7b", "--tiny", "--steps",
                         "10", "--batch", "4", "--seq", "32",
                         "--log-every", "100"])
    assert np.isfinite(losses).all()


def test_serve_asr_launcher(capsys):
    from repro.launch import serve
    serve.main(["--mode", "asr", "--utterances", "1"])
    out = capsys.readouterr().out
    assert "RTF" in out and "best words" in out
