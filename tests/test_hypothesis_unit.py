"""Fused Pallas hypothesis unit: interpret-mode bit-for-bit parity with
the pure-jnp ref pipeline, fused-vs-legacy semantic equivalence, the
hash-sentinel collision regression, and KernelPolicy dispatch."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hypothesis as hyp
from repro.kernels import ops, ref
from repro.kernels.policy import KernelPolicy

NEG_INF = hyp.NEG_INF


def _candidates(seed, b, n, dup_rate=0.5, dead_rate=0.2):
    """Random candidate rows with forced duplicate hashes and dead
    (-inf) entries."""
    r = np.random.RandomState(seed)
    n_hash = max(1, int(n * (1.0 - dup_rate)))
    hashes = r.randint(0, n_hash, (b, n)).astype(np.int32)
    pb = (r.randn(b, n) * 3).astype(np.float32)
    pnb = (r.randn(b, n) * 3).astype(np.float32)
    dead = r.rand(b, n) < dead_rate
    pb = np.where(dead, NEG_INF, pb)
    pnb = np.where(dead, NEG_INF, pnb)
    return jnp.asarray(hashes), jnp.asarray(pb), jnp.asarray(pnb)


# ---------------------------------------------------------------------------
# kernel vs ref: bit-for-bit on CPU interpret mode
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed,b,n,k,beam", [
    (0, 1, 12, 4, 5.0), (1, 3, 64, 16, 10.0), (2, 4, 200, 16, 3.0),
    (3, 2, 130, 32, 1e9),          # crosses the 128-lane pad boundary
])
def test_fused_kernel_matches_ref_bit_for_bit(seed, b, n, k, beam):
    hashes, pb, pnb = _candidates(seed, b, n)
    got = ops.hypothesis_unit(hashes, pb, pnb, k, beam,
                              policy=KernelPolicy("interpret"))
    want = ops.hypothesis_unit(hashes, pb, pnb, k, beam,
                               policy=KernelPolicy("ref"))
    for key in ("idx", "pb", "pnb", "valid"):
        np.testing.assert_array_equal(np.asarray(got[key]),
                                      np.asarray(want[key]), err_msg=key)
    # ...and both match the standalone ref.py oracle
    oracle = ref.hypothesis_unit(hashes, pb, pnb, k=k, beam=beam)
    for key in ("idx", "pb", "pnb", "valid"):
        np.testing.assert_array_equal(np.asarray(got[key]),
                                      np.asarray(oracle[key]), err_msg=key)


def test_fused_kernel_all_pruned_edge():
    """A row whose candidates are ALL dead selects nothing, bit-for-bit
    across interpret and ref."""
    hashes = jnp.zeros((2, 10), jnp.int32)
    dead = jnp.full((2, 10), NEG_INF, jnp.float32)
    outs = [ops.hypothesis_unit(hashes, dead, dead, 4, 2.0,
                                policy=KernelPolicy(m))
            for m in ("interpret", "ref")]
    for key in ("idx", "pb", "pnb", "valid"):
        np.testing.assert_array_equal(np.asarray(outs[0][key]),
                                      np.asarray(outs[1][key]))
    assert not np.asarray(outs[0]["valid"]).any()
    assert np.all(np.asarray(outs[0]["pb"]) == NEG_INF)


def test_fused_kernel_duplicate_hash_merges_mass():
    """All candidates share one hash: the single survivor carries the
    full channel-wise logsumexp mass."""
    r = np.random.RandomState(0)
    pb = jnp.asarray(r.randn(1, 8).astype(np.float32))
    pnb = jnp.asarray(r.randn(1, 8).astype(np.float32))
    hashes = jnp.full((1, 8), 77, jnp.int32)
    for mode in ("interpret", "ref"):
        out = ops.hypothesis_unit(hashes, pb, pnb, 4, 1e9,
                                  policy=KernelPolicy(mode))
        valid = np.asarray(out["valid"])[0]
        assert valid.tolist() == [True, False, False, False]
        want_pb = float(jax.nn.logsumexp(pb))
        want_pnb = float(jax.nn.logsumexp(pnb))
        assert abs(float(out["pb"][0, 0]) - want_pb) < 1e-4
        assert abs(float(out["pnb"][0, 0]) - want_pnb) < 1e-4


# ---------------------------------------------------------------------------
# fused step vs the legacy merge_duplicates + select pipeline
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed,n,k,beam", [(0, 30, 8, 5.0), (1, 50, 12, 2.0),
                                           (2, 6, 12, 1e9)])
def test_fused_step_matches_legacy_pipeline(seed, n, k, beam):
    """hypothesis_unit_step (fused) == merge_duplicates -> select
    (legacy) on everything except the float error of the merge order."""
    hashes, pb, pnb = _candidates(seed, 1, n)
    c = hyp.Candidates(hashes[0], pb[0], pnb[0],
                       {"node": jnp.arange(n, dtype=jnp.int32)})
    fused = hyp.hypothesis_unit_step(c, k, beam)
    legacy = hyp.select(hyp.merge_duplicates(c), k, beam)
    assert (np.asarray(fused["valid"]) == np.asarray(legacy["valid"])).all()
    v = np.asarray(fused["valid"])
    for key in ("hash", "node"):
        np.testing.assert_array_equal(np.asarray(fused[key])[v],
                                      np.asarray(legacy[key])[v])
    for key in ("pb", "pnb"):
        np.testing.assert_allclose(np.asarray(fused[key])[v],
                                   np.asarray(legacy[key])[v],
                                   rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# sentinel collision regression
# ---------------------------------------------------------------------------
def test_valid_candidate_with_sentinel_hash_survives_merge():
    """A live candidate whose 31-bit hash equals 2**31 - 1 used to be
    keyed onto the invalid-candidate sentinel and silently dropped."""
    h = jnp.asarray([2**31 - 1, 5, 2**31 - 1], jnp.int32)
    pb = jnp.asarray([-1.0, -2.0, NEG_INF], jnp.float32)
    pnb = jnp.asarray([-0.5, NEG_INF, -3.0], jnp.float32)
    c = hyp.Candidates(h, pb, pnb, {})
    m = hyp.merge_duplicates(c)
    tot = np.asarray(hyp.total_score(m.pb, m.pnb))
    live = tot > NEG_INF / 2
    assert live.sum() == 2          # both hashes survive, merged
    want = np.logaddexp(np.logaddexp(-1.0, -0.5), -3.0)
    assert abs(tot[live & (np.asarray(h) == 2**31 - 1)][0] - want) < 1e-4

    sel = hyp.hypothesis_unit_step(c, 2, 1e9)
    assert np.asarray(sel["valid"]).all()
    assert set(np.asarray(sel["hash"]).tolist()) == {2**31 - 1, 5}


def test_dead_candidates_never_merge_with_sentinel_hash():
    """Dead entries must not contribute mass to a live 2**31-1 hash."""
    h = jnp.full((6,), 2**31 - 1, jnp.int32)
    pb = jnp.asarray([-1.0] + [NEG_INF] * 5, jnp.float32)
    pnb = jnp.full((6,), NEG_INF, jnp.float32)
    sel = hyp.hypothesis_unit_step(hyp.Candidates(h, pb, pnb, {}), 3, 1e9)
    v = np.asarray(sel["valid"])
    assert v.tolist() == [True, False, False]
    assert abs(float(sel["pb"][0]) - (-1.0)) < 1e-5


# ---------------------------------------------------------------------------
# KernelPolicy dispatch
# ---------------------------------------------------------------------------
def test_kernel_policy_resolution():
    assert KernelPolicy("ref").resolve() == "ref"
    assert KernelPolicy("interpret").resolve(hot=True) == "interpret"
    auto = KernelPolicy()
    assert auto.resolve(hot=True) == ("ref" if jax.default_backend() == "cpu"
                                      else "mosaic")
    assert auto.resolve() == ("interpret" if jax.default_backend() == "cpu"
                              else "mosaic")
    with pytest.raises(ValueError):
        KernelPolicy("eager")


def test_policy_dispatch_is_consistent_across_small_kernels():
    """Every ops wrapper honors an explicit policy: ref and interpret
    agree on beam_prune (exact masking math in both)."""
    r = np.random.RandomState(0)
    s = jnp.asarray(r.randn(300).astype(np.float32) * 10)
    a = ops.beam_prune(s, 4.0, policy=KernelPolicy("ref"))
    b = ops.beam_prune(s, 4.0, policy=KernelPolicy("interpret"))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
