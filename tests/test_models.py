"""Per-arch smoke tests (reduced configs) + model-level invariants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.models import LM, pad_vocab


def _batch(cfg, B=2, S=64, seed=0):
    key = jax.random.PRNGKey(seed)
    if cfg.embed_inputs:
        toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
        return {"tokens": toks, "labels": toks}
    emb = jax.random.normal(key, (B, S, cfg.d_model)).astype(jnp.bfloat16)
    lbl = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    return {"embeds": emb, "labels": lbl}


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_forward_and_train_step(arch):
    """Reduced config of the same family: one forward + one train step on
    CPU, asserting output shapes + no NaNs (brief requirement)."""
    cfg = get_config(arch).tiny()
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    loss, metrics = lm.loss_fn(params, batch, remat=False)
    assert np.isfinite(float(loss)), arch
    # one actual optimizer step
    from repro.optim import adamw
    ocfg = adamw.AdamWConfig(lr=1e-3)
    opt = adamw.init(params, ocfg)
    grads = jax.grad(lambda p: lm.loss_fn(p, batch, remat=False)[0])(params)
    new_p, _ = adamw.update(grads, opt, params, ocfg)
    l2, _ = lm.loss_fn(new_p, batch, remat=False)
    assert np.isfinite(float(l2))
    # prefill shapes
    logits, cache = lm.prefill(params, batch if cfg.embed_inputs else
                               {"embeds": batch["embeds"]})
    assert logits.shape == (2, pad_vocab(cfg.vocab_size))
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("arch", ["qwen2-72b", "chatglm3-6b", "qwen2-vl-7b",
                                  "h2o-danube-1.8b", "jamba-v0.1-52b",
                                  "mamba2-1.3b", "musicgen-medium"])
def test_decode_matches_prefill(arch):
    """Prefill of S tokens == prefill of S-1 + one decode step (exact)."""
    cfg = get_config(arch).tiny()
    lm = LM(cfg)
    key = jax.random.PRNGKey(1)
    params = lm.init(key)
    B, S = 2, 32
    batch = _batch(cfg, B, S, seed=1)
    bfull = {k: v for k, v in batch.items() if k != "labels"}
    if cfg.embed_inputs:
        b1 = {"tokens": bfull["tokens"][:, :S - 1]}
        b2 = {"tokens": bfull["tokens"][:, S - 1:]}
    else:
        b1 = {"embeds": bfull["embeds"][:, :S - 1]}
        b2 = {"embeds": bfull["embeds"][:, S - 1:]}
    logits_full, _ = lm.prefill(params, bfull)
    _, c1 = lm.prefill(params, b1)
    cache = lm.init_cache(B, S)

    def merge(dst, src):
        return dst.at[tuple(slice(0, s) for s in src.shape)].set(
            src.astype(dst.dtype))
    cache["layers"] = jax.tree.map(merge, cache["layers"], c1["layers"])
    cache["kpos"] = cache["kpos"].at[:S - 1].set(c1["kpos"])
    cache["offset"] = c1["offset"]
    logits_dec, tok, _ = lm.decode_step(params, cache, b2)
    lf = np.asarray(logits_full[:, :cfg.vocab_size], np.float32)
    ld = np.asarray(logits_dec[:, :cfg.vocab_size], np.float32)
    err = np.abs(lf - ld).max() / (np.abs(lf).max() + 1e-9)
    # jamba's mamba+attention hybrid accumulates slightly more drift
    # between the chunked-prefill and step-decode paths on CPU BLAS
    tol = 3e-2 if arch.startswith("jamba") else 2e-2
    assert err < tol, (arch, err)


@pytest.mark.slow
def test_swa_ring_cache_decode():
    """Sliding-window arch decodes with a window-sized ring cache."""
    cfg = get_config("h2o-danube-1.8b").tiny()   # window=64
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    B = 2
    S = 96                                        # > window
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                              cfg.vocab_size)
    # sequential decode with ring cache of size window
    cache = lm.init_cache(B, cfg.attn_window)
    assert cache["layers"]["p0"]["k"].shape[2] == cfg.attn_window
    for t in range(S):
        logits, tok, cache = lm.decode_step(params, cache,
                                            {"tokens": toks[:, t:t + 1]})
    # real-vocab logits finite (padded tail is -inf by design)
    assert np.isfinite(np.asarray(logits, np.float32)[:, :cfg.vocab_size]).all()
    assert int(cache["offset"]) == S


def test_vocab_padding_masked_in_decode():
    cfg = dataclasses.replace(get_config("mamba2-1.3b").tiny(),
                              vocab_size=250)   # pad to 512
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    cache = lm.init_cache(1, 8)
    logits, tok, _ = lm.decode_step(params, cache,
                                    {"tokens": jnp.zeros((1, 1), jnp.int32)})
    assert int(tok[0]) < cfg.vocab_size
    assert np.all(np.asarray(logits)[:, cfg.vocab_size:] == -np.inf)


def test_param_counts_match_actual_params():
    """Analytic param_counts (used for roofline MODEL_FLOPS) matches the
    real parameter tree within vocab-padding tolerance."""
    for arch in ("qwen2-72b", "jamba-v0.1-52b", "qwen2-moe-a2.7b",
                 "mamba2-1.3b"):
        cfg = get_config(arch).tiny()
        lm = LM(cfg)
        shapes = lm.param_shapes()
        actual = sum(int(np.prod(l.shape))
                     for l in jax.tree.leaves(shapes))
        # remove vocab padding from actual for comparison
        Vp = pad_vocab(cfg.vocab_size)
        n_emb = (1 if (cfg.embed_inputs or cfg.tie_embeddings) else 0) \
            + (0 if cfg.tie_embeddings else 1)
        actual -= n_emb * (Vp - cfg.vocab_size) * cfg.d_model
        expected = cfg.param_counts()["total"]
        rel = abs(actual - expected) / expected
        assert rel < 0.05, (arch, actual, expected, rel)


def test_moe_capacity_drop_monotone():
    """Higher capacity factor => decode/prefill agree (no drops)."""
    cfg = get_config("qwen2-moe-a2.7b").tiny()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    loss, m = lm.loss_fn(params, batch, remat=False)
    assert np.isfinite(float(loss))
    assert float(m["aux"]) >= 0.0
