"""Serving-path optimizations: int8 weights, flash-decoding, EP MoE —
formal versions of the §Perf verification runs."""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import LM
from repro.models import layers as L


@pytest.mark.parametrize("arch,tol", [
    ("chatglm3-6b", 0.15), ("qwen2-moe-a2.7b", 0.3),
    ("mamba2-1.3b", 0.15),
    # jamba tiny (d=64) compounds int8 noise through MoE routing flips —
    # a discrete effect of the toy width, not the quantizer (bisection in
    # §Perf notes: no single component dominates)
    ("jamba-v0.1-52b", 0.7),
])
def test_int8_serving_weights_close(arch, tol):
    cfg = get_config(arch).tiny()
    lm = LM(cfg)
    p = lm.init(jax.random.PRNGKey(0))
    pq = L.quantize_params_for_serving(p)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                              cfg.vocab_size)
    lf, _ = lm.prefill(p, {"tokens": toks})
    lq, _ = lm.prefill(pq, {"tokens": toks})
    a = np.asarray(lf[:, :cfg.vocab_size], np.float32)
    b = np.asarray(lq[:, :cfg.vocab_size], np.float32)
    rel = np.abs(a - b).max() / np.abs(a).max()
    assert rel < tol, (arch, rel)
    # decode runs under quantized params
    cache = lm.init_cache(2, 8)
    logits, tok, _ = lm.decode_step(pq, cache,
                                    {"tokens": toks[:, :1]})
    assert np.isfinite(np.asarray(logits)[:, :cfg.vocab_size]).all()


def test_quantize_skips_non_linear_leaves():
    cfg = get_config("jamba-v0.1-52b").tiny()
    p = LM(cfg).init(jax.random.PRNGKey(0))
    pq = L.quantize_params_for_serving(p)
    # conv, router, embed stay unquantized
    lay = pq["layers"]["p0"]["mixer"]
    assert "w" in lay["conv_x"]
    moe_layer = pq["layers"]["p1"]["mlp"]
    assert "w" in moe_layer["router"]
    assert "w" in pq["embed"]
    # attention projection is quantized
    attn = pq["layers"]["p3"]["mixer"]
    assert "wq" in attn["wqkv"] and "wscale" in attn["wqkv"]


SUBPROC_FLASH_DECODE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from repro.models import layers
    from repro.parallel.sharding import Sharder
    mesh = jax.make_mesh((2, 2), ("data", "model"))
    B, Sc, H, K, D = 4, 32, 8, 4, 16
    r = np.random.RandomState(0)
    q = jnp.asarray(r.randn(B,1,H,D).astype(np.float32))
    kc = jnp.asarray(r.randn(B,Sc,K,D).astype(np.float32))
    vc = jnp.asarray(r.randn(B,Sc,K,D).astype(np.float32))
    kn = jnp.asarray(r.randn(B,1,K,D).astype(np.float32))
    vn = jnp.asarray(r.randn(B,1,K,D).astype(np.float32))
    qpos = jnp.full((B,), 20, jnp.int32)
    kpos = jnp.where(jnp.arange(Sc) < 20, jnp.arange(Sc), -1).astype(jnp.int32)
    for win in (None, 8):
        ref = layers.attention_decode(q, kc, vc, qpos, kpos, window=win,
                                      k_new=kn, v_new=vn)
        with mesh:
            out = jax.jit(lambda *a: layers.attention_decode_sharded(
                *a, window=win, k_new=kn, v_new=vn,
                sharder=Sharder(mesh)))(q, kc, vc, qpos, kpos)
        err = float(jnp.abs(ref - out).max())
        assert err < 1e-5, (win, err)
    print("FLASH_DECODE_OK")
""")


@pytest.mark.slow
def test_flash_decoding_matches_reference_subprocess():
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", SUBPROC_FLASH_DECODE],
                       env=env, capture_output=True, text=True,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert "FLASH_DECODE_OK" in r.stdout, r.stdout + r.stderr


SUBPROC_EP_MOE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs.base import MoESpec
    from repro.models import moe
    from repro.parallel.sharding import Sharder
    mesh = jax.make_mesh((2, 2), ("data", "model"))
    spec = MoESpec(n_experts=4, top_k=2, expert_d_ff=32, capacity_factor=8.0)
    D = 16
    p = moe.init_moe(jax.random.PRNGKey(0), D, spec, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, D))
    y_ref, _ = moe.apply_moe(p, x, spec, "silu")
    with mesh:
        y_ep, aux = jax.jit(lambda p, x: moe.apply_moe_ep(
            p, x, spec, "silu", Sharder(mesh)))(p, x)
        g = jax.jit(jax.grad(lambda p: moe.apply_moe_ep(
            p, x, spec, "silu", Sharder(mesh))[0].sum()))(p)
    err = float(jnp.abs(y_ref - y_ep).max())
    assert err < 1e-5, err
    gn = sum(float(jnp.sum(jnp.abs(l))) for l in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0
    print("EP_MOE_OK")
""")


@pytest.mark.slow
def test_ep_moe_matches_reference_subprocess():
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", SUBPROC_EP_MOE],
                       env=env, capture_output=True, text=True,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert "EP_MOE_OK" in r.stdout, r.stdout + r.stderr
