"""Runtime counterparts to the static rules: compilation budgets and
transfer guards.

Static analysis can prove a `float()` sits inside a jit trace, but not
that a shape-polymorphic call path retraces per request — that only
shows up at runtime.  `count_compilations()` counts REAL XLA backend
compiles (via jax.monitoring's backend_compile duration event, which
does not fire on tracing-cache or persistent-cache hits), and
`compilation_budget(n)` turns a count into an assertion, generalizing
the hand-rolled jit-entry counters the serving tests used to carry.

`no_implicit_transfers()` wraps jax.transfer_guard("disallow") for the
serving hot path: the jitted step must receive device arrays, never
silently upload numpy scalars or read back scalar indices per step.

jax is imported lazily so `python -m repro.analysis` stays stdlib-only.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Iterator, List

_COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"

_lock = threading.Lock()
_listener_installed = False
_active: List["CompilationCounter"] = []


class CompilationCounter:
    """Counts XLA backend compiles observed while active."""

    def __init__(self) -> None:
        self.count = 0

    def _bump(self) -> None:
        self.count += 1


def _on_compile_event(event: str, duration: float, **kwargs) -> None:
    if event != _COMPILE_EVENT:
        return
    with _lock:
        for counter in _active:
            counter._bump()


def _ensure_listener() -> None:
    global _listener_installed
    with _lock:
        if _listener_installed:
            return
        import jax

        jax.monitoring.register_event_duration_secs_listener(
            _on_compile_event)
        _listener_installed = True


@contextlib.contextmanager
def count_compilations() -> Iterator[CompilationCounter]:
    """Yield a CompilationCounter tallying real XLA compiles (cache
    hits — tracing cache or persistent compilation cache — don't fire
    the event, so re-entering an already-compiled jit counts 0)."""
    _ensure_listener()
    counter = CompilationCounter()
    with _lock:
        _active.append(counter)
    try:
        yield counter
    finally:
        with _lock:
            _active.remove(counter)


@contextlib.contextmanager
def compilation_budget(budget: int, what: str = "block") -> \
        Iterator[CompilationCounter]:
    """Assert at most `budget` fresh XLA compiles happen in the block.

    A budget of 0 pins "fully warmed: no retraces allowed" — the main
    use in the serving tests.  The assertion is skipped if the body
    raised, so the budget never masks the original failure.
    """
    with count_compilations() as counter:
        yield counter
    if counter.count > budget:
        raise AssertionError(
            f"compilation budget exceeded for {what}: "
            f"{counter.count} XLA compiles > budget {budget} "
            "(an input shape/dtype/static-arg is varying per call)")


@contextlib.contextmanager
def no_implicit_transfers(strict: bool = False) -> Iterator[None]:
    """Disallow implicit host<->device transfers in the block.

    Wraps the serving engines' jitted step calls: arguments must
    already be device arrays (explicit jnp.asarray / jax.device_put
    conversion is fine and still allowed by the guard), and nothing
    inside may trigger a per-step scalar readback.

    Default mode guards only the host<->device directions:
    device-to-device transfers stay allowed because a cold mesh-sharded
    step legitimately reshards committed inputs across the mesh on
    dispatch.

    `strict=True` adds the device-to-device direction (a blanket
    jax.transfer_guard("disallow")), which also fails on
    reshard-on-dispatch — on CPU host devices that reshard bounces
    through the host, so a warmed sharded step that still hits it is
    paying a hidden per-step round-trip.  Use it on WARMED paths whose
    inputs are already placed with the step's in_specs shardings (the
    engines upload batch/idx via explicit jax.device_put)."""
    import jax

    if strict:
        with jax.transfer_guard("disallow"):
            yield
        return
    with jax.transfer_guard_host_to_device("disallow"), \
            jax.transfer_guard_device_to_host("disallow"):
        yield
