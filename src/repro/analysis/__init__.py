"""repro-lint: repo-specific static analysis for the JAX/Pallas serving
stack (`python -m repro.analysis src/`).

Eight AST rules encode the contracts the serving engines, kernels, and
launchers rely on — each one a bug class that previously had to be
found by hand (see README "Static analysis" for the rule table and
docs/examples):

  RPL001  jit hazards: Python control flow / int()/float()/.item() on
          tracer-derived values inside jit-traced functions, and
          mutable defaults on static jit args (silent retraces,
          TracerBoolConversionError).
  RPL002  kernel contract: every `pl.pallas_call` site is registered in
          kernels/policy.py KERNEL_REGISTRY with a ref twin that exists
          and an interpret-parity test that references it, and its
          grid/BlockSpec divisibility assumption is shape-checked or
          has a documented fallback.
  RPL003  aliasing: results built from engine-owned slot state must
          route through `copy_result` before they escape the engine
          (the PR 6 poll-aliasing class).
  RPL004  thread discipline: `@worker_only` engine methods may not be
          called from asyncio handlers except through an EngineWorker
          submit/call thunk.
  RPL005  RNG discipline: modules that run sharded compute (jit with
          `out_shardings`, or `shard_map` — including the serving
          engines' ('data','model') mesh step) and create PRNG keys
          must call `mesh_invariant_rng()` (the PR 5 elastic
          mesh-dependent-init class).
  RPL006  collective/axis discipline (interprocedural): collectives
          inside shard_map-reachable functions must name an axis the
          binder's PartitionSpecs declare; a local partial matmul over
          a sharded contraction dim needs a dominating psum (the PR 8
          silent-wrong-numerics class); `mesh.shape[...]` on a mesh
          parameter needs an `axis_names` guard.
  RPL007  Pallas block contract: KERNEL_REGISTRY 'entry' metadata
          names a real function whose signature covers a registered
          ref twin, index_map outputs stay bounded/pure, and the
          divisibility shape-guard sits next to the pallas_call.
  RPL008  commit discipline: engine slot/pool state mutated before a
          may-raise call without a commit=False probe or a restoring
          try/finally (the PR 9 corrupt-slot-on-fault class).

RPL001/003/004/005 are per-file; RPL002/006/007/008 run over the
project-wide symbol table and call graph (repro.analysis.callgraph /
repro.analysis.interproc), with facts propagated through bounded
two-level call summaries — anything the engine can't resolve is
treated as unknown, and unknown is never flagged.

Suppress a finding with a trailing or preceding-line comment
`# repro-lint: disable=RPL001` (comma-separate several codes), or a
whole file with `# repro-lint: disable-file=RPL001`.  Interprocedural
findings carry related locations (e.g. the callee hazard line), and a
disable comment at any of them also suppresses the finding.

The runtime counterpart lives in `repro.analysis.guards`: compilation
budgets (counting real XLA compiles via jax.monitoring) and transfer
guards for the serving hot path.
"""
from repro.analysis.core import Finding, RULE_DOCS, run_paths

__all__ = ["Finding", "RULE_DOCS", "run_paths"]
