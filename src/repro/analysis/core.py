"""repro-lint driver: file walking, suppressions, rule dispatch.

Two-phase analysis: every file is parsed once into a `ParsedModule`,
a shared `Context` gathers the cross-file facts the rules need (the
kernel registry literal from kernels/policy.py, the set of
`@worker_only`-annotated method names), then per-file and global rules
run over the parsed set.  Pure stdlib `ast` — nothing here imports jax,
so the linter runs in milliseconds and in any environment.
"""
from __future__ import annotations

import ast
import pathlib
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set

SUPPRESS_TAG = "# repro-lint: disable="
SUPPRESS_FILE_TAG = "# repro-lint: disable-file="

RULE_DOCS = {
    "RPL001": "jit hazard: Python control flow or host coercion on a "
              "tracer, or a mutable default on a static jit arg",
    "RPL002": "kernel contract: pallas_call without a registered ref "
              "twin + parity test + shape-guarded grid assumptions",
    "RPL003": "aliasing: engine slot state escapes without copy_result",
    "RPL004": "thread discipline: @worker_only engine method called "
              "from an asyncio handler (or a supervisor/watchdog entry "
              "point) outside a worker thunk",
    "RPL005": "RNG discipline: sharded compute (out_shardings jit or "
              "shard_map) + PRNGKey without mesh_invariant_rng()",
    "RPL006": "collective/axis discipline: collective axis names inside "
              "shard_map-reachable code must be declared by the binder's "
              "PartitionSpecs; partial matmuls over a shard-local slice "
              "need a dominating psum; mesh.shape[...] needs an "
              "axis_names guard",
    "RPL007": "Pallas block contract: registry 'entry' metadata, "
              "entry<->ref-twin signature parity, bounded index_map "
              "outputs, and shape-guard placement for each pallas_call",
    "RPL008": "commit discipline: engine slot/pool state mutated before "
              "a may-raise call without commit=False probing or a "
              "restoring finally",
}


@dataclass(frozen=True)
class Finding:
    path: str
    line: int
    col: int
    code: str
    message: str
    related: tuple = ()           # ((path, line), ...) secondary sites —
                                  # a suppression at any of them counts

    def format(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.code} " \
               f"{self.message}"


@dataclass
class ParsedModule:
    path: pathlib.Path
    rel: str                      # path relative to the repo root
    source: str
    tree: ast.Module
    lines: List[str] = field(default_factory=list)

    def __post_init__(self):
        if not self.lines:
            self.lines = self.source.splitlines()


class Suppressions:
    """Per-file suppression map.

    A `# repro-lint: disable=RPL001[,RPL002]` comment suppresses those
    codes on its own line; on a comment-only line it also suppresses the
    next statement line (so a suppression can sit above a long
    statement) — and keeps sliding past decorator / blank / comment
    lines so a comment above `@decorator`s covers the `def` line too.
    `# repro-lint: disable-file=RPL001` suppresses a code everywhere in
    the file.  Suppressed findings are counted, never silently lost.
    """

    def __init__(self, lines: Sequence[str]):
        self.by_line: Dict[int, Set[str]] = {}
        self.file_wide: Set[str] = set()
        for i, text in enumerate(lines, start=1):
            if SUPPRESS_FILE_TAG in text:
                self.file_wide |= self._codes(text, SUPPRESS_FILE_TAG)
            if SUPPRESS_TAG in text:
                codes = self._codes(text, SUPPRESS_TAG)
                self.by_line.setdefault(i, set()).update(codes)
                if text.lstrip().startswith("#"):    # comment-only line
                    for j in range(i + 1, min(i + 12, len(lines) + 1)):
                        self.by_line.setdefault(j, set()).update(codes)
                        nxt = lines[j - 1].lstrip()
                        if nxt and not nxt.startswith(("#", "@")):
                            break

    @staticmethod
    def _codes(text: str, tag: str) -> Set[str]:
        spec = text.split(tag, 1)[1].split("#")[0]
        codes = set()
        for chunk in spec.replace(";", ",").split(","):
            tok = chunk.strip().split()
            if tok and tok[0].startswith("RPL"):
                codes.add(tok[0])
        return codes

    def covers(self, finding: Finding) -> bool:
        if finding.code in self.file_wide:
            return True
        return finding.code in self.by_line.get(finding.line, set())


def parse_file(path: pathlib.Path, root: pathlib.Path) -> ParsedModule:
    src = path.read_text()
    try:
        rel = str(path.relative_to(root))
    except ValueError:
        rel = str(path)
    return ParsedModule(path=path, rel=rel, source=src,
                        tree=ast.parse(src, filename=str(path)))


def find_repo_root(start: pathlib.Path) -> pathlib.Path:
    """Nearest ancestor holding pyproject.toml or .git (the anchor for
    registry-relative paths like `tests/test_kernels.py`)."""
    cur = start.resolve()
    if cur.is_file():
        cur = cur.parent
    for cand in (cur, *cur.parents):
        if (cand / "pyproject.toml").exists() or (cand / ".git").exists():
            return cand
    return cur


@dataclass
class Context:
    root: pathlib.Path
    modules: Dict[str, ParsedModule]
    worker_only_names: Set[str] = field(default_factory=set)
    _project = None

    def project(self):
        """Memoized whole-project symbol table + call graph shared by
        the interprocedural rules (RPL006–008)."""
        if self._project is None:
            from repro.analysis.callgraph import ProjectIndex
            self._project = ProjectIndex(self.modules, self.root)
        return self._project


def _collect_worker_only(modules: Dict[str, ParsedModule]) -> Set[str]:
    names: Set[str] = set()
    for mod in modules.values():
        for node in ast.walk(mod.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for deco in node.decorator_list:
                    d = deco.func if isinstance(deco, ast.Call) else deco
                    tail = d.attr if isinstance(d, ast.Attribute) else \
                        d.id if isinstance(d, ast.Name) else None
                    if tail == "worker_only":
                        names.add(node.name)
    return names


def iter_py_files(paths: Sequence[str]) -> List[pathlib.Path]:
    out: List[pathlib.Path] = []
    for p in paths:
        pth = pathlib.Path(p)
        if pth.is_dir():
            out.extend(sorted(f for f in pth.rglob("*.py")
                              if "__pycache__" not in f.parts))
        elif pth.suffix == ".py":
            out.append(pth)
    return out


def run_paths(paths: Sequence[str], *,
              rules: Optional[Sequence[str]] = None,
              root: Optional[pathlib.Path] = None):
    """Analyze `paths`; returns (findings, suppressed) with findings
    sorted by (path, line, code).  `rules` restricts to a subset of
    codes (default: all)."""
    from repro.analysis import rules as rulemod

    files = iter_py_files(paths)
    if root is None:
        root = find_repo_root(files[0] if files else pathlib.Path("."))
    modules = {str(f): parse_file(f, root) for f in files}
    ctx = Context(root=root, modules=modules)
    ctx.worker_only_names = _collect_worker_only(modules)

    active = set(rules or RULE_DOCS)
    raw: List[Finding] = []
    for mod in modules.values():
        for code, rule in rulemod.PER_FILE_RULES.items():
            if code in active:
                raw.extend(rule(mod, ctx))
    for code, rule in rulemod.GLOBAL_RULES.items():
        if code in active:
            raw.extend(rule(ctx))

    findings: List[Finding] = []
    suppressed: List[Finding] = []
    supp_cache: Dict[str, Suppressions] = {}

    def supp_for(rel: str) -> Optional[Suppressions]:
        if rel not in supp_cache:
            mod = next((m for m in modules.values() if m.rel == rel),
                       None)
            supp_cache[rel] = Suppressions(mod.lines) \
                if mod is not None else None
        return supp_cache[rel]

    for f in raw:
        supp = supp_for(f.path)
        covered = supp is not None and supp.covers(f)
        # an interprocedural finding may also be suppressed at any of
        # its related sites (e.g. the callee line of a may-raise chain)
        for rpath, rline in f.related:
            if covered:
                break
            rsupp = supp_for(rpath)
            covered = rsupp is not None and \
                f.code in (rsupp.file_wide
                           | rsupp.by_line.get(rline, set()))
        (suppressed if covered else findings).append(f)
    findings.sort(key=lambda f: (f.path, f.line, f.code))
    return findings, suppressed
