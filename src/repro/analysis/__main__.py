"""CLI: `python -m repro.analysis src/` — exit 1 on unsuppressed
findings, 0 otherwise.  `--list-rules` prints the rule table,
`--config-usage` prints the config-registry liveness report,
`--format github` emits workflow annotations, and
`--baseline FILE` fails only on findings NOT recorded in the baseline
(refresh it with `--update-baseline`)."""
from __future__ import annotations

import argparse
import collections
import json
import pathlib
import sys

from repro.analysis.core import (Finding, RULE_DOCS, find_repo_root,
                                 run_paths)


def _gh_escape(text: str) -> str:
    return (text.replace("%", "%25").replace("\r", "%0D")
            .replace("\n", "%0A"))


def format_finding(f: Finding, fmt: str, tag: str = None) -> str:
    """`tag` marks a non-gating finding ('suppressed' / 'baseline'):
    text mode prefixes it, github mode demotes ::error to ::notice."""
    if fmt == "github":
        level = "notice" if tag else "error"
        title = f"repro-lint {f.code}" + (f" ({tag})" if tag else "")
        return (f"::{level} file={f.path},line={f.line},"
                f"col={f.col + 1},title={_gh_escape(title)}::"
                f"{f.code} {_gh_escape(f.message)}")
    prefix = f"[{tag}] " if tag else ""
    return prefix + f.format()


def _baseline_key(f: Finding):
    # line numbers drift with unrelated edits; (path, code, message)
    # identifies a triaged finding robustly
    return (f.path, f.code, f.message)


def load_baseline(path: pathlib.Path):
    data = json.loads(path.read_text())
    counts: collections.Counter = collections.Counter()
    for row in data.get("findings", []):
        counts[(row["path"], row["code"], row["message"])] += 1
    return counts


def write_baseline(path: pathlib.Path, findings) -> None:
    rows = [{"path": f.path, "line": f.line, "code": f.code,
             "message": f.message}
            for f in findings]
    path.write_text(json.dumps({"findings": rows}, indent=2,
                               sort_keys=True) + "\n")


def split_against_baseline(findings, counts):
    """(new, baselined): a finding is baselined while its
    (path, code, message) key still has budget in the baseline —
    duplicates beyond the recorded count become new findings."""
    budget = collections.Counter(counts)
    new, baselined = [], []
    for f in findings:
        key = _baseline_key(f)
        if budget[key] > 0:
            budget[key] -= 1
            baselined.append(f)
        else:
            new.append(f)
    return new, baselined


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repro-lint: repo-specific JAX/Pallas static analysis")
    ap.add_argument("paths", nargs="*", default=["src/"],
                    help="files or directories to analyze (default: src/)")
    ap.add_argument("--rules", default=None,
                    help="comma-separated subset of rule codes to run")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule table and exit")
    ap.add_argument("--config-usage", action="store_true",
                    help="print the config-registry liveness report")
    ap.add_argument("--show-suppressed", action="store_true",
                    help="also print findings silenced by repro-lint "
                         "disable comments")
    ap.add_argument("--format", choices=("text", "github"),
                    default="text", dest="fmt",
                    help="'github' emits ::error workflow annotations "
                         "that land on the PR diff")
    ap.add_argument("--baseline", default=None, metavar="FILE",
                    help="JSON baseline of accepted findings: only NEW "
                         "findings fail the run")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite --baseline with the current findings "
                         "and exit 0")
    args = ap.parse_args(argv)

    if args.list_rules:
        for code, doc in sorted(RULE_DOCS.items()):
            print(f"{code}  {doc}")
        return 0

    if args.config_usage:
        from repro.analysis.imports import config_usage, format_config_usage
        root = find_repo_root(pathlib.Path(args.paths[0]
                                           if args.paths else "."))
        print(format_config_usage(config_usage(root)))
        return 0

    if args.update_baseline and not args.baseline:
        ap.error("--update-baseline requires --baseline FILE")

    rules = args.rules.split(",") if args.rules else None
    paths = args.paths or ["src/"]
    findings, suppressed = run_paths(paths, rules=rules)

    if args.update_baseline:
        write_baseline(pathlib.Path(args.baseline), findings)
        print(f"baseline updated: {len(findings)} finding(s) recorded "
              f"in {args.baseline}")
        return 0

    baselined = []
    if args.baseline and pathlib.Path(args.baseline).exists():
        findings, baselined = split_against_baseline(
            findings, load_baseline(pathlib.Path(args.baseline)))

    for f in findings:
        print(format_finding(f, args.fmt))
    for f in baselined:
        print(format_finding(f, args.fmt, tag="baseline"))
    if args.show_suppressed:
        for f in suppressed:
            print(format_finding(f, args.fmt, tag="suppressed"))
    tail = (f"{len(findings)} finding(s), {len(baselined)} baselined, "
            f"{len(suppressed)} suppressed")
    print(tail if findings or baselined or suppressed
          else f"repro-lint clean ({tail})")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
