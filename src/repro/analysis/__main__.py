"""CLI: `python -m repro.analysis src/` — exit 1 on unsuppressed
findings, 0 otherwise.  `--list-rules` prints the rule table,
`--config-usage` prints the config-registry liveness report."""
from __future__ import annotations

import argparse
import sys

from repro.analysis.core import RULE_DOCS, find_repo_root, run_paths


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repro-lint: repo-specific JAX/Pallas static analysis")
    ap.add_argument("paths", nargs="*", default=["src/"],
                    help="files or directories to analyze (default: src/)")
    ap.add_argument("--rules", default=None,
                    help="comma-separated subset of rule codes to run")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule table and exit")
    ap.add_argument("--config-usage", action="store_true",
                    help="print the config-registry liveness report")
    ap.add_argument("--show-suppressed", action="store_true",
                    help="also print findings silenced by repro-lint "
                         "disable comments")
    args = ap.parse_args(argv)

    if args.list_rules:
        for code, doc in sorted(RULE_DOCS.items()):
            print(f"{code}  {doc}")
        return 0

    if args.config_usage:
        import pathlib

        from repro.analysis.imports import config_usage, format_config_usage
        root = find_repo_root(pathlib.Path(args.paths[0]
                                           if args.paths else "."))
        print(format_config_usage(config_usage(root)))
        return 0

    rules = args.rules.split(",") if args.rules else None
    paths = args.paths or ["src/"]
    findings, suppressed = run_paths(paths, rules=rules)
    for f in findings:
        print(f.format())
    if args.show_suppressed:
        for f in suppressed:
            print(f"[suppressed] {f.format()}")
    tail = f"{len(findings)} finding(s), {len(suppressed)} suppressed"
    print(tail if findings or suppressed else f"repro-lint clean ({tail})")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
