"""Import graph + config-usage report.

Answers "is this module actually used?" for the config registry, where
plain grep lies: every configs/*.py is imported by configs/archs.py for
registration side effects, so import edges alone make everything look
live.  `config_usage` therefore reports, per config module, (a) its
importers OTHER than the blanket archs.py registration, and (b) files
elsewhere in the tree that mention its registered arch name as a
string literal (how tests and launchers actually select a config).
"""
from __future__ import annotations

import ast
import pathlib
from dataclasses import dataclass, field
from typing import Dict, List, Set

from repro.analysis.core import ParsedModule, iter_py_files, parse_file


def module_name(path: pathlib.Path, root: pathlib.Path) -> str:
    """Dotted module name for `path`, rooted at the import root
    (src/ layout aware: src/repro/x.py -> repro.x)."""
    rel = path.relative_to(root)
    parts = list(rel.with_suffix("").parts)
    if parts and parts[0] == "src":
        parts = parts[1:]
    if parts and parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


def build_import_graph(modules: Dict[str, ParsedModule],
                       root: pathlib.Path) -> Dict[str, Set[str]]:
    """module dotted name -> set of imported dotted names (absolute;
    relative imports are resolved against the importer's package)."""
    graph: Dict[str, Set[str]] = {}
    for mod in modules.values():
        name = module_name(mod.path, root)
        edges = graph.setdefault(name, set())
        pkg_parts = name.split(".")[:-1]
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Import):
                for alias in node.names:
                    edges.add(alias.name)
            elif isinstance(node, ast.ImportFrom):
                if node.level:
                    base = pkg_parts[:len(pkg_parts) - node.level + 1]
                    prefix = ".".join(base + ([node.module]
                                              if node.module else []))
                else:
                    prefix = node.module or ""
                if prefix:
                    edges.add(prefix)
                for alias in node.names:
                    if prefix:
                        edges.add(f"{prefix}.{alias.name}")
    return graph


@dataclass
class ConfigUsage:
    module: str                      # e.g. repro.configs.qwen2_72b
    arch_names: List[str]            # registered model names
    importers: List[str] = field(default_factory=list)    # minus archs.py
    name_refs: List[str] = field(default_factory=list)    # files citing name

    @property
    def dead(self) -> bool:
        return not self.importers and not self.name_refs


def _registered_names(mod: ParsedModule) -> List[str]:
    """String value of `name=` kwargs in register(ModelConfig(...))."""
    names: List[str] = []
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Call):
            for kw in node.keywords:
                if kw.arg == "name" and isinstance(kw.value, ast.Constant) \
                        and isinstance(kw.value.value, str):
                    names.append(kw.value.value)
    return names


def config_usage(root: pathlib.Path) -> List[ConfigUsage]:
    scan_dirs = [p for p in (root / "src", root / "tests",
                             root / "benchmarks") if p.is_dir()]
    files = iter_py_files([str(p) for p in scan_dirs])
    modules = {str(f): parse_file(f, root) for f in files}
    graph = build_import_graph(modules, root)

    cfg_dir = root / "src" / "repro" / "configs"
    skip = {"__init__", "base", "archs"}
    out: List[ConfigUsage] = []
    for path in sorted(cfg_dir.glob("*.py")):
        if path.stem in skip:
            continue
        dotted = module_name(path, root)
        mod = modules[str(path)]
        usage = ConfigUsage(module=dotted,
                            arch_names=_registered_names(mod))
        for importer, edges in graph.items():
            if importer in (dotted, "repro.configs.archs"):
                continue
            if dotted in edges or any(e.startswith(dotted + ".")
                                      for e in edges):
                usage.importers.append(importer)
        for other in modules.values():
            # the configs package itself (ASSIGNED_ARCHS in base.py, the
            # archs.py import list) is registry bookkeeping, not usage
            if other.path.parent == cfg_dir:
                continue
            if any(isinstance(n, ast.Constant) and n.value in
                   usage.arch_names for n in ast.walk(other.tree)
                   if isinstance(n, ast.Constant)):
                usage.name_refs.append(other.rel)
        usage.importers.sort()
        usage.name_refs.sort()
        out.append(usage)
    return out


def format_config_usage(usages: List[ConfigUsage]) -> str:
    lines = []
    for u in usages:
        status = "DEAD" if u.dead else "used"
        lines.append(f"{u.module} [{status}] names={u.arch_names}")
        if u.importers:
            lines.append(f"  importers (beyond archs.py): {u.importers}")
        if u.name_refs:
            lines.append(f"  name references: {u.name_refs}")
    return "\n".join(lines)
