"""The five repro-lint rules (see repro.analysis.__doc__ for the codes).

All rules are call-graph-LOCAL by design: they resolve names within one
module (plus the declared cross-file anchors — the kernel registry in
kernels/policy.py, `@worker_only` decorators, registry-named test
files).  That keeps them fast and predictable; contracts that need
whole-program reasoning get a runtime guard in `repro.analysis.guards`
instead.
"""
from __future__ import annotations

import ast
import pathlib
from typing import Dict, Iterable, List, Optional, Set, Tuple

from repro.analysis.core import Context, Finding, ParsedModule

# attribute reads that yield STATIC Python values even on a tracer
_SHAPE_ATTRS = {"shape", "ndim", "dtype", "size", "aval", "sharding"}
# module roots whose calls produce tracer values inside a jit trace
_ARRAY_ROOTS = {"jnp", "jax", "lax"}
_JIT_WRAPPERS = {"jit"}                 # jax.jit / compat aliases
_TRACE_CONSUMERS = {                    # callable-arg positions traced by jax
    "jit": (0,), "shard_map": (0,), "scan": (0,), "vmap": (0,),
    "pallas_call": (0,), "while_loop": (0, 1), "fori_loop": (2,),
    "checkpoint": (0,), "remat": (0,), "grad": (0,), "value_and_grad": (0,),
}


def _attr_tail(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return None


def _attr_root(node: ast.AST) -> Optional[str]:
    while isinstance(node, ast.Attribute):
        node = node.value
    return node.id if isinstance(node, ast.Name) else None


def _const_strs(node: ast.AST) -> List[str]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return [node.value]
    if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
        return [s for elt in node.elts for s in _const_strs(elt)]
    return []


# ---------------------------------------------------------------------------
# RPL001 — jit hazards
# ---------------------------------------------------------------------------

class _JitRoot:
    def __init__(self, fn, static_names: Set[str], static_nums: Set[int]):
        self.fn = fn
        self.static_names = static_names
        self.static_nums = static_nums


def _jit_call_info(call: ast.Call) -> Tuple[Set[str], Set[int]]:
    names: Set[str] = set()
    nums: Set[int] = set()
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            names |= set(_const_strs(kw.value))
        elif kw.arg == "static_argnums":
            if isinstance(kw.value, ast.Constant) and \
                    isinstance(kw.value.value, int):
                nums.add(kw.value.value)
            elif isinstance(kw.value, (ast.Tuple, ast.List)):
                nums |= {e.value for e in kw.value.elts
                         if isinstance(e, ast.Constant)
                         and isinstance(e.value, int)}
    return names, nums


def _decorator_jit(deco: ast.AST) -> Optional[Tuple[Set[str], Set[int]]]:
    """(static_argnames, static_argnums) if `deco` is a jit decorator:
    @jax.jit, @jit, @partial(jax.jit, ...), @functools.partial(jax.jit)."""
    if _attr_tail(deco) in _JIT_WRAPPERS:
        return set(), set()
    if isinstance(deco, ast.Call):
        tail = _attr_tail(deco.func)
        if tail in _JIT_WRAPPERS:
            return _jit_call_info(deco)
        if tail == "partial" and deco.args and \
                _attr_tail(deco.args[0]) in _JIT_WRAPPERS:
            return _jit_call_info(deco)
    return None


def _collect_jit_roots(mod: ParsedModule) -> List[_JitRoot]:
    """Functions traced by jax, resolved module-locally: jit-decorated
    defs, plus defs/lambdas whose NAME is passed to a trace-consuming
    call (jax.jit(step), shard_map(step, ...), lax.scan(body, ...))."""
    defs: Dict[str, List[ast.AST]] = {}
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.FunctionDef):
            defs.setdefault(node.name, []).append(node)

    roots: List[_JitRoot] = []
    seen: Set[ast.AST] = set()

    def add(fn, names=frozenset(), nums=frozenset()):
        if fn not in seen:
            seen.add(fn)
            roots.append(_JitRoot(fn, set(names), set(nums)))

    for node in ast.walk(mod.tree):
        if isinstance(node, ast.FunctionDef):
            for deco in node.decorator_list:
                info = _decorator_jit(deco)
                if info is not None:
                    add(node, *info)
        if isinstance(node, ast.Call):
            tail = _attr_tail(node.func)
            if tail not in _TRACE_CONSUMERS:
                continue
            static = _jit_call_info(node) if tail in _JIT_WRAPPERS \
                else (set(), set())
            for pos in _TRACE_CONSUMERS[tail]:
                if pos >= len(node.args):
                    continue
                arg = node.args[pos]
                if isinstance(arg, ast.Lambda):
                    add(arg, *static)
                elif isinstance(arg, ast.Name):
                    for fn in defs.get(arg.id, []):
                        add(fn, *static)
    return roots


class _TaintScope:
    """Conservative intra-function tracer taint: which local names may
    hold tracers at trace time."""

    def __init__(self, tainted: Set[str]):
        self.tainted = set(tainted)

    def expr(self, node: ast.AST) -> bool:
        if isinstance(node, ast.Name):
            return node.id in self.tainted
        if isinstance(node, ast.Attribute):
            if node.attr in _SHAPE_ATTRS:
                return False
            return self.expr(node.value)
        if isinstance(node, ast.Subscript):
            return self.expr(node.value) or self.expr(node.slice)
        if isinstance(node, ast.Call):
            tail = _attr_tail(node.func)
            if tail == "len":
                return False               # static under tracing
            if isinstance(node.func, ast.Attribute):
                root = _attr_root(node.func)
                if root in _ARRAY_ROOTS:
                    return True            # jnp./jax.lax. op -> tracer
                return self.expr(node.func.value)   # x.sum() on a tracer
            if tail == "range":
                return any(self.expr(a) for a in node.args)
            return False
        if isinstance(node, (ast.BinOp,)):
            return self.expr(node.left) or self.expr(node.right)
        if isinstance(node, ast.UnaryOp):
            return self.expr(node.operand)
        if isinstance(node, ast.BoolOp):
            return any(self.expr(v) for v in node.values)
        if isinstance(node, ast.Compare):
            # `x is None` / `x is not None` is a static structural check
            # even when x may hold a tracer — identity against None is
            # resolved at trace time, never on device values.
            if all(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops) \
                    and all(isinstance(c, ast.Constant) and c.value is None
                            for c in node.comparators):
                return False
            return self.expr(node.left) or \
                any(self.expr(c) for c in node.comparators)
        if isinstance(node, ast.IfExp):
            return (self.expr(node.body) or self.expr(node.test)
                    or self.expr(node.orelse))
        if isinstance(node, (ast.Tuple, ast.List)):
            return any(self.expr(e) for e in node.elts)
        if isinstance(node, ast.Starred):
            return self.expr(node.value)
        return False

    def assign_target(self, target: ast.AST, tainted: bool) -> None:
        if isinstance(target, ast.Name):
            if tainted:
                self.tainted.add(target.id)
            else:
                self.tainted.discard(target.id)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for elt in target.elts:
                self.assign_target(elt, tainted)
        elif isinstance(target, ast.Starred):
            self.assign_target(target.value, tainted)


def _fn_params(fn) -> List[Tuple[int, str, Optional[ast.AST]]]:
    a = fn.args
    params = [*a.posonlyargs, *a.args]
    defaults: List[Optional[ast.AST]] = \
        [None] * (len(params) - len(a.defaults)) + list(a.defaults)
    out = [(i, p.arg, d) for i, (p, d) in enumerate(zip(params, defaults))]
    out += [(None, p.arg, d)
            for p, d in zip(a.kwonlyargs, a.kw_defaults)]
    return out


def _check_jit_body(fn, scope: _TaintScope, mod: ParsedModule,
                    findings: List[Finding]) -> None:
    def flag(node, msg):
        findings.append(Finding(mod.rel, node.lineno, node.col_offset,
                                "RPL001", msg))

    def walk(stmts, scope):
        for st in stmts:
            if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef)):
                inner = _TaintScope(scope.tainted)
                for _, name, _ in _fn_params(st):
                    inner.tainted.add(name)    # nested defs are traced too
                walk(st.body, inner)
                continue
            for node in ast.walk(st):
                if isinstance(node, ast.Call):
                    tail = _attr_tail(node.func)
                    if tail in ("int", "float", "bool") and node.args and \
                            isinstance(node.func, ast.Name) and \
                            scope.expr(node.args[0]):
                        flag(node, f"`{tail}()` on a tracer-derived value "
                                   "inside a jit-traced function forces a "
                                   "trace-time concretization error or a "
                                   "silent per-value retrace")
                    if isinstance(node.func, ast.Attribute) and \
                            node.func.attr == "item" and not node.args and \
                            scope.expr(node.func.value):
                        flag(node, "`.item()` on a tracer-derived value "
                                   "inside a jit-traced function")
            if isinstance(st, (ast.If, ast.While)):
                if scope.expr(st.test):
                    kind = "if" if isinstance(st, ast.If) else "while"
                    flag(st, f"Python `{kind}` on a tracer-derived value "
                             "inside a jit-traced function (use jnp.where/"
                             "lax.cond, or hoist to a static arg)")
                walk(st.body, scope)
                walk(st.orelse, scope)
            elif isinstance(st, ast.For):
                if scope.expr(st.iter):
                    flag(st, "Python `for` over a tracer-derived value "
                             "inside a jit-traced function (loop bounds "
                             "must be static; use lax.scan/fori_loop)")
                walk(st.body, scope)
                walk(st.orelse, scope)
            elif isinstance(st, (ast.Assign,)):
                tainted = scope.expr(st.value)
                for t in st.targets:
                    scope.assign_target(t, tainted)
            elif isinstance(st, ast.AnnAssign) and st.value is not None:
                scope.assign_target(st.target, scope.expr(st.value))
            elif isinstance(st, ast.AugAssign):
                if scope.expr(st.value):
                    scope.assign_target(st.target, True)
            elif isinstance(st, (ast.With,)):
                walk(st.body, scope)
            elif isinstance(st, ast.Try):
                walk(st.body, scope)
                for h in st.handlers:
                    walk(h.body, scope)
                walk(st.orelse, scope)
                walk(st.finalbody, scope)

    walk(fn.body if not isinstance(fn, ast.Lambda) else [], scope)


def rule_rpl001(mod: ParsedModule, ctx: Context) -> List[Finding]:
    findings: List[Finding] = []
    for root in _collect_jit_roots(mod):
        fn = root.fn
        if isinstance(fn, ast.Lambda):
            continue                      # no statements to mis-branch on
        tainted: Set[str] = set()
        for pos, name, default in _fn_params(fn):
            static = name in root.static_names or \
                (pos is not None and pos in root.static_nums)
            if static:
                if isinstance(default, (ast.List, ast.Dict, ast.Set)):
                    findings.append(Finding(
                        mod.rel, default.lineno, default.col_offset,
                        "RPL001",
                        f"static jit arg `{name}` has a non-hashable "
                        "(mutable) default: jit static args must be "
                        "hashable or every call re-traces"))
            else:
                tainted.add(name)
        _check_jit_body(fn, _TaintScope(tainted), mod, findings)
    return findings


# ---------------------------------------------------------------------------
# RPL002 — kernel contract (global rule)
# ---------------------------------------------------------------------------

_KERNEL_EXEMPT = {"policy", "ref", "ops", "__init__"}


def _load_registry(policy_mod: ParsedModule):
    for node in ast.walk(policy_mod.tree):
        if isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name) and t.id == "KERNEL_REGISTRY":
                    try:
                        return ast.literal_eval(node.value), node.lineno
                    except ValueError:
                        return None, node.lineno
    return None, 1


def _module_has(mod: ParsedModule, pred) -> bool:
    return any(pred(n) for n in ast.walk(mod.tree))


def rule_rpl002(ctx: Context) -> List[Finding]:
    findings: List[Finding] = []
    ref_defs_cache: Dict[pathlib.Path, Set[str]] = {}

    def sibling(mod: ParsedModule, stem: str) -> Optional[ParsedModule]:
        path = mod.path.parent / f"{stem}.py"
        key = str(path)
        if key in ctx.modules:
            return ctx.modules[key]
        if path.exists():
            from repro.analysis.core import parse_file
            return parse_file(path, ctx.root)
        return None

    for mod in list(ctx.modules.values()):
        if mod.path.parent.name != "kernels" or \
                mod.path.stem in _KERNEL_EXEMPT:
            continue
        calls = [n for n in ast.walk(mod.tree)
                 if isinstance(n, ast.Call)
                 and _attr_tail(n.func) == "pallas_call"]
        if not calls:
            continue
        at = calls[0]
        policy = sibling(mod, "policy")
        if policy is None:
            findings.append(Finding(mod.rel, at.lineno, at.col_offset,
                                    "RPL002",
                                    "pallas_call with no kernels/policy.py "
                                    "to hold the KERNEL_REGISTRY entry"))
            continue
        registry, reg_line = _load_registry(policy)
        if registry is None:
            findings.append(Finding(policy.rel, reg_line, 0, "RPL002",
                                    "KERNEL_REGISTRY missing or not a pure "
                                    "dict literal in kernels/policy.py"))
            continue
        entry = registry.get(mod.path.stem)
        if entry is None:
            findings.append(Finding(
                mod.rel, at.lineno, at.col_offset, "RPL002",
                f"pallas_call site `{mod.path.stem}` has no "
                "KERNEL_REGISTRY entry in kernels/policy.py (every "
                "kernel needs a ref twin + interpret-parity test)"))
            continue
        missing = {"ref", "test", "shape_guard"} - set(entry)
        if missing:
            findings.append(Finding(
                policy.rel, reg_line, 0, "RPL002",
                f"KERNEL_REGISTRY[{mod.path.stem!r}] missing keys: "
                f"{sorted(missing)}"))
            continue
        ref_mod = sibling(mod, "ref")
        ref_path = mod.path.parent / "ref.py"
        if ref_path not in ref_defs_cache:
            ref_defs_cache[ref_path] = set() if ref_mod is None else {
                n.name for n in ast.walk(ref_mod.tree)
                if isinstance(n, ast.FunctionDef)}
        refs = entry["ref"] if isinstance(entry["ref"], (list, tuple)) \
            else [entry["ref"]]
        for ref_name in refs:
            if ref_name not in ref_defs_cache[ref_path]:
                findings.append(Finding(
                    mod.rel, at.lineno, at.col_offset, "RPL002",
                    f"registered ref twin `{ref_name}` is not defined in "
                    "kernels/ref.py"))
        test_path = ctx.root / entry["test"]
        if not test_path.exists():
            findings.append(Finding(
                mod.rel, at.lineno, at.col_offset, "RPL002",
                f"registered parity test `{entry['test']}` does not exist"))
        else:
            text = test_path.read_text()
            if mod.path.stem not in text and \
                    not any(r in text for r in refs):
                findings.append(Finding(
                    mod.rel, at.lineno, at.col_offset, "RPL002",
                    f"parity test `{entry['test']}` references neither "
                    f"`{mod.path.stem}` nor its ref twin"))
        guard = entry["shape_guard"]
        if guard == "checked":
            if not _module_has(mod, lambda n: isinstance(n, ast.Mod)):
                findings.append(Finding(
                    mod.rel, at.lineno, at.col_offset, "RPL002",
                    "shape_guard declared 'checked' but the module has no "
                    "divisibility (%) check guarding its grid/BlockSpec "
                    "assumptions"))
        elif not (isinstance(guard, str) and guard.startswith("fallback:")):
            findings.append(Finding(
                policy.rel, reg_line, 0, "RPL002",
                f"KERNEL_REGISTRY[{mod.path.stem!r}] shape_guard must be "
                "'checked' or a documented 'fallback: ...' note"))
    return findings


# ---------------------------------------------------------------------------
# RPL003 — engine-state aliasing
# ---------------------------------------------------------------------------

# attributes holding (or caching) engine/slot state arrays —
# `_prepared` (sharded int8 weight shards) and `_slot_steps` (per-slot
# step counters) joined with the 2D-mesh sharded engine step
_STATE_ATTRS = {"result", "_slot_bufs", "_beam", "_stream_state", "_gen",
                "_tokens", "cache", "_prepared", "_slot_steps",
                "_fault_log"}   # _fault_log: per-engine fault forensics
                                # (PR 9 quarantine layer)
# engine receivers state may hang off
_ENGINE_NAMES = {"self", "eng", "engine", "sess", "session"}
# engine methods whose return values are materialized views over
# engine-owned buffers: callers must route them through copy_result
_READOUT_CALLS = {"slot_best"}
# calls that SANITIZE (deep-copy) a tainted payload
_SANITIZERS = {"copy_result", "deepcopy", "list", "jsonable", "copy"}


def _receiver_ok(node: ast.AST) -> bool:
    root = _attr_root(node)
    return root in _ENGINE_NAMES or (
        isinstance(node, ast.Attribute) and "engine" in node.attr)


class _AliasScope(_TaintScope):
    def expr(self, node: ast.AST) -> bool:       # noqa: C901 - small DFA
        if isinstance(node, ast.Name):
            return node.id in self.tainted
        if isinstance(node, ast.Attribute):
            if node.attr in _STATE_ATTRS and _receiver_ok(node.value):
                return True
            return self.expr(node.value)
        if isinstance(node, ast.Subscript):
            return self.expr(node.value)
        if isinstance(node, ast.Call):
            tail = _attr_tail(node.func)
            if tail in _SANITIZERS:
                return False
            if tail in _READOUT_CALLS:
                return True
            if tail == "dict":                   # shallow: aliasing survives
                return any(self.expr(a) for a in node.args) or \
                    any(self.expr(kw.value) for kw in node.keywords)
            return False
        if isinstance(node, ast.Dict):
            return any(v is not None and self.expr(v) for v in node.values)
        if isinstance(node, (ast.Tuple, ast.List)):
            return any(self.expr(e) for e in node.elts)
        if isinstance(node, ast.IfExp):
            return self.expr(node.body) or self.expr(node.orelse)
        if isinstance(node, ast.BoolOp):
            return any(self.expr(v) for v in node.values)
        return False


def rule_rpl003(mod: ParsedModule, ctx: Context) -> List[Finding]:
    findings: List[Finding] = []
    for fn in ast.walk(mod.tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        scope = _AliasScope(set())
        for st in ast.walk(fn):
            if isinstance(st, ast.Assign):
                tainted = scope.expr(st.value)
                for t in st.targets:
                    scope.assign_target(t, tainted)
            elif isinstance(st, ast.Return) and st.value is not None:
                if scope.expr(st.value):
                    findings.append(Finding(
                        mod.rel, st.lineno, st.col_offset, "RPL003",
                        f"`{fn.name}` returns a payload aliasing engine "
                        "slot state without routing through copy_result "
                        "(caller mutation corrupts, or read-only views "
                        "escape, the engine's stored results)"))
            elif isinstance(st, ast.Call) and \
                    _attr_tail(st.func) == "set_result" and st.args and \
                    scope.expr(st.args[0]):
                findings.append(Finding(
                    mod.rel, st.lineno, st.col_offset, "RPL003",
                    "future resolved with a payload aliasing engine slot "
                    "state: route it through copy_result first"))
    return findings


# ---------------------------------------------------------------------------
# RPL004 — thread discipline
# ---------------------------------------------------------------------------

# sync functions that ALSO run on the event-loop thread (not the
# engine worker): supervisor / watchdog / health entry points, matched
# by name.  They observe, abandon, and restart workers, so a direct
# @worker_only call from one of them is the same cross-thread race an
# asyncio handler would have.
_LOOP_SIDE_NAMES = ("supervis", "watchdog", "healthz")


def rule_rpl004(mod: ParsedModule, ctx: Context) -> List[Finding]:
    if not ctx.worker_only_names:
        return []
    findings: List[Finding] = []

    def scan(node: ast.AST, in_lambda: bool, where: str):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.Lambda):
                scan(child, True, where)
                continue
            if isinstance(child, ast.Call) and not in_lambda:
                tail = _attr_tail(child.func)
                if isinstance(child.func, ast.Attribute) and \
                        tail in ctx.worker_only_names:
                    findings.append(Finding(
                        mod.rel, child.lineno, child.col_offset, "RPL004",
                        f"@worker_only engine method `{tail}` called from "
                        f"{where}: only the engine's "
                        "EngineWorker thread may drive it — submit a "
                        "thunk via worker.call/submit instead"))
            scan(child, in_lambda, where)

    for fn in ast.walk(mod.tree):
        if isinstance(fn, ast.AsyncFunctionDef):
            scan(fn, False, "an asyncio handler")
        elif isinstance(fn, ast.FunctionDef) and \
                any(k in fn.name.lower() for k in _LOOP_SIDE_NAMES):
            scan(fn, False, f"supervisor/watchdog entry point `{fn.name}`")
    return findings


# ---------------------------------------------------------------------------
# RPL005 — RNG discipline
# ---------------------------------------------------------------------------

def rule_rpl005(mod: ParsedModule, ctx: Context) -> List[Finding]:
    calls = [n for n in ast.walk(mod.tree) if isinstance(n, ast.Call)]
    # sharded compute in this module: a jit with explicit shardings, or
    # a shard_map call (the serving engines' 2D ('data','model') step —
    # mesh-dependent RNG would fork per data shard just like it forked
    # per topology in the PR 5 elastic-restart bug)
    has_sharded = any(
        (any(kw.arg in ("out_shardings", "in_shardings")
             for kw in c.keywords) and _attr_tail(c.func) in _JIT_WRAPPERS)
        or _attr_tail(c.func) == "shard_map"
        for c in calls)
    if not has_sharded:
        return []
    key_calls = [c for c in calls if _attr_tail(c.func) == "PRNGKey"]
    if not key_calls:
        return []
    if any(_attr_tail(c.func) == "mesh_invariant_rng" for c in calls):
        return []
    return [Finding(
        mod.rel, c.lineno, c.col_offset, "RPL005",
        "PRNGKey in a module that runs sharded compute (jit with "
        "out_shardings, or shard_map) but never calls "
        "mesh_invariant_rng(): legacy threefry makes the generated "
        "values depend on the mesh — elastic restarts on a different "
        "topology silently fork the trajectory (PR 5 bug), and a "
        "('data','model') serving mesh would fork it per data shard")
        for c in key_calls]


PER_FILE_RULES = {
    "RPL001": rule_rpl001,
    "RPL003": rule_rpl003,
    "RPL004": rule_rpl004,
    "RPL005": rule_rpl005,
}

GLOBAL_RULES = {
    "RPL002": rule_rpl002,
}


def iter_rule_codes() -> Iterable[str]:
    yield from PER_FILE_RULES
    yield from GLOBAL_RULES
