"""The repro-lint rules (see repro.analysis.__doc__ for the codes).

RPL001–005 are call-graph-LOCAL: they resolve names within one module
(plus the declared cross-file anchors — the kernel registry in
kernels/policy.py, `@worker_only` decorators, registry-named test
files).  RPL006–008 are interprocedural: they run over the whole-project
symbol table + call graph in `analysis/callgraph.py` with the bounded
two-level summaries in `analysis/interproc.py` (may-raise, collectives,
PartitionSpec literals, axis-name value sets).  The bound is the
contract: anything the two-level inlining cannot resolve is "unknown"
and unknown is never flagged, so adding reach never adds guesswork.
Contracts that still need runtime observation keep their guard in
`repro.analysis.guards`.
"""
from __future__ import annotations

import ast
import pathlib
from typing import Dict, Iterable, List, Optional, Set, Tuple

from repro.analysis.core import Context, Finding, ParsedModule

# attribute reads that yield STATIC Python values even on a tracer
_SHAPE_ATTRS = {"shape", "ndim", "dtype", "size", "aval", "sharding"}
# module roots whose calls produce tracer values inside a jit trace
_ARRAY_ROOTS = {"jnp", "jax", "lax"}
_JIT_WRAPPERS = {"jit"}                 # jax.jit / compat aliases
_TRACE_CONSUMERS = {                    # callable-arg positions traced by jax
    "jit": (0,), "shard_map": (0,), "scan": (0,), "vmap": (0,),
    "pallas_call": (0,), "while_loop": (0, 1), "fori_loop": (2,),
    "checkpoint": (0,), "remat": (0,), "grad": (0,), "value_and_grad": (0,),
}


def _attr_tail(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return None


def _attr_root(node: ast.AST) -> Optional[str]:
    while isinstance(node, ast.Attribute):
        node = node.value
    return node.id if isinstance(node, ast.Name) else None


def _const_strs(node: ast.AST) -> List[str]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return [node.value]
    if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
        return [s for elt in node.elts for s in _const_strs(elt)]
    return []


# ---------------------------------------------------------------------------
# RPL001 — jit hazards
# ---------------------------------------------------------------------------

class _JitRoot:
    def __init__(self, fn, static_names: Set[str], static_nums: Set[int]):
        self.fn = fn
        self.static_names = static_names
        self.static_nums = static_nums


def _jit_call_info(call: ast.Call) -> Tuple[Set[str], Set[int]]:
    names: Set[str] = set()
    nums: Set[int] = set()
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            names |= set(_const_strs(kw.value))
        elif kw.arg == "static_argnums":
            if isinstance(kw.value, ast.Constant) and \
                    isinstance(kw.value.value, int):
                nums.add(kw.value.value)
            elif isinstance(kw.value, (ast.Tuple, ast.List)):
                nums |= {e.value for e in kw.value.elts
                         if isinstance(e, ast.Constant)
                         and isinstance(e.value, int)}
    return names, nums


def _decorator_jit(deco: ast.AST) -> Optional[Tuple[Set[str], Set[int]]]:
    """(static_argnames, static_argnums) if `deco` is a jit decorator:
    @jax.jit, @jit, @partial(jax.jit, ...), @functools.partial(jax.jit)."""
    if _attr_tail(deco) in _JIT_WRAPPERS:
        return set(), set()
    if isinstance(deco, ast.Call):
        tail = _attr_tail(deco.func)
        if tail in _JIT_WRAPPERS:
            return _jit_call_info(deco)
        if tail == "partial" and deco.args and \
                _attr_tail(deco.args[0]) in _JIT_WRAPPERS:
            return _jit_call_info(deco)
    return None


def _collect_jit_roots(mod: ParsedModule) -> List[_JitRoot]:
    """Functions traced by jax, resolved module-locally: jit-decorated
    defs, plus defs/lambdas whose NAME is passed to a trace-consuming
    call (jax.jit(step), shard_map(step, ...), lax.scan(body, ...))."""
    defs: Dict[str, List[ast.AST]] = {}
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.FunctionDef):
            defs.setdefault(node.name, []).append(node)

    roots: List[_JitRoot] = []
    seen: Set[ast.AST] = set()

    def add(fn, names=frozenset(), nums=frozenset()):
        if fn not in seen:
            seen.add(fn)
            roots.append(_JitRoot(fn, set(names), set(nums)))

    for node in ast.walk(mod.tree):
        if isinstance(node, ast.FunctionDef):
            for deco in node.decorator_list:
                info = _decorator_jit(deco)
                if info is not None:
                    add(node, *info)
        if isinstance(node, ast.Call):
            tail = _attr_tail(node.func)
            if tail not in _TRACE_CONSUMERS:
                continue
            static = _jit_call_info(node) if tail in _JIT_WRAPPERS \
                else (set(), set())
            for pos in _TRACE_CONSUMERS[tail]:
                if pos >= len(node.args):
                    continue
                arg = node.args[pos]
                if isinstance(arg, ast.Lambda):
                    add(arg, *static)
                elif isinstance(arg, ast.Name):
                    for fn in defs.get(arg.id, []):
                        add(fn, *static)
    return roots


class _TaintScope:
    """Conservative intra-function tracer taint: which local names may
    hold tracers at trace time."""

    def __init__(self, tainted: Set[str]):
        self.tainted = set(tainted)

    def expr(self, node: ast.AST) -> bool:
        if isinstance(node, ast.Name):
            return node.id in self.tainted
        if isinstance(node, ast.Attribute):
            if node.attr in _SHAPE_ATTRS:
                return False
            return self.expr(node.value)
        if isinstance(node, ast.Subscript):
            return self.expr(node.value) or self.expr(node.slice)
        if isinstance(node, ast.Call):
            tail = _attr_tail(node.func)
            if tail == "len":
                return False               # static under tracing
            if isinstance(node.func, ast.Attribute):
                root = _attr_root(node.func)
                if root in _ARRAY_ROOTS:
                    return True            # jnp./jax.lax. op -> tracer
                return self.expr(node.func.value)   # x.sum() on a tracer
            if tail == "range":
                return any(self.expr(a) for a in node.args)
            return False
        if isinstance(node, (ast.BinOp,)):
            return self.expr(node.left) or self.expr(node.right)
        if isinstance(node, ast.UnaryOp):
            return self.expr(node.operand)
        if isinstance(node, ast.BoolOp):
            return any(self.expr(v) for v in node.values)
        if isinstance(node, ast.Compare):
            # `x is None` / `x is not None` is a static structural check
            # even when x may hold a tracer — identity against None is
            # resolved at trace time, never on device values.
            if all(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops) \
                    and all(isinstance(c, ast.Constant) and c.value is None
                            for c in node.comparators):
                return False
            return self.expr(node.left) or \
                any(self.expr(c) for c in node.comparators)
        if isinstance(node, ast.IfExp):
            return (self.expr(node.body) or self.expr(node.test)
                    or self.expr(node.orelse))
        if isinstance(node, (ast.Tuple, ast.List)):
            return any(self.expr(e) for e in node.elts)
        if isinstance(node, ast.Starred):
            return self.expr(node.value)
        return False

    def assign_target(self, target: ast.AST, tainted: bool) -> None:
        if isinstance(target, ast.Name):
            if tainted:
                self.tainted.add(target.id)
            else:
                self.tainted.discard(target.id)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for elt in target.elts:
                self.assign_target(elt, tainted)
        elif isinstance(target, ast.Starred):
            self.assign_target(target.value, tainted)


def _fn_params(fn) -> List[Tuple[int, str, Optional[ast.AST]]]:
    a = fn.args
    params = [*a.posonlyargs, *a.args]
    defaults: List[Optional[ast.AST]] = \
        [None] * (len(params) - len(a.defaults)) + list(a.defaults)
    out = [(i, p.arg, d) for i, (p, d) in enumerate(zip(params, defaults))]
    out += [(None, p.arg, d)
            for p, d in zip(a.kwonlyargs, a.kw_defaults)]
    return out


def _check_jit_body(fn, scope: _TaintScope, mod: ParsedModule,
                    findings: List[Finding]) -> None:
    def flag(node, msg):
        findings.append(Finding(mod.rel, node.lineno, node.col_offset,
                                "RPL001", msg))

    def walk(stmts, scope):
        for st in stmts:
            if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef)):
                inner = _TaintScope(scope.tainted)
                for _, name, _ in _fn_params(st):
                    inner.tainted.add(name)    # nested defs are traced too
                walk(st.body, inner)
                continue
            for node in ast.walk(st):
                if isinstance(node, ast.Call):
                    tail = _attr_tail(node.func)
                    if tail in ("int", "float", "bool") and node.args and \
                            isinstance(node.func, ast.Name) and \
                            scope.expr(node.args[0]):
                        flag(node, f"`{tail}()` on a tracer-derived value "
                                   "inside a jit-traced function forces a "
                                   "trace-time concretization error or a "
                                   "silent per-value retrace")
                    if isinstance(node.func, ast.Attribute) and \
                            node.func.attr == "item" and not node.args and \
                            scope.expr(node.func.value):
                        flag(node, "`.item()` on a tracer-derived value "
                                   "inside a jit-traced function")
            if isinstance(st, (ast.If, ast.While)):
                if scope.expr(st.test):
                    kind = "if" if isinstance(st, ast.If) else "while"
                    flag(st, f"Python `{kind}` on a tracer-derived value "
                             "inside a jit-traced function (use jnp.where/"
                             "lax.cond, or hoist to a static arg)")
                walk(st.body, scope)
                walk(st.orelse, scope)
            elif isinstance(st, ast.For):
                if scope.expr(st.iter):
                    flag(st, "Python `for` over a tracer-derived value "
                             "inside a jit-traced function (loop bounds "
                             "must be static; use lax.scan/fori_loop)")
                walk(st.body, scope)
                walk(st.orelse, scope)
            elif isinstance(st, (ast.Assign,)):
                tainted = scope.expr(st.value)
                for t in st.targets:
                    scope.assign_target(t, tainted)
            elif isinstance(st, ast.AnnAssign) and st.value is not None:
                scope.assign_target(st.target, scope.expr(st.value))
            elif isinstance(st, ast.AugAssign):
                if scope.expr(st.value):
                    scope.assign_target(st.target, True)
            elif isinstance(st, (ast.With,)):
                walk(st.body, scope)
            elif isinstance(st, ast.Try):
                walk(st.body, scope)
                for h in st.handlers:
                    walk(h.body, scope)
                walk(st.orelse, scope)
                walk(st.finalbody, scope)

    walk(fn.body if not isinstance(fn, ast.Lambda) else [], scope)


def rule_rpl001(mod: ParsedModule, ctx: Context) -> List[Finding]:
    findings: List[Finding] = []
    for root in _collect_jit_roots(mod):
        fn = root.fn
        if isinstance(fn, ast.Lambda):
            continue                      # no statements to mis-branch on
        tainted: Set[str] = set()
        for pos, name, default in _fn_params(fn):
            static = name in root.static_names or \
                (pos is not None and pos in root.static_nums)
            if static:
                if isinstance(default, (ast.List, ast.Dict, ast.Set)):
                    findings.append(Finding(
                        mod.rel, default.lineno, default.col_offset,
                        "RPL001",
                        f"static jit arg `{name}` has a non-hashable "
                        "(mutable) default: jit static args must be "
                        "hashable or every call re-traces"))
            else:
                tainted.add(name)
        _check_jit_body(fn, _TaintScope(tainted), mod, findings)
    return findings


# ---------------------------------------------------------------------------
# RPL002 — kernel contract (global rule)
# ---------------------------------------------------------------------------

_KERNEL_EXEMPT = {"policy", "ref", "ops", "__init__"}


def _load_registry(policy_mod: ParsedModule):
    for node in ast.walk(policy_mod.tree):
        if isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name) and t.id == "KERNEL_REGISTRY":
                    try:
                        return ast.literal_eval(node.value), node.lineno
                    except ValueError:
                        return None, node.lineno
    return None, 1


def _module_has(mod: ParsedModule, pred) -> bool:
    return any(pred(n) for n in ast.walk(mod.tree))


def rule_rpl002(ctx: Context) -> List[Finding]:
    findings: List[Finding] = []
    ref_defs_cache: Dict[pathlib.Path, Set[str]] = {}

    def sibling(mod: ParsedModule, stem: str) -> Optional[ParsedModule]:
        path = mod.path.parent / f"{stem}.py"
        key = str(path)
        if key in ctx.modules:
            return ctx.modules[key]
        if path.exists():
            from repro.analysis.core import parse_file
            return parse_file(path, ctx.root)
        return None

    for mod in list(ctx.modules.values()):
        if mod.path.parent.name != "kernels" or \
                mod.path.stem in _KERNEL_EXEMPT:
            continue
        calls = [n for n in ast.walk(mod.tree)
                 if isinstance(n, ast.Call)
                 and _attr_tail(n.func) == "pallas_call"]
        if not calls:
            continue
        at = calls[0]
        policy = sibling(mod, "policy")
        if policy is None:
            findings.append(Finding(mod.rel, at.lineno, at.col_offset,
                                    "RPL002",
                                    "pallas_call with no kernels/policy.py "
                                    "to hold the KERNEL_REGISTRY entry"))
            continue
        registry, reg_line = _load_registry(policy)
        if registry is None:
            findings.append(Finding(policy.rel, reg_line, 0, "RPL002",
                                    "KERNEL_REGISTRY missing or not a pure "
                                    "dict literal in kernels/policy.py"))
            continue
        entry = registry.get(mod.path.stem)
        if entry is None:
            findings.append(Finding(
                mod.rel, at.lineno, at.col_offset, "RPL002",
                f"pallas_call site `{mod.path.stem}` has no "
                "KERNEL_REGISTRY entry in kernels/policy.py (every "
                "kernel needs a ref twin + interpret-parity test)"))
            continue
        missing = {"ref", "test", "shape_guard"} - set(entry)
        if missing:
            findings.append(Finding(
                policy.rel, reg_line, 0, "RPL002",
                f"KERNEL_REGISTRY[{mod.path.stem!r}] missing keys: "
                f"{sorted(missing)}"))
            continue
        ref_mod = sibling(mod, "ref")
        ref_path = mod.path.parent / "ref.py"
        if ref_path not in ref_defs_cache:
            ref_defs_cache[ref_path] = set() if ref_mod is None else {
                n.name for n in ast.walk(ref_mod.tree)
                if isinstance(n, ast.FunctionDef)}
        refs = entry["ref"] if isinstance(entry["ref"], (list, tuple)) \
            else [entry["ref"]]
        for ref_name in refs:
            if ref_name not in ref_defs_cache[ref_path]:
                findings.append(Finding(
                    mod.rel, at.lineno, at.col_offset, "RPL002",
                    f"registered ref twin `{ref_name}` is not defined in "
                    "kernels/ref.py"))
        test_path = ctx.root / entry["test"]
        if not test_path.exists():
            findings.append(Finding(
                mod.rel, at.lineno, at.col_offset, "RPL002",
                f"registered parity test `{entry['test']}` does not exist"))
        else:
            text = test_path.read_text()
            if mod.path.stem not in text and \
                    not any(r in text for r in refs):
                findings.append(Finding(
                    mod.rel, at.lineno, at.col_offset, "RPL002",
                    f"parity test `{entry['test']}` references neither "
                    f"`{mod.path.stem}` nor its ref twin"))
        guard = entry["shape_guard"]
        if guard == "checked":
            if not _module_has(mod, lambda n: isinstance(n, ast.Mod)):
                findings.append(Finding(
                    mod.rel, at.lineno, at.col_offset, "RPL002",
                    "shape_guard declared 'checked' but the module has no "
                    "divisibility (%) check guarding its grid/BlockSpec "
                    "assumptions"))
        elif not (isinstance(guard, str) and guard.startswith("fallback:")):
            findings.append(Finding(
                policy.rel, reg_line, 0, "RPL002",
                f"KERNEL_REGISTRY[{mod.path.stem!r}] shape_guard must be "
                "'checked' or a documented 'fallback: ...' note"))
    return findings


# ---------------------------------------------------------------------------
# RPL003 — engine-state aliasing
# ---------------------------------------------------------------------------

# attributes holding (or caching) engine/slot state arrays —
# `_prepared` (sharded int8 weight shards) and `_slot_steps` (per-slot
# step counters) joined with the 2D-mesh sharded engine step
_STATE_ATTRS = {"result", "_slot_bufs", "_beam", "_stream_state", "_gen",
                "_tokens", "cache", "_prepared", "_slot_steps",
                "_fault_log"}   # _fault_log: per-engine fault forensics
                                # (PR 9 quarantine layer)
# engine receivers state may hang off
_ENGINE_NAMES = {"self", "eng", "engine", "sess", "session"}
# engine methods whose return values are materialized views over
# engine-owned buffers: callers must route them through copy_result
_READOUT_CALLS = {"slot_best"}
# calls that SANITIZE (deep-copy) a tainted payload
_SANITIZERS = {"copy_result", "deepcopy", "list", "jsonable", "copy"}


def _receiver_ok(node: ast.AST) -> bool:
    root = _attr_root(node)
    return root in _ENGINE_NAMES or (
        isinstance(node, ast.Attribute) and "engine" in node.attr)


class _AliasScope(_TaintScope):
    def expr(self, node: ast.AST) -> bool:       # noqa: C901 - small DFA
        if isinstance(node, ast.Name):
            return node.id in self.tainted
        if isinstance(node, ast.Attribute):
            if node.attr in _STATE_ATTRS and _receiver_ok(node.value):
                return True
            return self.expr(node.value)
        if isinstance(node, ast.Subscript):
            return self.expr(node.value)
        if isinstance(node, ast.Call):
            tail = _attr_tail(node.func)
            if tail in _SANITIZERS:
                return False
            if tail in _READOUT_CALLS:
                return True
            if tail == "dict":                   # shallow: aliasing survives
                return any(self.expr(a) for a in node.args) or \
                    any(self.expr(kw.value) for kw in node.keywords)
            return False
        if isinstance(node, ast.Dict):
            return any(v is not None and self.expr(v) for v in node.values)
        if isinstance(node, (ast.Tuple, ast.List)):
            return any(self.expr(e) for e in node.elts)
        if isinstance(node, ast.IfExp):
            return self.expr(node.body) or self.expr(node.orelse)
        if isinstance(node, ast.BoolOp):
            return any(self.expr(v) for v in node.values)
        return False


def rule_rpl003(mod: ParsedModule, ctx: Context) -> List[Finding]:
    findings: List[Finding] = []
    for fn in ast.walk(mod.tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        scope = _AliasScope(set())
        for st in ast.walk(fn):
            if isinstance(st, ast.Assign):
                tainted = scope.expr(st.value)
                for t in st.targets:
                    scope.assign_target(t, tainted)
            elif isinstance(st, ast.Return) and st.value is not None:
                if scope.expr(st.value):
                    findings.append(Finding(
                        mod.rel, st.lineno, st.col_offset, "RPL003",
                        f"`{fn.name}` returns a payload aliasing engine "
                        "slot state without routing through copy_result "
                        "(caller mutation corrupts, or read-only views "
                        "escape, the engine's stored results)"))
            elif isinstance(st, ast.Call) and \
                    _attr_tail(st.func) == "set_result" and st.args and \
                    scope.expr(st.args[0]):
                findings.append(Finding(
                    mod.rel, st.lineno, st.col_offset, "RPL003",
                    "future resolved with a payload aliasing engine slot "
                    "state: route it through copy_result first"))
    return findings


# ---------------------------------------------------------------------------
# RPL004 — thread discipline
# ---------------------------------------------------------------------------

# sync functions that ALSO run on the event-loop thread (not the
# engine worker): supervisor / watchdog / health entry points, matched
# by name.  They observe, abandon, and restart workers, so a direct
# @worker_only call from one of them is the same cross-thread race an
# asyncio handler would have.
_LOOP_SIDE_NAMES = ("supervis", "watchdog", "healthz")


def rule_rpl004(mod: ParsedModule, ctx: Context) -> List[Finding]:
    if not ctx.worker_only_names:
        return []
    findings: List[Finding] = []

    def scan(node: ast.AST, in_lambda: bool, where: str):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.Lambda):
                scan(child, True, where)
                continue
            if isinstance(child, ast.Call) and not in_lambda:
                tail = _attr_tail(child.func)
                if isinstance(child.func, ast.Attribute) and \
                        tail in ctx.worker_only_names:
                    findings.append(Finding(
                        mod.rel, child.lineno, child.col_offset, "RPL004",
                        f"@worker_only engine method `{tail}` called from "
                        f"{where}: only the engine's "
                        "EngineWorker thread may drive it — submit a "
                        "thunk via worker.call/submit instead"))
            scan(child, in_lambda, where)

    for fn in ast.walk(mod.tree):
        if isinstance(fn, ast.AsyncFunctionDef):
            scan(fn, False, "an asyncio handler")
        elif isinstance(fn, ast.FunctionDef) and \
                any(k in fn.name.lower() for k in _LOOP_SIDE_NAMES):
            scan(fn, False, f"supervisor/watchdog entry point `{fn.name}`")
    return findings


# ---------------------------------------------------------------------------
# RPL005 — RNG discipline
# ---------------------------------------------------------------------------

def rule_rpl005(mod: ParsedModule, ctx: Context) -> List[Finding]:
    calls = [n for n in ast.walk(mod.tree) if isinstance(n, ast.Call)]
    # sharded compute in this module: a jit with explicit shardings, or
    # a shard_map call (the serving engines' 2D ('data','model') step —
    # mesh-dependent RNG would fork per data shard just like it forked
    # per topology in the PR 5 elastic-restart bug)
    has_sharded = any(
        (any(kw.arg in ("out_shardings", "in_shardings")
             for kw in c.keywords) and _attr_tail(c.func) in _JIT_WRAPPERS)
        or _attr_tail(c.func) == "shard_map"
        for c in calls)
    if not has_sharded:
        return []
    key_calls = [c for c in calls if _attr_tail(c.func) == "PRNGKey"]
    if not key_calls:
        return []
    if any(_attr_tail(c.func) == "mesh_invariant_rng" for c in calls):
        return []
    return [Finding(
        mod.rel, c.lineno, c.col_offset, "RPL005",
        "PRNGKey in a module that runs sharded compute (jit with "
        "out_shardings, or shard_map) but never calls "
        "mesh_invariant_rng(): legacy threefry makes the generated "
        "values depend on the mesh — elastic restarts on a different "
        "topology silently fork the trajectory (PR 5 bug), and a "
        "('data','model') serving mesh would fork it per data shard")
        for c in key_calls]


# ---------------------------------------------------------------------------
# RPL006 — collective/axis discipline (interprocedural)
# ---------------------------------------------------------------------------

def _guarded_axes(fi, index) -> Set[str]:
    """Axis names `fi` checks against `mesh.axis_names` before use:
    `"model" in mesh.axis_names`, or a comprehension filtering a
    constant iterable through such a membership test."""
    guarded: Set[str] = set()
    comp_iters: Dict[str, List[ast.expr]] = {}
    for n in index.owned(fi):
        if isinstance(n, (ast.GeneratorExp, ast.ListComp, ast.SetComp)):
            for gen in n.generators:
                if isinstance(gen.target, ast.Name):
                    comp_iters.setdefault(gen.target.id, []) \
                        .append(gen.iter)
    for n in index.owned(fi):
        if not (isinstance(n, ast.Compare) and len(n.ops) == 1
                and isinstance(n.ops[0], (ast.In, ast.NotIn))):
            continue
        if not any(isinstance(a, ast.Attribute)
                   and a.attr == "axis_names"
                   for a in ast.walk(n.comparators[0])):
            continue
        guarded |= set(_const_strs(n.left))
        if isinstance(n.left, ast.Name):
            for it in comp_iters.get(n.left.id, []):
                guarded |= set(_const_strs(it))
    return guarded


def _rpl006_partial(fi, summ, index) -> List[Finding]:
    """Two-level taint inside one shard_map-reachable function:
    level 1 = a shard-local slice (axis_index + dynamic_slice pattern),
    level 2 = a matmul-derived partial product over it.  A level-2
    value escaping via return (or committed to engine state) without a
    dominating psum is each shard's DIFFERENT partial sum — the PR 8
    bug class."""
    from repro.analysis.interproc import MATMUL_TAILS, PSUM_TAILS
    findings: List[Finding] = []
    lv: Dict[str, int] = {}

    def level(expr) -> int:
        if isinstance(expr, ast.Name):
            return lv.get(expr.id, 0)
        if isinstance(expr, ast.Call):
            tail = _attr_tail(expr.func)
            argl = max((level(a) for a in expr.args), default=0)
            argl = max(argl, max((level(kw.value)
                                  for kw in expr.keywords), default=0))
            if tail in PSUM_TAILS:
                return 0
            callees = index.resolve_callable(expr.func, fi, fi.mod)
            if callees:
                c = callees[0]
                if summ.is_shard_local_slicer(c):
                    return 1
                if summ.contains_psum(c):
                    return 0
                if argl and summ.contains_matmul(c):
                    return 2
                return argl
            if tail in MATMUL_TAILS and argl:
                return 2
            return argl
        if isinstance(expr, ast.BinOp):
            sub = max(level(expr.left), level(expr.right))
            if isinstance(expr.op, ast.MatMult) and sub:
                return 2
            return sub
        if isinstance(expr, ast.Attribute):
            return 0 if expr.attr in _SHAPE_ATTRS else level(expr.value)
        if isinstance(expr, ast.Subscript):
            return level(expr.value)
        if isinstance(expr, (ast.Tuple, ast.List)):
            return max((level(e) for e in expr.elts), default=0)
        if isinstance(expr, ast.IfExp):
            return max(level(expr.body), level(expr.orelse))
        if isinstance(expr, ast.UnaryOp):
            return level(expr.operand)
        return 0

    def assign(target, val):
        if isinstance(target, ast.Name):
            lv[target.id] = val
        elif isinstance(target, (ast.Tuple, ast.List)):
            for e in target.elts:
                assign(e, val)
        elif isinstance(target, ast.Starred):
            assign(target.value, val)
        elif isinstance(target, ast.Attribute):
            if val >= 2 and target.attr in _STATE_ATTRS and \
                    _attr_root(target) in _ENGINE_NAMES:
                findings.append(Finding(
                    fi.mod.rel, target.lineno, target.col_offset,
                    "RPL006",
                    f"partial matmul product committed to engine state "
                    f"`{target.attr}` without a dominating psum: under "
                    "shard_map each shard stores a different partial "
                    "sum"))
        elif isinstance(target, ast.Subscript):
            assign(target.value, val)

    def walk(stmts):
        for st in stmts:
            if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef,
                               ast.ClassDef)):
                continue
            if isinstance(st, ast.Assign):
                val = level(st.value)
                for t in st.targets:
                    assign(t, val)
            elif isinstance(st, ast.AugAssign):
                assign(st.target, max(level(st.value),
                                      level(st.target)))
            elif isinstance(st, ast.AnnAssign) and st.value is not None:
                assign(st.target, level(st.value))
            elif isinstance(st, ast.Return) and st.value is not None:
                if level(st.value) >= 2:
                    findings.append(Finding(
                        fi.mod.rel, st.lineno, st.col_offset, "RPL006",
                        f"`{fi.name}` returns a matmul over a "
                        "shard-local slice with no dominating psum on "
                        "the path: under shard_map every shard returns "
                        "a DIFFERENT partial sum — wrap the product in "
                        "jax.lax.psum(..., axis) (or route through a "
                        "psum-carrying helper)"))
            else:
                for blk_name in ("body", "orelse", "finalbody"):
                    blk = getattr(st, blk_name, None)
                    if blk:
                        walk(blk)
                for h in getattr(st, "handlers", []):
                    walk(h.body)

    walk(fi.node.body)
    return findings


def rule_rpl006(ctx: Context) -> List[Finding]:
    from repro.analysis.interproc import Summaries
    index = ctx.project()
    summ = Summaries(index)
    findings: List[Finding] = []

    # (c) mesh.shape["axis"] on a mesh PARAMETER without an axis_names
    # membership guard anywhere in the function: helpers taking a
    # caller's mesh must not assume its topology.
    for fi in index.functions.values():
        if "mesh" not in index.param_names(fi):
            continue
        guarded = _guarded_axes(fi, index)
        for n in index.owned(fi):
            if not (isinstance(n, ast.Subscript)
                    and isinstance(n.value, ast.Attribute)
                    and n.value.attr == "shape"
                    and isinstance(n.value.value, ast.Name)
                    and n.value.value.id == "mesh"):
                continue
            sl = n.slice
            if isinstance(sl, ast.Constant) and \
                    isinstance(sl.value, str) and sl.value not in guarded:
                findings.append(Finding(
                    fi.mod.rel, n.lineno, n.col_offset, "RPL006",
                    f"`mesh.shape[{sl.value!r}]` in `{fi.name}` without "
                    f"checking {sl.value!r} in mesh.axis_names: "
                    "KeyErrors (or silently mis-shards) on meshes that "
                    "don't declare the axis — guard the lookup or use "
                    "mesh.shape.get"))

    # (a)+(b): shard_map-reachable functions
    roots = index.shard_map_roots()
    declared_of = {id(r): (summ.p_literals(r.binder)
                           if r.binder is not None else set())
                   for r in roots}
    reach = index.reachable([r.fn for r in roots])
    for fi, root_fns in reach.items():
        rs = [r for r in roots if r.fn in root_fns]
        declared: Set[str] = set()
        for r in rs:
            declared |= declared_of[id(r)]
        declared_known = bool(rs) and \
            all(declared_of[id(r)] for r in rs)
        if declared_known:
            for coll in summ.collectives(fi):
                vals, complete = summ.axis_values(coll.axis, fi)
                if complete and vals and not vals <= declared:
                    related = tuple((r.binder.mod.rel, r.call.lineno)
                                    for r in rs if r.binder is not None)
                    findings.append(Finding(
                        fi.mod.rel, coll.call.lineno,
                        coll.call.col_offset, "RPL006",
                        f"`{coll.kind}` over axis "
                        f"{sorted(vals - declared)} inside "
                        f"shard_map-reachable `{fi.name}`, but the "
                        "binding shard_map's PartitionSpecs only "
                        f"declare {sorted(declared)}: an undeclared "
                        "axis name fails at trace time (or silently "
                        "no-ops under a differently-named mesh)",
                        related=related))
        findings.extend(_rpl006_partial(fi, summ, index))
    return findings


# ---------------------------------------------------------------------------
# RPL007 — Pallas block contract (interprocedural)
# ---------------------------------------------------------------------------

def _sibling_module(ctx: Context, mod: ParsedModule,
                    stem: str) -> Optional[ParsedModule]:
    path = mod.path.parent / f"{stem}.py"
    key = str(path)
    if key in ctx.modules:
        return ctx.modules[key]
    if path.exists():
        from repro.analysis.core import parse_file
        return parse_file(path, ctx.root)
    return None


def _required_params(fn) -> Set[str]:
    a = fn.args
    pos = [*a.posonlyargs, *a.args]
    required = {p.arg for p in pos[:len(pos) - len(a.defaults)]}
    required |= {p.arg for p, d in zip(a.kwonlyargs, a.kw_defaults)
                 if d is None}
    return required


def _all_params(fn) -> Set[str]:
    a = fn.args
    return {p.arg for p in (*a.posonlyargs, *a.args, *a.kwonlyargs)}


def _index_map_lambda(expr, fi, index):
    """Resolve a BlockSpec index_map argument to a Lambda node: either
    inline, or a local name bound to one (`row = lambda b: (b, 0)`)."""
    if isinstance(expr, ast.Lambda):
        return expr
    if isinstance(expr, ast.Name) and fi is not None:
        for rhs in index.local_assignments(fi, expr.id):
            if isinstance(rhs, ast.Lambda):
                return rhs
    return None


def _index_map_violations(lam) -> List[str]:
    params = {a.arg for a in (*lam.args.posonlyargs, *lam.args.args,
                              *lam.args.kwonlyargs)}
    fn_names = set()
    for n in ast.walk(lam.body):
        if isinstance(n, ast.Call):
            for f in ast.walk(n.func):
                if isinstance(f, ast.Name):
                    fn_names.add(id(f))
    out = []
    for n in ast.walk(lam.body):
        if isinstance(n, ast.Name) and n.id not in params and \
                id(n) not in fn_names:
            out.append(f"closes over `{n.id}`")
        elif isinstance(n, ast.Constant) and \
                not isinstance(n.value, int):
            out.append(f"non-integer constant {n.value!r}")
    return sorted(set(out))


def _guards_divisibility(fi, index, depth: int = 2,
                         _seen=None) -> bool:
    if fi is None:
        return False
    if _seen is None:
        _seen = set()
    if id(fi) in _seen:
        return False
    _seen.add(id(fi))
    # operator nodes are interpreter singletons, so test the BinOp /
    # AugAssign carriers rather than the ast.Mod instances themselves
    if any(isinstance(n, (ast.BinOp, ast.AugAssign))
           and isinstance(n.op, ast.Mod)
           for n in ast.walk(fi.node)):
        return True
    if depth > 0:
        return any(_guards_divisibility(callee, index, depth - 1, _seen)
                   for _, callee in index.callees(fi))
    return False


def rule_rpl007(ctx: Context) -> List[Finding]:
    index = ctx.project()
    findings: List[Finding] = []
    for mod in list(ctx.modules.values()):
        if mod.path.parent.name != "kernels" or \
                mod.path.stem in _KERNEL_EXEMPT:
            continue
        calls = [n for n in ast.walk(mod.tree)
                 if isinstance(n, ast.Call)
                 and _attr_tail(n.func) == "pallas_call"]
        if not calls:
            continue
        at = calls[0]
        policy = _sibling_module(ctx, mod, "policy")
        registry, reg_line = _load_registry(policy) \
            if policy is not None else (None, 1)
        entry_meta = (registry or {}).get(mod.path.stem)
        if entry_meta is None:
            continue                       # RPL002's finding; don't dup
        entry_name = entry_meta.get("entry")
        if not entry_name:
            findings.append(Finding(
                policy.rel, reg_line, 0, "RPL007",
                f"KERNEL_REGISTRY[{mod.path.stem!r}] has no 'entry' "
                "metadata naming the public wrapper whose signature "
                "mirrors the ref twin and whose body guards the grid"))
            continue
        entry_fn = next(
            (n for n in mod.tree.body
             if isinstance(n, ast.FunctionDef) and n.name == entry_name),
            None)
        if entry_fn is None:
            findings.append(Finding(
                mod.rel, at.lineno, at.col_offset, "RPL007",
                f"registered entry wrapper `{entry_name}` is not "
                f"defined at module level in {mod.rel}"))
            continue

        # signature parity: some registered ref twin's REQUIRED params
        # must all appear in the entry wrapper's signature, so the
        # policy can swap entry<->ref call-compatibly.
        ref_mod = _sibling_module(ctx, mod, "ref")
        refs = entry_meta.get("ref", [])
        refs = refs if isinstance(refs, (list, tuple)) else [refs]
        ref_fns = []
        if ref_mod is not None:
            ref_fns = [n for n in ref_mod.tree.body
                       if isinstance(n, ast.FunctionDef)
                       and n.name in refs]
        if ref_fns:
            entry_params = _all_params(entry_fn)
            if not any(_required_params(r) <= entry_params
                       for r in ref_fns):
                want = sorted(_required_params(ref_fns[0]) - entry_params)
                findings.append(Finding(
                    mod.rel, entry_fn.lineno, entry_fn.col_offset,
                    "RPL007",
                    f"entry wrapper `{entry_name}` matches no "
                    f"registered ref twin's required signature "
                    f"(e.g. `{ref_fns[0].name}` needs {want}): policy "
                    "dispatch between kernel and ref would TypeError"))

        # index_map outputs must be pure functions of the grid indices
        for call in ast.walk(mod.tree):
            if not (isinstance(call, ast.Call)
                    and _attr_tail(call.func) == "BlockSpec"):
                continue
            im = next((kw.value for kw in call.keywords
                       if kw.arg == "index_map"),
                      call.args[1] if len(call.args) > 1 else None)
            if im is None:
                continue
            lam = _index_map_lambda(im, index.owner.get(call), index)
            if lam is None:
                continue
            for why in _index_map_violations(lam):
                findings.append(Finding(
                    mod.rel, lam.lineno, lam.col_offset, "RPL007",
                    f"BlockSpec index_map {why}: index maps must be "
                    "pure functions of the grid indices (plus int "
                    "literals) or the block offsets silently read the "
                    "wrong tiles"))

        # shape_guard 'checked' means the divisibility check must
        # dominate each pallas_call (same function or a callee)
        if entry_meta.get("shape_guard") == "checked":
            for call in calls:
                encl = index.owner.get(call)
                if not _guards_divisibility(encl, index):
                    findings.append(Finding(
                        mod.rel, call.lineno, call.col_offset, "RPL007",
                        "pallas_call under shape_guard 'checked' whose "
                        "enclosing function (and two callee levels) has "
                        "no divisibility (%) check: the grid contract "
                        "is asserted by the registry but not enforced "
                        "on this call path"))
    return findings


# ---------------------------------------------------------------------------
# RPL008 — commit discipline (interprocedural)
# ---------------------------------------------------------------------------

# transactional slot/pool state: RPL003's attrs minus the readout
# payload (`result`, owned per-session) and the forensics log
# (`_fault_log`, append-only and harvested after recovery)
_RPL008_ATTRS = _STATE_ATTRS - {"result", "_fault_log"}
_RPL008_RECEIVERS = {"self", "eng", "engine"}
_MUTATOR_METHODS = {"append", "extend", "update", "clear", "pop",
                    "remove", "insert", "fill", "setdefault"}


def _state_attr_of(node) -> Optional[str]:
    t = node
    if isinstance(t, ast.Subscript):
        t = t.value
    if isinstance(t, ast.Attribute) and t.attr in _RPL008_ATTRS and \
            _attr_root(t) in _RPL008_RECEIVERS:
        return t.attr
    return None


def _rpl008_fn(fi, summ, index) -> List[Finding]:
    """Execution-order walk flagging a DIRECT engine-state mutation
    followed by a may-raise call (jit dispatch, fault-injector probe,
    or a callee that raises — two levels deep).  Loop bodies are walked
    once (each iteration is its own transaction), except-handler bodies
    are recovery code and skipped, and a try with handlers or a
    state-restoring finally protects its calls."""
    findings: List[Finding] = []
    pending: List[Tuple[str, int]] = []

    def hazard_of(call):
        h = summ.call_hazard(call)
        if h is not None:
            return h, ()
        for tgt in index.resolve_callable(call.func, fi, fi.mod):
            if tgt is fi:
                continue
            mr = summ.may_raise(tgt)
            if mr is not None:
                return (f"calls `{tgt.name}()` which {mr.reason}",
                        ((mr.where, mr.line),))
        return None

    def check_calls(node, protected):
        for n in ast.walk(node):
            if not (isinstance(n, ast.Call)
                    and index.owner.get(n) is fi):
                continue
            hz = hazard_of(n)
            if hz is None or not pending or protected:
                continue
            attr, mline = pending[0]
            reason, related = hz
            findings.append(Finding(
                fi.mod.rel, n.lineno, n.col_offset, "RPL008",
                f"engine state `{attr}` mutated at line {mline} and "
                f"then a may-raise call runs ({reason}): a raise "
                "leaves the slot/pool half-committed — stage results "
                "locally and commit after the call, probe with "
                "commit=False first, or restore in a finally",
                related=((fi.mod.rel, mline),) + related))

    def record(st):
        if isinstance(st, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
            targets = st.targets if isinstance(st, ast.Assign) \
                else [st.target]
            for t in targets:
                attr = _state_attr_of(t)
                if attr is not None:
                    pending.append((attr, st.lineno))
        elif isinstance(st, ast.Expr) and isinstance(st.value, ast.Call):
            c = st.value
            if isinstance(c.func, ast.Attribute) and \
                    c.func.attr in _MUTATOR_METHODS:
                attr = _state_attr_of(c.func.value)
                if attr is not None:
                    pending.append((attr, st.lineno))

    def finally_restores(st) -> bool:
        for blk_st in st.finalbody:
            for n in ast.walk(blk_st):
                if isinstance(n, (ast.Assign, ast.AugAssign,
                                  ast.AnnAssign)):
                    targets = n.targets if isinstance(n, ast.Assign) \
                        else [n.target]
                    if any(_state_attr_of(t) is not None
                           for t in targets):
                        return True
        return False

    def walk(stmts, protected):
        for st in stmts:
            if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef,
                               ast.ClassDef)):
                continue
            if isinstance(st, ast.Try):
                prot = protected or bool(st.handlers) or \
                    finally_restores(st)
                walk(st.body, prot)
                walk(st.orelse, prot)
                walk(st.finalbody, protected)
            elif isinstance(st, (ast.If, ast.While)):
                check_calls(st.test, protected)
                walk(st.body, protected)
                walk(st.orelse, protected)
            elif isinstance(st, ast.For):
                check_calls(st.iter, protected)
                walk(st.body, protected)
                walk(st.orelse, protected)
            elif isinstance(st, ast.With):
                for item in st.items:
                    check_calls(item.context_expr, protected)
                walk(st.body, protected)
            else:
                check_calls(st, protected)
                record(st)

    walk(fi.node.body, False)
    return findings


def rule_rpl008(ctx: Context) -> List[Finding]:
    from repro.analysis.interproc import Summaries
    index = ctx.project()
    summ = Summaries(index)
    findings: List[Finding] = []
    for fi in index.functions.values():
        findings.extend(_rpl008_fn(fi, summ, index))
    return findings


PER_FILE_RULES = {
    "RPL001": rule_rpl001,
    "RPL003": rule_rpl003,
    "RPL004": rule_rpl004,
    "RPL005": rule_rpl005,
}

GLOBAL_RULES = {
    "RPL002": rule_rpl002,
    "RPL006": rule_rpl006,
    "RPL007": rule_rpl007,
    "RPL008": rule_rpl008,
}


def iter_rule_codes() -> Iterable[str]:
    yield from PER_FILE_RULES
    yield from GLOBAL_RULES
