"""Bounded interprocedural summaries over a `ProjectIndex`.

Each summary answers one question about a function with at most TWO
levels of callee inlining (`depth=2`): may it raise on the hot path,
which collectives does it issue and over which axis names, which
PartitionSpec axis literals does it (or its callees) declare, does it
contain a psum / a matmul, is it the shard-local column slicer pattern.
The two-level bound keeps the analysis linear and the answers local
enough to explain in a finding message; anything the bound or the
resolver cannot see resolves to "unknown", and every client rule treats
unknown as "do not flag" — the engine adds reach, never guesses.

`axis_values` is the workhorse: it resolves an axis-name expression to
the set of string constants it can take (through locals, IfExp arms,
`self.X` assignments anywhere in the class, module constants, and —
one level deep — the arguments callers pass for a parameter), returning
`(values, complete)`.  `complete=False` means some path was opaque and
the caller must not flag.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.callgraph import (FunctionInfo, ProjectIndex,
                                      is_abstract)

COLLECTIVE_TAILS = {
    "psum", "psum_scatter", "pmean", "pmax", "pmin", "ppermute",
    "all_gather", "all_to_all", "axis_index",
}
PSUM_TAILS = {"psum", "psum_scatter"}
MATMUL_TAILS = {"dot", "matmul", "einsum", "tensordot", "dot_general"}

SUMMARY_DEPTH = 2


def _tail(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return None


def _root_name(node: ast.AST) -> Optional[str]:
    while isinstance(node, (ast.Attribute, ast.Subscript, ast.Call)):
        node = node.value if not isinstance(node, ast.Call) else node.func
    return node.id if isinstance(node, ast.Name) else None


def _receiver_mentions(node: ast.AST, needle: str) -> bool:
    """True if any attribute segment (or the root name) on the
    receiver chain contains `needle` — e.g. `self._faults.check`."""
    cur = node
    while isinstance(cur, ast.Attribute):
        if needle in cur.attr:
            return True
        cur = cur.value
    return isinstance(cur, ast.Name) and needle in cur.id


@dataclass(eq=False)
class Collective:
    kind: str
    call: ast.Call
    axis: Optional[ast.expr]      # the axis-name expression, if present


@dataclass(eq=False)
class MayRaise:
    reason: str
    line: int                     # line of the hazard (in `where` file)
    where: str                    # rel path of the hazard site


class Summaries:
    def __init__(self, index: ProjectIndex):
        self.index = index
        self._may_raise: Dict[FunctionInfo, Dict[int, object]] = {}
        self._collectives: Dict[FunctionInfo, List[Collective]] = {}
        self._p_literals: Dict[Tuple[int, int], Set[str]] = {}
        self._flags: Dict[Tuple[str, int, int], bool] = {}
        self._in_progress: Set[Tuple[str, int]] = set()

    # ---- collectives / spec literals ---------------------------------
    def collectives(self, fi: FunctionInfo) -> List[Collective]:
        if fi not in self._collectives:
            out = []
            for call in self.index.calls_of(fi):
                kind = _tail(call.func)
                if kind not in COLLECTIVE_TAILS:
                    continue
                axis = None
                for kw in call.keywords:
                    if kw.arg in ("axis_name", "axis"):
                        axis = kw.value
                pos = 0 if kind == "axis_index" else 1
                if axis is None and len(call.args) > pos:
                    axis = call.args[pos]
                out.append(Collective(kind, call, axis))
            self._collectives[fi] = out
        return self._collectives[fi]

    def p_literals(self, fi: FunctionInfo,
                   depth: int = SUMMARY_DEPTH) -> Set[str]:
        """String constants appearing in P()/PartitionSpec() calls in
        `fi` or (up to `depth`) its project callees — the axis names a
        shard_map binder declares."""
        key = (id(fi), depth)
        if key in self._p_literals:
            return self._p_literals[key]
        tag = ("p", id(fi))
        if tag in self._in_progress:
            return set()
        self._in_progress.add(tag)
        try:
            out: Set[str] = set()
            for call in self.index.calls_of(fi):
                if _tail(call.func) in ("P", "PartitionSpec"):
                    for a in call.args:
                        out |= _const_strs(a)
            if depth > 0:
                for _, callee in self.index.callees(fi):
                    if callee is not fi:
                        out |= self.p_literals(callee, depth - 1)
            self._p_literals[key] = out
            return out
        finally:
            self._in_progress.discard(tag)

    def _has(self, what: str, fi: FunctionInfo, depth: int) -> bool:
        key = (what, id(fi), depth)
        if key in self._flags:
            return self._flags[key]
        tag = (what, id(fi))
        if tag in self._in_progress:
            return False
        self._in_progress.add(tag)
        try:
            hit = False
            if what == "psum":
                hit = any(c.kind in PSUM_TAILS
                          for c in self.collectives(fi))
            elif what == "matmul":
                hit = any(
                    (isinstance(n, ast.BinOp)
                     and isinstance(n.op, ast.MatMult))
                    or (isinstance(n, ast.Call)
                        and _tail(n.func) in MATMUL_TAILS)
                    for n in self.index.owned(fi))
            if not hit and depth > 0:
                hit = any(self._has(what, callee, depth - 1)
                          for _, callee in self.index.callees(fi)
                          if callee is not fi)
            self._flags[key] = hit
            return hit
        finally:
            self._in_progress.discard(tag)

    def contains_psum(self, fi, depth: int = SUMMARY_DEPTH) -> bool:
        return self._has("psum", fi, depth)

    def contains_matmul(self, fi, depth: int = SUMMARY_DEPTH) -> bool:
        return self._has("matmul", fi, depth)

    def is_shard_local_slicer(self, fi: FunctionInfo) -> bool:
        """Body pairs axis_index with a dynamic_slice and returns the
        result: the `shard_local_cols` pattern, recognized by shape so
        renames and copies still count as taint sources."""
        has_idx = any(c.kind == "axis_index" for c in self.collectives(fi))
        has_slice = any(
            isinstance(n, ast.Call) and (_tail(n.func) or "")
            .startswith("dynamic_slice")
            for n in self.index.owned(fi))
        has_ret = any(isinstance(n, ast.Return) and n.value is not None
                      for n in self.index.owned(fi))
        return has_idx and has_slice and has_ret

    # ---- may-raise ---------------------------------------------------
    def may_raise(self, fi: FunctionInfo,
                  depth: int = SUMMARY_DEPTH) -> Optional[MayRaise]:
        cache = self._may_raise.setdefault(fi, {})
        if depth in cache:
            return cache[depth]            # type: ignore[return-value]
        tag = ("raise", id(fi))
        if tag in self._in_progress:
            return None
        self._in_progress.add(tag)
        try:
            result = self._may_raise_uncached(fi, depth)
            cache[depth] = result
            return result
        finally:
            self._in_progress.discard(tag)

    def _may_raise_uncached(self, fi, depth) -> Optional[MayRaise]:
        if is_abstract(fi.node):
            return None
        esc = _escaping_raise(fi.node.body)
        if esc is not None:
            return MayRaise(f"raises at {fi.mod.rel}:{esc.lineno}",
                            esc.lineno, fi.mod.rel)
        for call in self.index.calls_of(fi):
            hazard = self.call_hazard(call)
            if hazard is not None:
                return MayRaise(
                    f"{hazard} at {fi.mod.rel}:{call.lineno}",
                    call.lineno, fi.mod.rel)
        if depth > 0:
            for call, callee in self.index.callees(fi):
                if callee is fi:
                    continue
                sub = self.may_raise(callee, depth - 1)
                if sub is not None:
                    return MayRaise(
                        f"calls {callee.name}() which {sub.reason}",
                        sub.line, sub.where)
        return None

    @staticmethod
    def call_hazard(call: ast.Call) -> Optional[str]:
        """Syntactic may-raise hazards: dispatching a jitted step
        (`self._jit_*`) or probing the fault injector
        (`self._faults.check`)."""
        tail = _tail(call.func)
        if tail is not None and tail.startswith("_jit"):
            return f"dispatches {tail}()"
        if tail == "check" and isinstance(call.func, ast.Attribute) and \
                _receiver_mentions(call.func.value, "fault"):
            return "probes the fault injector"
        return None

    # ---- axis-name value resolution ----------------------------------
    def axis_values(self, expr: Optional[ast.expr],
                    fi: Optional[FunctionInfo],
                    depth: int = SUMMARY_DEPTH,
                    _seen: Optional[Set] = None) -> \
            Tuple[Set[str], bool]:
        """(possible string values, complete).  `None` constants are
        dropped but stay complete (an IfExp arm disabling the collective
        axis is fine); any unresolvable path flips complete to False."""
        if _seen is None:
            _seen = set()
        if expr is None:
            return set(), True
        if isinstance(expr, ast.Constant):
            if isinstance(expr.value, str):
                return {expr.value}, True
            if expr.value is None:
                return set(), True
            return set(), False
        if isinstance(expr, (ast.Tuple, ast.List, ast.Set)):
            return self._union(expr.elts, fi, depth, _seen)
        if isinstance(expr, ast.IfExp):
            return self._union([expr.body, expr.orelse], fi, depth,
                               _seen)
        if isinstance(expr, ast.BoolOp):
            return self._union(expr.values, fi, depth, _seen)
        if isinstance(expr, ast.Name):
            return self._name_values(expr.id, fi, depth, _seen)
        if isinstance(expr, ast.Attribute) and \
                isinstance(expr.value, ast.Name) and \
                expr.value.id == "self" and fi is not None and \
                fi.cls is not None:
            return self._self_attr_values(expr.attr, fi, depth, _seen)
        return set(), False

    def _union(self, exprs, fi, depth, _seen):
        vals: Set[str] = set()
        complete = True
        for e in exprs:
            v, c = self.axis_values(e, fi, depth, _seen)
            vals |= v
            complete = complete and c
        return vals, complete

    def _name_values(self, name, fi, depth, _seen):
        f = fi
        while f is not None:
            key = ("name", id(f), name)
            if key in _seen:
                return set(), False
            if name in self.index.param_names(f):
                _seen.add(key)
                return self._param_values(f, name, depth, _seen)
            rhss = self.index.local_assignments(f, name)
            if rhss:
                _seen.add(key)
                return self._union(rhss, f, depth, _seen)
            f = f.parent
        if fi is not None:
            rhss = self.index.module_assignments(fi.mod, name)
            if rhss:
                return self._union(rhss, None, depth, _seen)
        return set(), False

    def _self_attr_values(self, attr, fi, depth, _seen):
        key = ("attr", fi.cls, attr)
        if key in _seen:
            return set(), False
        _seen.add(key)
        cls = self.index.classes.get(fi.cls)
        if cls is None:
            return set(), False
        rhss = []
        for c in self.index._ancestry(fi.cls):
            for m in c.methods.values():
                for n in self.index.owned(m):
                    if isinstance(n, ast.Assign):
                        for t in n.targets:
                            if isinstance(t, ast.Attribute) and \
                                    t.attr == attr and \
                                    isinstance(t.value, ast.Name) and \
                                    t.value.id == "self":
                                rhss.append((n.value, m))
        if not rhss:
            return set(), False
        vals: Set[str] = set()
        complete = True
        for rhs, owner in rhss:
            v, c = self.axis_values(rhs, owner, depth, _seen)
            vals |= v
            complete = complete and c
        return vals, complete

    def _param_values(self, f, name, depth, _seen):
        """Union of the argument expressions callers pass for
        parameter `name` of `f` (one level; bounded by `depth`)."""
        if depth <= 0:
            return set(), False
        default = _param_default(f.node, name)
        sites = self.index.callers_of(f)
        if not sites:
            if default is not None:
                return self.axis_values(default, f.parent, depth - 1,
                                        _seen)
            return set(), False
        vals: Set[str] = set()
        complete = True
        for caller, call in sites:
            arg = _bind_arg(f, call, name)
            if arg is _MISSING:
                if default is not None:
                    v, c = self.axis_values(default, f.parent,
                                            depth - 1, _seen)
                    vals |= v
                    complete = complete and c
                else:
                    complete = False
                continue
            if arg is _OPAQUE:
                complete = False
                continue
            v, c = self.axis_values(arg, caller, depth - 1, _seen)
            vals |= v
            complete = complete and c
        return vals, complete


_MISSING = object()
_OPAQUE = object()


def _param_default(node, name) -> Optional[ast.expr]:
    a = node.args
    pos = [*a.posonlyargs, *a.args]
    n_def = len(a.defaults)
    for i, p in enumerate(pos):
        if p.arg == name:
            j = i - (len(pos) - n_def)
            return a.defaults[j] if j >= 0 else None
    for p, d in zip(a.kwonlyargs, a.kw_defaults):
        if p.arg == name:
            return d
    return None


def _bind_arg(f: FunctionInfo, call: ast.Call, name: str):
    """The expression `call` passes for `f`'s parameter `name`.
    Bound-method calls (`obj.m(...)`) skip the `self` slot."""
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
        if kw.arg is None:                 # **kwargs at the site
            return _OPAQUE
    if any(isinstance(a, ast.Starred) for a in call.args):
        return _OPAQUE
    a = f.node.args
    pos = [p.arg for p in (*a.posonlyargs, *a.args)]
    offset = 0
    if f.cls is not None and pos and pos[0] in ("self", "cls") and \
            isinstance(call.func, ast.Attribute):
        offset = 1
    try:
        idx = pos.index(name) - offset
    except ValueError:
        return _MISSING
    if 0 <= idx < len(call.args):
        return call.args[idx]
    return _MISSING


def _escaping_raise(body) -> Optional[ast.Raise]:
    """First `raise` that can escape the function: raises inside a
    `try` that has except-handlers are treated as caught (precision
    over recall); raises inside handler bodies do escape."""
    for st in body:
        if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef,
                           ast.ClassDef)):
            continue
        if isinstance(st, ast.Raise):
            return st
        if isinstance(st, ast.Try):
            if not st.handlers:
                hit = _escaping_raise(st.body)
                if hit is not None:
                    return hit
            for h in st.handlers:
                hit = _escaping_raise(h.body)
                if hit is not None:
                    return hit
            for blk in (st.orelse, st.finalbody):
                hit = _escaping_raise(blk)
                if hit is not None:
                    return hit
        else:
            for blk_name in ("body", "orelse", "finalbody"):
                blk = getattr(st, blk_name, None)
                if blk:
                    hit = _escaping_raise(blk)
                    if hit is not None:
                        return hit
    return None


def _const_strs(node: ast.AST) -> Set[str]:
    return {n.value for n in ast.walk(node)
            if isinstance(n, ast.Constant) and isinstance(n.value, str)}
