"""Whole-project symbol table + call graph for the interprocedural rules.

`ProjectIndex` parses nothing itself — it indexes the `ParsedModule` set
the driver already holds — and resolves *project-internal* calls only:
imports (module- and function-local), module-level defs, `self.`/`cls.`
methods through the class hierarchy (abstract `raise NotImplementedError`
bodies resolve to their concrete overrides), nested defs, and locals
bound to a call whose callee returns a locally-defined function (the
serving engines' `step = self._step_fn()` factory pattern).  Anything
else — third-party calls, arbitrary attribute receivers — resolves to
nothing, so downstream summaries stay conservative instead of guessing.

Resolution is name-based and flow-insensitive: a local rebound to two
different functions resolves to both.  That over-approximation is the
right direction for every current client (reachability, may-raise and
mutation summaries union over candidates).
"""
from __future__ import annotations

import ast
import pathlib
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.analysis.core import ParsedModule
from repro.analysis.imports import module_name


@dataclass(eq=False)
class FunctionInfo:
    """One indexed function: module-level def, method, or nested def."""
    qualname: str                 # repro.serving.asr.AsrEngine._step
    name: str
    mod: ParsedModule
    node: ast.AST                 # FunctionDef | AsyncFunctionDef
    cls: Optional[str] = None     # enclosing class qualname (methods and
                                  # defs nested inside methods)
    parent: Optional["FunctionInfo"] = None   # enclosing function


@dataclass(eq=False)
class ClassInfo:
    qualname: str
    name: str
    mod: ParsedModule
    node: ast.ClassDef
    bases: List[ast.expr] = field(default_factory=list)
    methods: Dict[str, FunctionInfo] = field(default_factory=dict)


@dataclass(eq=False)
class ShardMapRoot:
    """One `shard_map(f, ...)` site: the traced root function and the
    binder (the function the shard_map call sits in, whose PartitionSpec
    literals declare the mesh axes the traced body may address)."""
    fn: FunctionInfo
    binder: Optional[FunctionInfo]
    call: ast.Call
    mod: ParsedModule


def is_abstract(node: ast.AST) -> bool:
    """Body is (docstring +) a lone `raise NotImplementedError`: an
    interface slot, not a may-raise implementation — calls through it
    resolve to the concrete overrides instead."""
    body = list(getattr(node, "body", []))
    if body and isinstance(body[0], ast.Expr) and \
            isinstance(body[0].value, ast.Constant) and \
            isinstance(body[0].value.value, str):
        body = body[1:]
    if len(body) != 1 or not isinstance(body[0], ast.Raise):
        return False
    exc = body[0].exc
    if isinstance(exc, ast.Call):
        exc = exc.func
    return isinstance(exc, ast.Name) and exc.id == "NotImplementedError"


def _fn_param_names(node) -> List[str]:
    a = node.args
    return [p.arg for p in (*a.posonlyargs, *a.args, *a.kwonlyargs)]


class ProjectIndex:
    def __init__(self, modules: Dict[str, ParsedModule],
                 root: pathlib.Path):
        self.root = root
        self.modules = list(modules.values())
        self.functions: Dict[str, FunctionInfo] = {}
        self.classes: Dict[str, ClassInfo] = {}
        self.mod_name: Dict[str, str] = {}          # rel path -> dotted
        self.mod_scope: Dict[str, Dict[str, str]] = {}
        self.fn_scope: Dict[FunctionInfo, Dict[str, str]] = {}
        self.owner: Dict[ast.AST, Optional[FunctionInfo]] = {}
        self._calls: Dict[FunctionInfo, List[ast.Call]] = {}
        self._assigns: Dict[FunctionInfo, Dict[str, List[ast.expr]]] = {}
        self._callees: Dict[FunctionInfo, List] = {}
        self._callers: Optional[Dict[FunctionInfo, List]] = None
        self._ancestry_cache: Dict[str, List[ClassInfo]] = {}
        for mod in self.modules:
            try:
                dotted = module_name(mod.path.resolve(),
                                     root.resolve())
            except ValueError:
                dotted = mod.path.stem
            self.mod_name[mod.rel] = dotted
            self.mod_scope[dotted] = {}
            self._scan(mod.tree, mod, dotted, fi=None, cls=None,
                       prefix=dotted)
            self._bind_imports(mod, dotted)

    # ---- construction ------------------------------------------------
    def _scan(self, node, mod, dotted, fi, cls, prefix):
        for child in ast.iter_child_nodes(node):
            self.owner[child] = fi
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                if fi is None and cls is None:
                    qual = f"{prefix}.{child.name}"
                    self.mod_scope[dotted][child.name] = qual
                elif fi is None:              # class body: a method
                    qual = f"{prefix}.{child.name}"
                else:                         # nested def
                    qual = f"{prefix}.<locals>.{child.name}"
                sub = FunctionInfo(qual, child.name, mod, child,
                                   cls=cls, parent=fi)
                self.functions[qual] = sub
                if cls is not None and fi is None:
                    self.classes[cls].methods[child.name] = sub
                self._scan(child, mod, dotted, sub, cls, qual)
            elif isinstance(child, ast.ClassDef):
                cqual = f"{prefix}.{child.name}"
                self.classes[cqual] = ClassInfo(
                    cqual, child.name, mod, child, list(child.bases))
                if fi is None and cls is None:
                    self.mod_scope[dotted][child.name] = cqual
                self._scan(child, mod, dotted, None, cqual, cqual)
            else:
                self._scan(child, mod, dotted, fi, cls, prefix)

    def _bind_imports(self, mod, dotted):
        pkg_parts = dotted.split(".")[:-1]
        for node in ast.walk(mod.tree):
            env = None
            if isinstance(node, ast.Import):
                env = self._env_for(node, dotted)
                for alias in node.names:
                    if alias.asname:
                        env[alias.asname] = alias.name
                    else:
                        head = alias.name.split(".")[0]
                        env[head] = head
            elif isinstance(node, ast.ImportFrom):
                env = self._env_for(node, dotted)
                if node.level:
                    base = pkg_parts[:len(pkg_parts) - node.level + 1]
                    prefix = ".".join(base + ([node.module]
                                              if node.module else []))
                else:
                    prefix = node.module or ""
                for alias in node.names:
                    tgt = f"{prefix}.{alias.name}" if prefix else alias.name
                    env[alias.asname or alias.name] = tgt

    def _env_for(self, node, dotted) -> Dict[str, str]:
        fi = self.owner.get(node)
        if fi is None:
            return self.mod_scope[dotted]
        return self.fn_scope.setdefault(fi, {})

    # ---- per-function node access ------------------------------------
    def calls_of(self, fi: FunctionInfo) -> List[ast.Call]:
        """Call nodes belonging DIRECTLY to `fi` (nested defs own their
        own calls)."""
        if fi not in self._calls:
            self._calls[fi] = [n for n in ast.walk(fi.node)
                               if isinstance(n, ast.Call)
                               and self.owner.get(n) is fi]
        return self._calls[fi]

    def owned(self, fi: FunctionInfo):
        for n in ast.walk(fi.node):
            if self.owner.get(n) is fi or n is fi.node:
                yield n

    def local_assignments(self, fi: FunctionInfo,
                          name: str) -> List[ast.expr]:
        """RHS expressions ever assigned to local `name` in `fi`
        (plain/ann assigns; `for name in it` contributes `it`, which
        value-resolution unions elementwise when it is a literal)."""
        if fi not in self._assigns:
            table: Dict[str, List[ast.expr]] = {}

            def put(target, value):
                if isinstance(target, ast.Name):
                    table.setdefault(target.id, []).append(value)

            for n in self.owned(fi):
                if isinstance(n, ast.Assign) and n.value is not None:
                    for t in n.targets:
                        put(t, n.value)
                elif isinstance(n, ast.AnnAssign) and n.value is not None:
                    put(n.target, n.value)
                elif isinstance(n, ast.For):
                    put(n.target, n.iter)
            self._assigns[fi] = table
        return self._assigns[fi].get(name, [])

    def module_assignments(self, mod: ParsedModule,
                           name: str) -> List[ast.expr]:
        out = []
        for n in mod.tree.body:
            if isinstance(n, ast.Assign):
                for t in n.targets:
                    if isinstance(t, ast.Name) and t.id == name:
                        out.append(n.value)
        return out

    # ---- resolution --------------------------------------------------
    def resolve_binding(self, name: str, within: Optional[FunctionInfo],
                        mod: ParsedModule) -> Optional[str]:
        fi = within
        while fi is not None:
            q = f"{fi.qualname}.<locals>.{name}"
            if q in self.functions:
                return q
            env = self.fn_scope.get(fi)
            if env and name in env:
                return env[name]
            fi = fi.parent
        return self.mod_scope.get(self.mod_name[mod.rel], {}).get(name)

    def resolve_callable(self, expr, within: Optional[FunctionInfo],
                         mod: ParsedModule,
                         _depth: int = 0) -> List[FunctionInfo]:
        """Project functions `expr` may denote as a callable."""
        if _depth > 4:
            return []
        if isinstance(expr, ast.Name):
            target = self.resolve_binding(expr.id, within, mod)
            if target is not None:
                fn = self.functions.get(target)
                return [fn] if fn is not None else []
            if within is None:
                return []
            out: List[FunctionInfo] = []
            for rhs in self.local_assignments(within, expr.id):
                if isinstance(rhs, ast.Call):
                    for callee in self.resolve_callable(
                            rhs.func, within, mod, _depth + 1):
                        out.extend(self.returned_functions(callee))
                elif isinstance(rhs, (ast.Name, ast.Attribute)):
                    out.extend(self.resolve_callable(
                        rhs, within, mod, _depth + 1))
            return _dedup(out)
        if isinstance(expr, ast.Attribute):
            if isinstance(expr.value, ast.Name) and \
                    expr.value.id in ("self", "cls"):
                cls = within.cls if within is not None else None
                if cls is not None:
                    return self.resolve_method(cls, expr.attr)
                return []
            parts = []
            cur = expr
            while isinstance(cur, ast.Attribute):
                parts.append(cur.attr)
                cur = cur.value
            if not isinstance(cur, ast.Name):
                return []
            base = self.resolve_binding(cur.id, within, mod)
            if base is None:
                return []
            parts.reverse()
            qual = ".".join([base] + parts)
            fn = self.functions.get(qual)
            if fn is not None:
                return [fn]
            owner_q = ".".join([base] + parts[:-1])
            if owner_q in self.classes:
                return self.resolve_method(owner_q, parts[-1])
            return []
        return []

    def resolve_method(self, cls_qual: str, name: str) -> \
            List[FunctionInfo]:
        for c in self._ancestry(cls_qual):
            m = c.methods.get(name)
            if m is None:
                continue
            if is_abstract(m.node):
                overrides = [k.methods[name] for k in self.modules_subs
                             (cls_qual)
                             if name in k.methods
                             and not is_abstract(k.methods[name].node)]
                return overrides or [m]
            return [m]
        return []

    def _ancestry(self, cls_qual: str) -> List[ClassInfo]:
        if cls_qual in self._ancestry_cache:
            return self._ancestry_cache[cls_qual]
        out: List[ClassInfo] = []
        seen = set()
        queue = [cls_qual]
        while queue:
            q = queue.pop(0)
            if q in seen or q not in self.classes:
                continue
            seen.add(q)
            c = self.classes[q]
            out.append(c)
            for b in c.bases:
                tgt = None
                if isinstance(b, ast.Name):
                    tgt = self.resolve_binding(b.id, None, c.mod)
                elif isinstance(b, ast.Attribute) and \
                        isinstance(b.value, ast.Name):
                    base = self.resolve_binding(b.value.id, None, c.mod)
                    if base is not None:
                        tgt = f"{base}.{b.attr}"
                if tgt is not None:
                    queue.append(tgt)
        self._ancestry_cache[cls_qual] = out
        return out

    def modules_subs(self, cls_qual: str) -> List[ClassInfo]:
        """Classes anywhere in the project whose ancestry includes
        `cls_qual` (the class itself excluded)."""
        return [c for q, c in self.classes.items() if q != cls_qual
                and any(a.qualname == cls_qual for a in self._ancestry(q))]

    def returned_functions(self, fi: FunctionInfo) -> List[FunctionInfo]:
        """Nested defs `fi` returns (directly, or wrapped in jit/partial):
        resolves the `step = self._step_fn()` factory pattern."""
        out = []
        for n in self.owned(fi):
            if not isinstance(n, ast.Return) or n.value is None:
                continue
            v = n.value
            if isinstance(v, ast.Call) and v.args and \
                    _tail(v.func) in ("jit", "partial"):
                v = v.args[0]
            if isinstance(v, ast.Name):
                q = f"{fi.qualname}.<locals>.{v.id}"
                if q in self.functions:
                    out.append(self.functions[q])
        return out

    # ---- call graph --------------------------------------------------
    def callees(self, fi: FunctionInfo) -> \
            List[Tuple[ast.Call, FunctionInfo]]:
        if fi not in self._callees:
            out = []
            for call in self.calls_of(fi):
                for tgt in self.resolve_callable(call.func, fi, fi.mod):
                    out.append((call, tgt))
            self._callees[fi] = out
        return self._callees[fi]

    def callers_of(self, fi: FunctionInfo) -> \
            List[Tuple[FunctionInfo, ast.Call]]:
        if self._callers is None:
            self._callers = {}
            for caller in list(self.functions.values()):
                for call, tgt in self.callees(caller):
                    self._callers.setdefault(tgt, []).append((caller, call))
        return self._callers.get(fi, [])

    def reachable(self, roots: List[FunctionInfo]) -> \
            Dict[FunctionInfo, List[FunctionInfo]]:
        """BFS closure over callees: reached function -> the roots that
        reach it (roots reach themselves)."""
        out: Dict[FunctionInfo, List[FunctionInfo]] = {}
        for root in roots:
            queue, seen = [root], {root}
            while queue:
                fi = queue.pop(0)
                out.setdefault(fi, [])
                if root not in out[fi]:
                    out[fi].append(root)
                for _, tgt in self.callees(fi):
                    if tgt not in seen:
                        seen.add(tgt)
                        queue.append(tgt)
        return out

    def shard_map_roots(self) -> List[ShardMapRoot]:
        out = []
        for mod in self.modules:
            for node in ast.walk(mod.tree):
                if not (isinstance(node, ast.Call)
                        and _tail(node.func) == "shard_map"
                        and node.args):
                    continue
                binder = self.owner.get(node)
                for fn in self.resolve_callable(node.args[0], binder, mod):
                    out.append(ShardMapRoot(fn, binder, node, mod))
        return out

    def param_names(self, fi: FunctionInfo) -> List[str]:
        return _fn_param_names(fi.node)


def _tail(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return None


def _dedup(fis: List[FunctionInfo]) -> List[FunctionInfo]:
    seen, out = set(), []
    for f in fis:
        if id(f) not in seen:
            seen.add(id(f))
            out.append(f)
    return out


def build_index(modules: Dict[str, ParsedModule],
                root: pathlib.Path) -> ProjectIndex:
    return ProjectIndex(modules, root)
