from repro.optim.adamw import AdamWConfig, init, update
from repro.optim.schedules import cosine_with_warmup
