"""AdamW with optional int8-quantized moments (blockwise, error-free decode).

8-bit moments are the distributed-optimization trick that lets
llama4-maverick-400b fit the 2-pod HBM budget (see DESIGN.md §5): moment
trees are stored as {'q': int8, 'scale': f32 blocks} with the same sharding
rules as their parameters.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core import quant


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"     # float32 | bfloat16 | int8


def _encode(x, cfg: AdamWConfig):
    if cfg.moment_dtype == "int8":
        return quant.quantize(x)
    return x.astype(jnp.dtype(cfg.moment_dtype))


def _decode(x, cfg: AdamWConfig):
    if cfg.moment_dtype == "int8":
        return quant.dequantize(x)
    return x.astype(jnp.float32)


def init(params, cfg: AdamWConfig) -> dict:
    def zeros():
        # fresh buffers each time: _encode is a no-op astype for f32, and
        # shared m/v buffers would break donation (same buffer donated twice)
        return jax.tree.map(lambda p: _encode(
            jnp.zeros(p.shape, jnp.float32), cfg), params)
    return {"m": zeros(), "v": zeros(), "count": jnp.zeros((), jnp.int32)}


def _is_moment_leaf(x):
    return isinstance(x, dict) and set(x) == {"q", "scale"}


def update(grads, opt_state, params, cfg: AdamWConfig, lr_scale=1.0):
    """Returns (new_params, new_opt_state)."""
    count = opt_state["count"] + 1
    # global-norm clip (fp32)
    gsq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree.leaves(grads))
    gnorm = jnp.sqrt(gsq)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    bc1 = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** count.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m = cfg.b1 * _decode(m, cfg) + (1 - cfg.b1) * g
        v = cfg.b2 * _decode(v, cfg) + (1 - cfg.b2) * jnp.square(g)
        step = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * step).astype(p.dtype)
        return new_p, _encode(m, cfg), _encode(v, cfg)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = tdef.flatten_up_to(opt_state["m"])
    flat_v = tdef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "count": count}
