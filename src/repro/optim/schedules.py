"""LR schedules (pure functions of step)."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_with_warmup(step, *, base_lr=1.0, warmup=200, total=10000,
                       min_frac=0.1):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = jnp.minimum(step / max(warmup, 1), 1.0)
    t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return base_lr * warm * cos
