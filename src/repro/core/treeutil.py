"""Pytree helpers for slot-pooled (leading-batch-axis) state.

The multi-stream scheduler keeps every piece of per-stream carried state
— TDS left-context buffers, decoder BeamState — as a pytree whose leaves
carry a leading slot axis.  These two helpers are the whole protocol:
broadcast a single-stream init tree to B slots, and reset one slot back
to a fresh init tree (utterance boundary in that slot).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def batch_tree(tree, batch: int):
    """Broadcast each leaf x -> (batch,) + x.shape."""
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (batch,) + x.shape), tree)


def set_slot(tree, slot, fresh):
    """Return `tree` with `fresh` (no slot axis) written into `slot`."""
    return jax.tree.map(lambda b, i: b.at[slot].set(i), tree, fresh)
