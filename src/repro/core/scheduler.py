"""ASRPU runtime: command decoder API + decoding-step scheduler (paper §3).

The accelerator's command set (Table 1) maps 1:1 onto this class:

  ConfigureASR_AcousticScoring  -> configure_acoustic_scoring(kernels)
  ConfigureASR_HypExpansion     -> configure_hyp_expansion(expand_fn)
  ConfigureBeamWidth            -> configure_beam_width(beam)
  DecodingStep                  -> decoding_step(signal_chunk)
  CleanDecoding                 -> clean_decoding()

Decoding steps (§3.1) run the acoustic-scoring phase (the kernel sequence:
feature extraction + one kernel per DNN layer) and then the
hypothesis-expansion phase once per emitted acoustic vector.

Setup threads (§3.2) become the static `StepPlan`: JAX needs static
shapes, so the per-kernel setup arithmetic — how many outputs are
producible from buffered inputs, what to retire, how many threads to
launch — runs at plan time and fixes the steady-state schedule; a step
whose buffers cannot produce a single output returns early exactly like a
setup thread returning zero.  The plan doubles as the driver for the
paper's instruction-count performance model (benchmarks/).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.tds_asr import (ASRPU_HW, DECODER_CONFIG, FEATURE_CONFIG,
                                   TDS_CONFIG, DecoderConfig, FeatureConfig,
                                   TDSConfig)
from repro.core import decoder as dec
from repro.core import features
from repro.core.lexicon import BigramLM, Lexicon
from repro.models import tds


@dataclass
class PlannedKernel:
    """One kernel execution inside a decoding step (Fig. 6)."""
    name: str
    kind: str
    n_threads: int          # threads launched by the ASR controller
    n_frames: int           # output frames this step
    macs_per_thread: int    # inner-loop MACs (setup thread metadata)
    weight_bytes: int
    n_subkernels: int


@dataclass
class StepPlan:
    """Static steady-state decoding-step schedule (the setup threads)."""
    samples_per_step: int
    feat_frames_per_step: int
    acoustic_frames_per_step: int   # hyp-expansion repetitions (Fig. 6)
    kernels: List[PlannedKernel]

    def total_threads(self) -> int:
        return sum(k.n_threads for k in self.kernels)


def make_step_plan(tds_cfg: TDSConfig = TDS_CONFIG,
                   feat_cfg: FeatureConfig = FEATURE_CONFIG,
                   step_ms: float = 80.0, beam_k: int = 128) -> StepPlan:
    """The setup-thread arithmetic for one steady-state decoding step."""
    samples = int(feat_cfg.sample_rate * step_ms / 1000)
    feat_frames = int(step_ms / feat_cfg.shift_ms)          # 8 @ 80ms
    sub = tds_cfg.total_subsample
    assert feat_frames % sub == 0, (feat_frames, sub)
    out_frames = feat_frames // sub
    kernels = [PlannedKernel(
        "mfcc", "feature", n_threads=feat_frames, n_frames=feat_frames,
        macs_per_thread=(feat_cfg.frame_len                  # window+preemph
                         + feat_cfg.n_fft * int(np.log2(feat_cfg.n_fft))
                         + (feat_cfg.n_fft // 2 + 1) * feat_cfg.n_mels
                         + feat_cfg.n_mels * feat_cfg.n_mfcc),
        weight_bytes=0, n_subkernels=1)]
    t = feat_frames
    for spec in tds.build_kernel_specs(tds_cfg):
        t_out = t // spec.stride
        if spec.kind == "layernorm":
            kernels.append(PlannedKernel(
                spec.name, spec.kind, n_threads=t_out, n_frames=t_out,
                macs_per_thread=2 * spec.n_out, weight_bytes=0,
                n_subkernels=1))
        else:
            # one thread per output neuron per frame (paper §3.1)
            kernels.append(PlannedKernel(
                spec.name, spec.kind, n_threads=t_out * spec.n_out,
                n_frames=t_out, macs_per_thread=spec.n_in,
                weight_bytes=spec.weight_bytes,
                n_subkernels=spec.n_subkernels))
        t = t_out
    assert t == out_frames, (t, out_frames)
    return StepPlan(samples, feat_frames, out_frames, kernels)


class ASRPU:
    """The accelerator, as a streaming decoder object (paper §3/§4)."""

    def __init__(self, hw=ASRPU_HW):
        self.hw = hw
        self._tds_cfg: Optional[TDSConfig] = None
        self._params = None
        self._feat_cfg = FEATURE_CONFIG
        self._dec_cfg = DECODER_CONFIG
        self._lex: Optional[Lexicon] = None
        self._lm: Optional[BigramLM] = None
        self._use_int8 = False
        self.plan: Optional[StepPlan] = None
        self._jit_step = None
        self.clean_decoding()

    # ---- configuration commands -------------------------------------
    def configure_acoustic_scoring(self, tds_cfg: TDSConfig, params,
                                   feat_cfg: FeatureConfig = FEATURE_CONFIG,
                                   use_int8: bool = False,
                                   step_ms: float = 80.0):
        self._tds_cfg, self._params = tds_cfg, params
        self._feat_cfg = feat_cfg
        self._use_int8 = use_int8
        self.plan = make_step_plan(tds_cfg, feat_cfg, step_ms,
                                   self._dec_cfg.beam_size)
        self._build_step()

    def configure_hyp_expansion(self, lex: Lexicon, lm: BigramLM,
                                dec_cfg: DecoderConfig = DECODER_CONFIG):
        self._lex, self._lm, self._dec_cfg = lex, lm, dec_cfg
        if self._tds_cfg is not None:
            self._build_step()

    def configure_beam_width(self, beam: float):
        from dataclasses import replace
        self._dec_cfg = replace(self._dec_cfg, beam_threshold=beam)
        if self._tds_cfg is not None and self._lex is not None:
            self._build_step()

    def clean_decoding(self):
        """Reset hypothesis memory + streaming buffers for a new utterance."""
        self._sample_buf = np.zeros((0,), np.float32)
        self._stream_state = None
        self._beam = None
        self._n_steps = 0

    # ---- the fused decoding-step program ------------------------------
    def _build_step(self):
        if self._lex is None or self._tds_cfg is None:
            return
        tds_cfg, feat_cfg = self._tds_cfg, self._feat_cfg
        dec_cfg, lex, lm = self._dec_cfg, self._lex, self._lm
        use_int8 = self._use_int8
        nfr = self.plan.feat_frames_per_step

        def step(params, stream_state, beam_state, samples):
            feats = features.mfcc(samples, feat_cfg)[:nfr]
            logp, new_state = tds.forward(params, tds_cfg, feats,
                                          stream_state, use_int8=use_int8)

            def expand(bs, lp):
                return dec.expand_step(bs, lp, lex, lm, dec_cfg), None
            beam_state, _ = jax.lax.scan(expand, beam_state, logp)
            return new_state, beam_state

        self._jit_step = jax.jit(step)

    # ---- runtime commands ---------------------------------------------
    def decoding_step(self, signal: np.ndarray):
        """Append `signal` to the stream and run decoding steps for every
        full 80ms window available. Returns the current best hypothesis."""
        assert self._jit_step is not None, "accelerator not configured"
        self._sample_buf = np.concatenate([self._sample_buf,
                                           np.asarray(signal, np.float32)])
        if self._stream_state is None:
            self._stream_state = tds.init_stream_state(self._tds_cfg)
            self._beam = dec.init_state(self._dec_cfg.beam_size, self._lm)
        spp = self.plan.samples_per_step
        # the MFCC framing needs frame_len-frame_shift lookahead samples
        look = self._feat_cfg.frame_len - self._feat_cfg.frame_shift
        while self._sample_buf.shape[0] >= spp + look:
            chunk = jnp.asarray(self._sample_buf[:spp + look])
            self._sample_buf = self._sample_buf[spp:]
            self._stream_state, self._beam = self._jit_step(
                self._params, self._stream_state, self._beam, chunk)
            self._n_steps += 1
        return self.best()

    def best(self, final: bool = False):
        """Current best hypothesis. final=True commits a pending
        utterance-final word (call when the utterance is known to end)."""
        if self._beam is None:
            return {"words": np.zeros((0,), np.int32), "score": -np.inf}
        beam = self._beam
        if final:
            beam = dec.finalize(beam, self._lex, self._lm, self._dec_cfg)
        b = dec.best(beam)
        n = int(b["n_words"])
        return {"words": np.asarray(b["words"])[:n],
                "tokens": np.asarray(b["tokens"])[:int(b["n_tokens"])],
                "score": float(b["score"])}
