"""ASRPU command-API shims over the serving engine (paper §3, Table 1).

The accelerator's command set maps 1:1 onto these classes:

  ConfigureASR_AcousticScoring  -> configure_acoustic_scoring(kernels)
  ConfigureASR_HypExpansion     -> configure_hyp_expansion(expand_fn)
  ConfigureBeamWidth            -> configure_beam_width(beam)
  DecodingStep                  -> decoding_step(signal_chunk)
  CleanDecoding                 -> clean_decoding()

DEPRECATED: the mutable configure-command sequence is kept only as the
paper-shaped surface (and for the parity tests that pin the redesign).
New code should build a frozen `repro.serving.AsrProgram` /
`EngineConfig` and stream through `Session.push/poll/finish` — see
README.md "Serving architecture" for the migration table.  Both shims
here hold no decoding state of their own: each is a thin adapter that
accumulates the configure commands into an `AsrProgram` and drives one
`repro.serving.AsrEngine` slot pool (n_slots=1 for `ASRPU`).

`StepPlan`/`make_step_plan` (the setup-thread schedule, §3.2) live in
core/stepplan.py and are re-exported here for compatibility.
"""
from __future__ import annotations

import warnings
from typing import List, Optional

import numpy as np

from repro.configs.tds_asr import (ASRPU_HW, DECODER_CONFIG, FEATURE_CONFIG,
                                   DecoderConfig, FeatureConfig, TDSConfig)
from repro.core.lexicon import BigramLM, Lexicon
from repro.core.stepplan import (PlannedKernel, StepPlan,  # noqa: F401
                                 make_step_plan)
from repro.serving import AsrEngine, AsrProgram, EngineConfig
from repro.serving.asr import empty_hypothesis
from repro.serving.engine import copy_result


class ASRPU:
    """The accelerator as a streaming decoder object — a deprecated shim
    translating the command API onto a 1-slot serving engine."""

    _n_slots = 1

    def __init__(self, hw=ASRPU_HW):
        warnings.warn(
            f"{type(self).__name__} is deprecated: build a frozen "
            "repro.serving.AsrProgram/EngineConfig and stream through "
            "Session.push/poll/finish (see README.md 'Serving "
            "architecture' for the migration table)",
            DeprecationWarning, stacklevel=2)
        self.hw = hw
        self._tds_cfg: Optional[TDSConfig] = None
        self._params = None
        self._feat_cfg = FEATURE_CONFIG
        self._dec_cfg = DECODER_CONFIG
        self._lex: Optional[Lexicon] = None
        self._lm: Optional[BigramLM] = None
        self._use_int8 = False
        self._step_ms = 80.0
        self.plan: Optional[StepPlan] = None
        self._engine: Optional[AsrEngine] = None

    # ---- configuration commands -------------------------------------
    def configure_acoustic_scoring(self, tds_cfg: TDSConfig, params,
                                   feat_cfg: FeatureConfig = FEATURE_CONFIG,
                                   use_int8: bool = False,
                                   step_ms: float = 80.0):
        self._tds_cfg, self._params = tds_cfg, params
        self._feat_cfg = feat_cfg
        self._use_int8 = use_int8
        self._step_ms = step_ms
        self.plan = make_step_plan(tds_cfg, feat_cfg, step_ms,
                                   self._dec_cfg.beam_size)
        self._reconfigure()

    def configure_hyp_expansion(self, lex: Lexicon, lm: BigramLM,
                                dec_cfg: DecoderConfig = DECODER_CONFIG):
        self._lex, self._lm, self._dec_cfg = lex, lm, dec_cfg
        self._reconfigure()

    def configure_beam_width(self, beam: float):
        from dataclasses import replace
        self._dec_cfg = replace(self._dec_cfg, beam_threshold=beam)
        self._reconfigure()

    def _reconfigure(self):
        """Swap in an engine for the new program.  A configure command
        between DecodingSteps is legal in the paper's command API, so
        in-flight decoding state (sample buffers, left context, beam)
        carries over to the new engine — matching the old behavior of
        re-jitting the step in place."""
        old, self._engine = self._engine, None
        if old is None or self._tds_cfg is None or self._lex is None:
            return
        self._require_engine().adopt_state(old)

    # ---- engine assembly --------------------------------------------
    def _program(self) -> AsrProgram:
        # max_windows_per_step=1: the paper's DecodingStep command is
        # one 80 ms window per execution, and callers observe _n_steps —
        # bulk multi-window fusion is an engine-API behavior only.
        # flush_tail=False: the paper's command API has no end-of-input
        # signal (DecodingStep/best only ever decode whole windows), so
        # the engine-level trailing-window flush must not fire here.
        return AsrProgram(self._tds_cfg, self._lex, self._lm,
                          self._feat_cfg, self._dec_cfg,
                          use_int8=self._use_int8, step_ms=self._step_ms,
                          max_windows_per_step=1, flush_tail=False)

    def _require_engine(self) -> AsrEngine:
        assert self._tds_cfg is not None and self._lex is not None, \
            "accelerator not configured"
        if self._engine is None:
            self._engine = AsrEngine(
                EngineConfig(self._program(), n_slots=self._n_slots),
                self._params)
        return self._engine

    @property
    def _n_steps(self) -> int:
        return self._engine.n_steps if self._engine is not None else 0

    @property
    def _beam(self):
        # intentional raw exposure: parity tests introspect the live
        # beam; callers never mutate it (jax arrays are immutable)
        # repro-lint: disable=RPL003
        return self._engine._beam if self._engine is not None else None

    @property
    def _stream_state(self):
        # repro-lint: disable=RPL003  (same intentional exposure)
        return (self._engine._stream_state
                if self._engine is not None else None)

    # ---- runtime commands -------------------------------------------
    def clean_decoding(self):
        """Reset hypothesis memory + streaming buffers for a new utterance."""
        if self._engine is not None:
            self._engine.reset()

    def decoding_step(self, signal: np.ndarray):
        """Append `signal` to the stream and run decoding steps for every
        full 80ms window available. Returns the current best hypothesis."""
        eng = self._require_engine()
        eng.feed_slot(0, signal)
        eng.pump()
        return self.best()

    def best(self, final: bool = False):
        """Current best hypothesis. final=True commits a pending
        utterance-final word (call when the utterance is known to end)."""
        if self._engine is None:
            return empty_hypothesis()
        return copy_result(self._engine.slot_best(0, final=final))


class MultiStreamASRPU(ASRPU):
    """B concurrent utterance streams through ONE vmapped decoding step —
    a deprecated shim over an N-slot `repro.serving.AsrEngine`.

    Command API extensions over ASRPU:
      CleanDecoding(slot)   -> clean_decoding(slot=s): reset one stream
      DecodingStep(slot, x) -> decoding_step(x, slot=s)
      serve(utterances)     -> continuous batching: admission of queued
                               utterances into freed slots until drained
    """

    def __init__(self, n_streams: int, hw=ASRPU_HW):
        assert n_streams >= 1
        self.n_streams = n_streams
        self._n_slots = n_streams
        super().__init__(hw)

    # slot/final are keyword-only: through the ASRPU-typed interface a
    # positional best(True) would otherwise bind slot=1 silently.
    def clean_decoding(self, slot: Optional[int] = None):
        """Reset all streams (slot=None) or one stream's buffers, left
        context, and hypothesis memory (utterance boundary in a slot)."""
        if self._engine is None:
            return
        if slot is None:
            self._engine.reset()
        else:
            self._engine.reset_slot(slot)

    def decoding_step(self, signal: np.ndarray, *, slot: int = 0):
        """Append `signal` to stream `slot` and advance ALL streams for
        every full window available. Returns slot's best hypothesis."""
        eng = self._require_engine()
        eng.feed_slot(slot, signal)
        eng.pump()
        return self.best(slot=slot)

    def best(self, *, slot: int = 0, final: bool = False):
        """Best hypothesis of stream `slot` (see ASRPU.best)."""
        if self._engine is None:
            return empty_hypothesis()
        return copy_result(self._engine.slot_best(slot, final=final))

    def serve(self, utterances) -> List[dict]:
        """Continuous batching over whole utterances (audio arrays);
        results in input order.  Delegates to AsrEngine.serve."""
        return self._require_engine().serve(utterances)
