"""ASRPU runtime: command decoder API + decoding-step scheduler (paper §3).

The accelerator's command set (Table 1) maps 1:1 onto this class:

  ConfigureASR_AcousticScoring  -> configure_acoustic_scoring(kernels)
  ConfigureASR_HypExpansion     -> configure_hyp_expansion(expand_fn)
  ConfigureBeamWidth            -> configure_beam_width(beam)
  DecodingStep                  -> decoding_step(signal_chunk)
  CleanDecoding                 -> clean_decoding()

Decoding steps (§3.1) run the acoustic-scoring phase (the kernel sequence:
feature extraction + one kernel per DNN layer) and then the
hypothesis-expansion phase once per emitted acoustic vector.

Setup threads (§3.2) become the static `StepPlan`: JAX needs static
shapes, so the per-kernel setup arithmetic — how many outputs are
producible from buffered inputs, what to retire, how many threads to
launch — runs at plan time and fixes the steady-state schedule; a step
whose buffers cannot produce a single output returns early exactly like a
setup thread returning zero.  The plan doubles as the driver for the
paper's instruction-count performance model (benchmarks/).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.tds_asr import (ASRPU_HW, DECODER_CONFIG, FEATURE_CONFIG,
                                   TDS_CONFIG, DecoderConfig, FeatureConfig,
                                   TDSConfig)
from repro.core import decoder as dec
from repro.core import features
from repro.core.lexicon import BigramLM, Lexicon
from repro.models import tds


@dataclass
class PlannedKernel:
    """One kernel execution inside a decoding step (Fig. 6)."""
    name: str
    kind: str
    n_threads: int          # threads launched by the ASR controller
    n_frames: int           # output frames this step
    macs_per_thread: int    # inner-loop MACs (setup thread metadata)
    weight_bytes: int
    n_subkernels: int


@dataclass
class StepPlan:
    """Static steady-state decoding-step schedule (the setup threads)."""
    samples_per_step: int
    feat_frames_per_step: int
    acoustic_frames_per_step: int   # hyp-expansion repetitions (Fig. 6)
    kernels: List[PlannedKernel]

    def total_threads(self) -> int:
        return sum(k.n_threads for k in self.kernels)


def make_step_plan(tds_cfg: TDSConfig = TDS_CONFIG,
                   feat_cfg: FeatureConfig = FEATURE_CONFIG,
                   step_ms: float = 80.0, beam_k: int = 128) -> StepPlan:
    """The setup-thread arithmetic for one steady-state decoding step."""
    samples = int(feat_cfg.sample_rate * step_ms / 1000)
    feat_frames = int(step_ms / feat_cfg.shift_ms)          # 8 @ 80ms
    sub = tds_cfg.total_subsample
    assert feat_frames % sub == 0, (feat_frames, sub)
    out_frames = feat_frames // sub
    kernels = [PlannedKernel(
        "mfcc", "feature", n_threads=feat_frames, n_frames=feat_frames,
        macs_per_thread=(feat_cfg.frame_len                  # window+preemph
                         + feat_cfg.n_fft * int(np.log2(feat_cfg.n_fft))
                         + (feat_cfg.n_fft // 2 + 1) * feat_cfg.n_mels
                         + feat_cfg.n_mels * feat_cfg.n_mfcc),
        weight_bytes=0, n_subkernels=1)]
    t = feat_frames
    for spec in tds.build_kernel_specs(tds_cfg):
        t_out = t // spec.stride
        if spec.kind == "layernorm":
            kernels.append(PlannedKernel(
                spec.name, spec.kind, n_threads=t_out, n_frames=t_out,
                macs_per_thread=2 * spec.n_out, weight_bytes=0,
                n_subkernels=1))
        else:
            # one thread per output neuron per frame (paper §3.1)
            kernels.append(PlannedKernel(
                spec.name, spec.kind, n_threads=t_out * spec.n_out,
                n_frames=t_out, macs_per_thread=spec.n_in,
                weight_bytes=spec.weight_bytes,
                n_subkernels=spec.n_subkernels))
        t = t_out
    assert t == out_frames, (t, out_frames)
    return StepPlan(samples, feat_frames, out_frames, kernels)


class ASRPU:
    """The accelerator, as a streaming decoder object (paper §3/§4)."""

    def __init__(self, hw=ASRPU_HW):
        self.hw = hw
        self._tds_cfg: Optional[TDSConfig] = None
        self._params = None
        self._feat_cfg = FEATURE_CONFIG
        self._dec_cfg = DECODER_CONFIG
        self._lex: Optional[Lexicon] = None
        self._lm: Optional[BigramLM] = None
        self._use_int8 = False
        self.plan: Optional[StepPlan] = None
        self._jit_step = None
        self.clean_decoding()

    # ---- configuration commands -------------------------------------
    def configure_acoustic_scoring(self, tds_cfg: TDSConfig, params,
                                   feat_cfg: FeatureConfig = FEATURE_CONFIG,
                                   use_int8: bool = False,
                                   step_ms: float = 80.0):
        self._tds_cfg, self._params = tds_cfg, params
        self._feat_cfg = feat_cfg
        self._use_int8 = use_int8
        self.plan = make_step_plan(tds_cfg, feat_cfg, step_ms,
                                   self._dec_cfg.beam_size)
        self._build_step()

    def configure_hyp_expansion(self, lex: Lexicon, lm: BigramLM,
                                dec_cfg: DecoderConfig = DECODER_CONFIG):
        self._lex, self._lm, self._dec_cfg = lex, lm, dec_cfg
        if self._tds_cfg is not None:
            self._build_step()

    def configure_beam_width(self, beam: float):
        from dataclasses import replace
        self._dec_cfg = replace(self._dec_cfg, beam_threshold=beam)
        if self._tds_cfg is not None and self._lex is not None:
            self._build_step()

    def clean_decoding(self):
        """Reset hypothesis memory + streaming buffers for a new utterance."""
        self._sample_buf = np.zeros((0,), np.float32)
        self._stream_state = None
        self._beam = None
        self._n_steps = 0

    # ---- the fused decoding-step program ------------------------------
    def _fused_step_fn(self) -> Callable:
        """The fused single-stream decoding step (acoustic scoring + one
        hypothesis expansion per emitted acoustic frame).  Pure in all
        carried state, so the multi-stream scheduler can vmap it over a
        leading slot axis unchanged."""
        tds_cfg, feat_cfg = self._tds_cfg, self._feat_cfg
        dec_cfg, lex, lm = self._dec_cfg, self._lex, self._lm
        use_int8 = self._use_int8
        nfr = self.plan.feat_frames_per_step

        def step(params, stream_state, beam_state, samples):
            feats = features.mfcc(samples, feat_cfg)[:nfr]
            logp, new_state = tds.forward(params, tds_cfg, feats,
                                          stream_state, use_int8=use_int8)

            def expand(bs, lp):
                return dec.expand_step(bs, lp, lex, lm, dec_cfg), None
            beam_state, _ = jax.lax.scan(expand, beam_state, logp)
            return new_state, beam_state

        return step

    def _build_step(self):
        if self._lex is None or self._tds_cfg is None:
            return
        self._jit_step = jax.jit(self._fused_step_fn())

    def _window(self):
        """(retired, needed) samples per decoding step: a step consumes
        samples_per_step and the MFCC framing additionally needs
        frame_len - frame_shift lookahead samples in the buffer."""
        spp = self.plan.samples_per_step
        look = self._feat_cfg.frame_len - self._feat_cfg.frame_shift
        return spp, spp + look

    # ---- runtime commands ---------------------------------------------
    def decoding_step(self, signal: np.ndarray):
        """Append `signal` to the stream and run decoding steps for every
        full 80ms window available. Returns the current best hypothesis."""
        assert self._jit_step is not None, "accelerator not configured"
        self._sample_buf = np.concatenate([self._sample_buf,
                                           np.asarray(signal, np.float32)])
        if self._stream_state is None:
            self._stream_state = tds.init_stream_state(self._tds_cfg)
            self._beam = dec.init_state(self._dec_cfg.beam_size, self._lm)
        spp, need = self._window()
        while self._sample_buf.shape[0] >= need:
            chunk = jnp.asarray(self._sample_buf[:need])
            self._sample_buf = self._sample_buf[spp:]
            self._stream_state, self._beam = self._jit_step(
                self._params, self._stream_state, self._beam, chunk)
            self._n_steps += 1
        return self.best()

    def best(self, final: bool = False):
        """Current best hypothesis. final=True commits a pending
        utterance-final word (call when the utterance is known to end)."""
        if self._beam is None:
            return {"words": np.zeros((0,), np.int32), "score": -np.inf}
        return self._best_of(self._beam, final)

    def _best_of(self, beam, final: bool):
        if final:
            beam = dec.finalize(beam, self._lex, self._lm, self._dec_cfg)
        b = dec.best(beam)
        n = int(b["n_words"])
        return {"words": np.asarray(b["words"])[:n],
                "tokens": np.asarray(b["tokens"])[:int(b["n_tokens"])],
                "score": float(b["score"])}


class MultiStreamASRPU(ASRPU):
    """B concurrent utterance streams through ONE vmapped decoding step.

    The single-stream ASRPU advances one `_stream_state`/`_beam` per
    DecodingStep; at server scale the fused step must run at batch size
    B.  This scheduler owns a slot pool (mirroring `serve_lm`'s
    continuous batching): every pytree leaf of the TDS stream state and
    the BeamState carries a leading slot axis, each slot has its own
    sample buffer, and one jitted `vmap` of the fused step advances all
    slots that have a full 80 ms window.  Slots without a full window are
    masked out — their carried state passes through unchanged, so each
    slot's trajectory is exactly the single-stream one (parity-tested in
    tests/test_multistream.py).

    Command API extensions over ASRPU:
      CleanDecoding(slot)   -> clean_decoding(slot=s): reset one stream
      DecodingStep(slot, x) -> decoding_step(x, slot=s)
      serve(utterances)     -> continuous batching: admission of queued
                               utterances into freed slots until drained
    """

    def __init__(self, n_streams: int, hw=ASRPU_HW):
        assert n_streams >= 1
        self.n_streams = n_streams
        super().__init__(hw)

    # ---- the vmapped fused step --------------------------------------
    def _build_step(self):
        if self._lex is None or self._tds_cfg is None:
            return
        vstep = jax.vmap(self._fused_step_fn(), in_axes=(None, 0, 0, 0))

        def step(params, stream_state, beam_state, samples, active):
            new_ss, new_bs = vstep(params, stream_state, beam_state, samples)

            def keep(new, old):
                m = active.reshape((-1,) + (1,) * (new.ndim - 1))
                return jnp.where(m, new, old)
            return (jax.tree.map(keep, new_ss, stream_state),
                    jax.tree.map(keep, new_bs, beam_state))

        self._jit_step = jax.jit(step)

    # ---- slot-pool state ---------------------------------------------
    def clean_decoding(self, slot: Optional[int] = None):
        """Reset all streams (slot=None) or one stream's buffers, left
        context, and hypothesis memory (utterance boundary in a slot)."""
        if slot is None:
            self._slot_bufs = [np.zeros((0,), np.float32)
                               for _ in range(self.n_streams)]
            self._slot_steps = np.zeros((self.n_streams,), np.int64)
            self._stream_state = None
            self._beam = None
            self._n_steps = 0
            return
        self._slot_bufs[slot] = np.zeros((0,), np.float32)
        self._slot_steps[slot] = 0
        if self._stream_state is not None:
            self._stream_state = tds.reset_stream_slot(
                self._stream_state, slot, self._tds_cfg)
            self._beam = dec.reset_slot(self._beam, slot, self._lm)

    def _ensure_state(self):
        if self._stream_state is None:
            self._stream_state = tds.init_batched_stream_state(
                self._tds_cfg, self.n_streams)
            self._beam = dec.init_batched_state(
                self.n_streams, self._dec_cfg.beam_size, self._lm)

    def _pump_once(self) -> bool:
        """One vmapped decoding step advancing every slot that has a full
        window buffered; masked slots carry state through unchanged.
        Returns False (and runs nothing) when no slot can produce output
        — the setup threads all returned zero."""
        spp, need = self._window()
        active = np.array([b.shape[0] >= need for b in self._slot_bufs])
        if not active.any():
            return False
        batch = np.zeros((self.n_streams, need), np.float32)
        for s in range(self.n_streams):
            if active[s]:
                batch[s] = self._slot_bufs[s][:need]
                self._slot_bufs[s] = self._slot_bufs[s][spp:]
        self._stream_state, self._beam = self._jit_step(
            self._params, self._stream_state, self._beam,
            jnp.asarray(batch), jnp.asarray(active))
        self._slot_steps += active
        self._n_steps += 1
        return True

    # ---- runtime commands --------------------------------------------
    # slot/final are keyword-only: through the ASRPU-typed interface a
    # positional best(True) would otherwise bind slot=1 silently.
    def decoding_step(self, signal: np.ndarray, *, slot: int = 0):
        """Append `signal` to stream `slot` and advance ALL streams for
        every full window available. Returns slot's best hypothesis."""
        assert self._jit_step is not None, "accelerator not configured"
        self._slot_bufs[slot] = np.concatenate(
            [self._slot_bufs[slot], np.asarray(signal, np.float32)])
        self._ensure_state()
        while self._pump_once():
            pass
        return self.best(slot=slot)

    def best(self, *, slot: int = 0, final: bool = False):
        """Best hypothesis of stream `slot` (see ASRPU.best)."""
        if self._beam is None:
            return {"words": np.zeros((0,), np.int32), "score": -np.inf}
        return self._best_of(dec.slot_state(self._beam, slot), final)

    def serve(self, utterances) -> List[dict]:
        """Continuous batching over whole utterances (audio arrays).

        Queued utterances are admitted into free slots; one vmapped step
        advances every active slot; a slot whose buffer can no longer
        fill a window is finalized (pending word committed) and freed for
        the next queued utterance.  Results come back in input order."""
        assert self._jit_step is not None, "accelerator not configured"
        self._ensure_state()
        _, need = self._window()
        queue = deque(enumerate(utterances))
        owner: List[Optional[int]] = [None] * self.n_streams
        results = {}
        while queue or any(o is not None for o in owner):
            for s in range(self.n_streams):
                if owner[s] is None and queue:
                    rid, audio = queue.popleft()
                    self.clean_decoding(slot=s)
                    self._slot_bufs[s] = np.asarray(audio, np.float32)
                    owner[s] = rid
            self._pump_once()
            for s in range(self.n_streams):
                if owner[s] is not None and self._slot_bufs[s].shape[0] < need:
                    res = self.best(slot=s, final=True)
                    res["steps"] = int(self._slot_steps[s])
                    results[owner[s]] = res
                    owner[s] = None
        return [results[i] for i in range(len(utterances))]
