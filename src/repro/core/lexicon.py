"""Lexicon trie + n-gram language model as dense padded arrays.

ASRPU traverses graph structures (lexicon tree, n-gram LM) with random
access through an LRU cache (paper §3.6).  The TPU-idiomatic equivalent is
dense padded arrays traversed with gathers (DESIGN.md §2): each trie node
stores up to `max_children` (child_id, token) pairs; word-final nodes carry
a word id for the LM.

The n-gram LM here is a bigram table (dense (n_words+1, n_words) log-prob
matrix; row n_words = sentence start).  Production n-gram models would use
the same interface over hashed arrays; the decoder only calls `lm_score`
and `lm_advance`.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class Lexicon:
    """Padded trie over acoustic tokens."""
    children: jax.Array      # (n_nodes, C) int32 child node id, -1 = pad
    child_token: jax.Array   # (n_nodes, C) int32 acoustic token on the edge
    word_id: jax.Array       # (n_nodes,) int32 word id if word-final else -1
    n_nodes: int
    max_children: int

    @property
    def root(self) -> int:
        return 0


def build_lexicon(words: Dict[str, Sequence[int]], max_children: int) -> Lexicon:
    """words: word -> token-id sequence. Word ids = insertion order."""
    children: List[Dict[int, int]] = [{}]
    word_id: List[int] = [-1]
    for wid, (_word, toks) in enumerate(words.items()):
        node = 0
        for t in toks:
            nxt = children[node].get(t)
            if nxt is None:
                nxt = len(children)
                children[node][t] = nxt
                children.append({})
                word_id.append(-1)
            node = nxt
        word_id[node] = wid
    n = len(children)
    ch = np.full((n, max_children), -1, np.int32)
    ct = np.full((n, max_children), -1, np.int32)
    for i, cs in enumerate(children):
        assert len(cs) <= max_children, f"fanout {len(cs)} > {max_children}"
        for j, (t, c) in enumerate(sorted(cs.items())):
            ch[i, j] = c
            ct[i, j] = t
    return Lexicon(jnp.asarray(ch), jnp.asarray(ct), jnp.asarray(word_id),
                   n, max_children)


@dataclass(frozen=True)
class BigramLM:
    """log P(w | prev). State = prev word id; start state = n_words."""
    table: jax.Array         # (n_words + 1, n_words) f32 log-probs
    n_words: int

    @property
    def start_state(self) -> int:
        return self.n_words

    def score(self, state: jax.Array, word: jax.Array) -> jax.Array:
        return self.table[state, word]

    def advance(self, state: jax.Array, word: jax.Array) -> jax.Array:
        del state
        return word


def uniform_bigram(n_words: int) -> BigramLM:
    t = jnp.full((n_words + 1, n_words), -np.log(n_words), jnp.float32)
    return BigramLM(t, n_words)


def bigram_from_counts(counts: np.ndarray, alpha: float = 0.5) -> BigramLM:
    """counts: (n_words+1, n_words) raw bigram counts (last row = <s>)."""
    c = counts.astype(np.float64) + alpha
    t = np.log(c / c.sum(axis=1, keepdims=True)).astype(np.float32)
    return BigramLM(jnp.asarray(t), counts.shape[1])
