"""CTC prefix-beam-search decoding with lexicon trie + n-gram LM (paper §4.3).

Each hypothesis-expansion execution (one acoustic frame) expands every
live hypothesis into:
  * 1 "stay" candidate  — CTC blank (pb channel) + CTC repeat (pnb channel),
  * C "continue" candidates — one per reachable lexicon-trie child,
  * C "commit" candidates — child is word-final: word is emitted, the LM
    scores the word, the hypothesis returns to the trie root.
exactly the candidate structure of the paper's hypothesis-expansion kernel
(reachable nodes + blank + repetition).  The hypothesis unit
(core/hypothesis.py) then merges duplicates and sort-prunes to K.

All state is fixed-shape struct-of-arrays; one utterance decode is a
lax.scan over frames.  `greedy_decode` is the paper's "simplest approach"
baseline (best token per frame, collapse repeats, drop blanks).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.tds_asr import DecoderConfig
from repro.core import hypothesis as hyp
from repro.core import treeutil
from repro.core.lexicon import BigramLM, Lexicon

NEG_INF = hyp.NEG_INF
MAX_TOKENS = 256
MAX_WORDS = 64


def _mix(h: jax.Array, x: jax.Array) -> jax.Array:
    """31-bit multiplicative prefix hash."""
    return ((h * jnp.int32(1000003)) ^ (x + jnp.int32(0x9E3779B))) & jnp.int32(
        0x7FFFFFFF)


class BeamState(NamedTuple):
    hash: jax.Array        # (K,)
    pb: jax.Array          # (K,)
    pnb: jax.Array         # (K,)
    node: jax.Array        # (K,) lexicon trie node
    lm_state: jax.Array    # (K,)
    last_token: jax.Array  # (K,) last emitted token (-1 = none)
    tokens: jax.Array      # (K, MAX_TOKENS) emitted token history
    n_tokens: jax.Array    # (K,)
    words: jax.Array       # (K, MAX_WORDS) committed word ids
    n_words: jax.Array     # (K,)


def init_state(k: int, lm: BigramLM) -> BeamState:
    def full(v, dt=jnp.float32):
        return jnp.full((k,), v, dt)
    return BeamState(
        hash=jnp.zeros((k,), jnp.int32).at[0].set(1),
        pb=full(NEG_INF).at[0].set(0.0),
        pnb=full(NEG_INF),
        node=jnp.zeros((k,), jnp.int32),
        lm_state=jnp.full((k,), lm.start_state, jnp.int32),
        last_token=full(-1, jnp.int32),
        tokens=jnp.full((k, MAX_TOKENS), -1, jnp.int32),
        n_tokens=jnp.zeros((k,), jnp.int32),
        words=jnp.full((k, MAX_WORDS), -1, jnp.int32),
        n_words=jnp.zeros((k,), jnp.int32),
    )


def _append(arr, n, val):
    """arr: (K, L); n: (K,); val: (K,) -> set arr[i, n[i]] = val[i]."""
    L = arr.shape[-1]
    onehot = jnp.arange(L)[None, :] == jnp.minimum(n, L - 1)[:, None]
    return jnp.where(onehot, val[:, None], arr)


def _append_if(arr, n, val):
    """Batched conditional append: arr (B, K, L); n/val (B, K); append
    `val` at position n where val >= 0, else pass the row through."""
    L = arr.shape[-1]
    onehot = (jnp.arange(L)[None, None, :]
              == jnp.minimum(n, L - 1)[:, :, None]) & (val >= 0)[:, :, None]
    return jnp.where(onehot, val[:, :, None], arr)


def expand_step_batched(state: BeamState, log_probs: jax.Array, lex: Lexicon,
                        lm: BigramLM, cfg: DecoderConfig,
                        kernels=None) -> BeamState:
    """One natively batched hypothesis-expansion execution.

    state: (B, K, ...) BeamState; log_probs: (B, V) — one acoustic frame
    per stream.  The lexicon trie and bigram table are SHARED across
    slots: every gather (`children`/`child_token`/`word_id`, bigram
    scores) runs once over the flattened (B*K,) / (B*K*C,) index set
    instead of per slot (the old path vmapped the whole per-stream step,
    re-gathering the shared tables slot by slot).  The merge/threshold/
    top-k lands in the fused hypothesis unit with a batch grid axis.

    Candidates carry only SCALAR payload fields; the token/word history
    rows of the K winners are reconstructed from (parent, appended
    token/word) after selection.  Materializing per-candidate histories
    — (B, K(2C+1), MAX_TOKENS) broadcasts — moved tens of MB per frame
    and dominated the expansion's wall clock."""
    B, K = state.hash.shape
    C = lex.max_children
    lp = log_probs.astype(jnp.float32)                   # (B, V)
    tot = hyp.total_score(state.pb, state.pnb)           # (B, K)
    alive = tot > NEG_INF / 2

    # ---- stay candidates (blank + repeat), one per hypothesis ----------
    lp_last = jnp.where(
        state.last_token >= 0,
        jnp.take_along_axis(lp, jnp.maximum(state.last_token, 0), axis=1),
        NEG_INF)                                         # (B, K)
    parent0 = jnp.broadcast_to(jnp.arange(K, dtype=jnp.int32)[None],
                               (B, K))
    stay = hyp.Candidates(
        hash=state.hash,
        pb=jnp.where(alive, tot + lp[:, cfg.blank_id][:, None], NEG_INF),
        pnb=jnp.where(alive, state.pnb + lp_last, NEG_INF),
        fields=dict(node=state.node, lm_state=state.lm_state,
                    last_token=state.last_token, n_tokens=state.n_tokens,
                    n_words=state.n_words, parent=parent0,
                    app_tok=jnp.full((B, K), -1, jnp.int32),
                    app_word=jnp.full((B, K), -1, jnp.int32)),
    )

    # ---- extension candidates (continue / commit), K x C per slot ------
    # shared-lexicon gathers: one flattened (B*K,) index set
    nodes_f = state.node.reshape(B * K)
    child = lex.children[nodes_f].reshape(B, K, C)
    ctok = lex.child_token[nodes_f].reshape(B, K, C)
    has_child = child >= 0
    ctok_s = jnp.maximum(ctok, 0)
    lp_ext = jnp.where(
        has_child,
        jnp.take_along_axis(lp, ctok_s.reshape(B, K * C),
                            axis=1).reshape(B, K, C),
        NEG_INF)                                         # (B, K, C)
    # CTC merge rule: extending with the last token needs a blank in between
    same = ctok_s == state.last_token[:, :, None]
    base = jnp.where(same, state.pb[:, :, None], tot[:, :, None])
    pnb_ext = jnp.where(alive[:, :, None], base + lp_ext, NEG_INF)

    h_ext = _mix(state.hash[:, :, None], ctok_s * 2)     # continue-hash
    n_tok_ext = state.n_tokens[:, :, None] + 1
    lm_state_b = jnp.broadcast_to(state.lm_state[:, :, None], (B, K, C))
    parent_b = jnp.broadcast_to(parent0[:, :, None], (B, K, C))

    def flat(x):
        return x.reshape((B, K * C) + x.shape[3:])

    cont = hyp.Candidates(
        hash=flat(h_ext),
        pb=jnp.full((B, K * C), NEG_INF),
        pnb=flat(pnb_ext),
        fields=dict(
            node=flat(child),
            lm_state=flat(lm_state_b),
            last_token=flat(ctok_s),
            n_tokens=flat(jnp.broadcast_to(n_tok_ext, (B, K, C))),
            n_words=flat(jnp.broadcast_to(state.n_words[:, :, None],
                                          (B, K, C))),
            parent=flat(parent_b),
            app_tok=flat(ctok_s),
            app_word=flat(jnp.full((B, K, C), -1, jnp.int32)),
        ),
    )

    wid = jnp.where(
        has_child,
        lex.word_id[jnp.maximum(child, 0).reshape(B * K * C)
                    ].reshape(B, K, C),
        -1)
    is_word = wid >= 0
    wid_s = jnp.maximum(wid, 0)
    lm_sc = lm.score(lm_state_b, wid_s)    # one shared bigram-table gather
    commit_pnb = jnp.where(is_word,
                           pnb_ext + cfg.lm_weight * lm_sc + cfg.word_score,
                           NEG_INF)
    h_commit = _mix(_mix(state.hash[:, :, None], ctok_s * 2 + 1), wid_s)

    commit = hyp.Candidates(
        hash=flat(h_commit),
        pb=jnp.full((B, K * C), NEG_INF),
        pnb=flat(commit_pnb),
        fields=dict(
            node=flat(jnp.where(is_word, lex.root, -1)),
            lm_state=flat(lm.advance(lm_state_b, wid_s)),
            last_token=flat(ctok_s),
            n_tokens=flat(jnp.broadcast_to(n_tok_ext, (B, K, C))),
            n_words=flat(jnp.broadcast_to(state.n_words[:, :, None] + 1,
                                          (B, K, C))),
            parent=flat(parent_b),
            app_tok=flat(ctok_s),
            app_word=flat(jnp.where(is_word, wid_s, -1)),
        ),
    )

    cand = hyp.Candidates(
        hash=jnp.concatenate([stay.hash, cont.hash, commit.hash], axis=1),
        pb=jnp.concatenate([stay.pb, cont.pb, commit.pb], axis=1),
        pnb=jnp.concatenate([stay.pnb, cont.pnb, commit.pnb], axis=1),
        fields={k: jnp.concatenate([stay.fields[k], cont.fields[k],
                                    commit.fields[k]], axis=1)
                for k in stay.fields},
    )
    sel = hyp.hypothesis_unit_step_batched(cand, K, cfg.beam_threshold,
                                           kernels)
    # reconstruct the K winners' token/word histories: gather the parent
    # rows and conditionally append the one new token/word
    parent = sel["parent"]                               # (B, K)
    par_tokens = jnp.take_along_axis(state.tokens, parent[:, :, None],
                                     axis=1)
    par_words = jnp.take_along_axis(state.words, parent[:, :, None], axis=1)
    appending = sel["app_tok"] >= 0
    tokens = _append_if(par_tokens,
                        sel["n_tokens"] - appending.astype(jnp.int32),
                        sel["app_tok"])
    words = _append_if(par_words,
                       sel["n_words"] - (sel["app_word"] >= 0
                                         ).astype(jnp.int32),
                       sel["app_word"])
    return BeamState(
        hash=sel["hash"], pb=sel["pb"], pnb=sel["pnb"], node=sel["node"],
        lm_state=sel["lm_state"], last_token=sel["last_token"],
        tokens=tokens, n_tokens=sel["n_tokens"], words=words,
        n_words=sel["n_words"])


def expand_step(state: BeamState, log_probs: jax.Array, lex: Lexicon,
                lm: BigramLM, cfg: DecoderConfig,
                kernels=None) -> BeamState:
    """One hypothesis-expansion execution over one acoustic frame for a
    single (K, ...) beam — the B=1 slice of the batched expansion, so
    single-stream and slot-pool decoding share one code path exactly."""
    out = expand_step_batched(
        jax.tree.map(lambda a: a[None], state), log_probs[None],
        lex, lm, cfg, kernels)
    return jax.tree.map(lambda a: a[0], out)


def decode(log_probs: jax.Array, lex: Lexicon, lm: BigramLM,
           cfg: DecoderConfig, kernels=None) -> BeamState:
    """Offline decode: log_probs (T, V) -> final beam state."""
    st = init_state(cfg.beam_size, lm)

    def step(s, lp):
        return expand_step(s, lp, lex, lm, cfg, kernels), None
    st, _ = jax.lax.scan(step, st, log_probs)
    return st


# ---------------------------------------------------------------------------
# batched (multi-stream) decoding: `expand_step_batched` above is natively
# slot-batched (shared lexicon/LM gathers, batch grid axis through the fused
# hypothesis unit).  BeamState leaves are (B, K, ...).  The slot helpers
# below are the beam-memory half of the serving engine's slot pool
# (repro.serving.asr.AsrEngine owns them at runtime).
# ---------------------------------------------------------------------------
def init_batched_state(batch: int, k: int, lm: BigramLM) -> BeamState:
    """Beam state for `batch` independent streams: leaves are (B, K, ...)."""
    return treeutil.batch_tree(init_state(k, lm), batch)


def decode_batched(log_probs: jax.Array, lex: Lexicon, lm: BigramLM,
                   cfg: DecoderConfig, kernels=None) -> BeamState:
    """Offline batched decode: log_probs (B, T, V) -> (B, K, ...) beams."""
    st = init_batched_state(log_probs.shape[0], cfg.beam_size, lm)

    def step(s, lp):
        return expand_step_batched(s, lp, lex, lm, cfg, kernels), None
    st, _ = jax.lax.scan(step, st, jnp.swapaxes(log_probs, 0, 1))
    return st


def finalize_batched(state: BeamState, lex: Lexicon, lm: BigramLM,
                     cfg: DecoderConfig) -> BeamState:
    """finalize over a leading stream axis: (B, K, ...) -> (B, K, ...)."""
    return jax.vmap(lambda s: finalize(s, lex, lm, cfg))(state)


def slot_state(state: BeamState, slot) -> BeamState:
    """Slice one stream's (K, ...) beam out of a (B, K, ...) batch."""
    return jax.tree.map(lambda a: a[slot], state)


def reset_slot(state: BeamState, slot, lm: BigramLM) -> BeamState:
    """Return `state` with stream `slot` reset to a fresh init_state."""
    return treeutil.set_slot(state, slot, init_state(state.hash.shape[1], lm))


def finalize(state: BeamState, lex: Lexicon, lm: BigramLM,
             cfg: DecoderConfig) -> BeamState:
    """End-of-utterance: commit pending word-final hypotheses.

    Words are normally committed when the search *extends past* a
    word-final trie node; the utterance's last word has no such extension
    step, so hypotheses sitting on a word-final node get their word (and
    LM score) applied here."""
    wid = lex.word_id[jnp.maximum(state.node, 0)]
    pend = (wid >= 0) & (state.node != lex.root)
    wid_s = jnp.maximum(wid, 0)
    bonus = cfg.lm_weight * lm.score(state.lm_state, wid_s) + cfg.word_score
    pb = jnp.where(pend & (state.pb > NEG_INF / 2), state.pb + bonus,
                   state.pb)
    pnb = jnp.where(pend & (state.pnb > NEG_INF / 2), state.pnb + bonus,
                    state.pnb)
    words = jnp.where(pend[:, None],
                      _append(state.words, state.n_words, wid_s),
                      state.words)
    return state._replace(
        pb=pb, pnb=pnb, words=words,
        n_words=jnp.where(pend, state.n_words + 1, state.n_words),
        lm_state=jnp.where(pend, lm.advance(state.lm_state, wid_s),
                           state.lm_state),
        node=jnp.where(pend, lex.root, state.node))


def best(state: BeamState) -> dict:
    i = jnp.argmax(hyp.total_score(state.pb, state.pnb))
    return {"score": hyp.total_score(state.pb, state.pnb)[i],
            "words": state.words[i], "n_words": state.n_words[i],
            "tokens": state.tokens[i], "n_tokens": state.n_tokens[i]}


def materialize_best(b: dict) -> dict:
    """Trim a `best` readout to host arrays: words/tokens cut to their
    true lengths + float score (the result payload of the serving
    engine and of the deprecated ASRPU command shims)."""
    n = int(b["n_words"])
    return {"words": np.asarray(b["words"])[:n],
            "tokens": np.asarray(b["tokens"])[:int(b["n_tokens"])],
            "score": float(b["score"])}


def best_hypothesis(state: BeamState, lex: Lexicon, lm: BigramLM,
                    cfg: DecoderConfig, *, final: bool = False) -> dict:
    """Materialize the best hypothesis of one (K, ...) beam as host
    arrays.  final=True first commits a pending utterance-final word
    (see `finalize`); the input state is not modified."""
    if final:
        state = finalize(state, lex, lm, cfg)
    return materialize_best(best(state))


def greedy_decode(log_probs: jax.Array, blank_id: int = 0) -> jax.Array:
    """Paper's baseline: best token per frame, collapse repeats, drop blanks.

    Returns (T,) int32, -1-padded collapsed token sequence.
    """
    ids = jnp.argmax(log_probs, axis=-1).astype(jnp.int32)     # (T,)
    prev = jnp.concatenate([jnp.full((1,), -1, jnp.int32), ids[:-1]])
    keep = (ids != blank_id) & (ids != prev)
    T = ids.shape[0]
    pos = jnp.cumsum(keep) - 1
    out = jnp.full((T,), -1, jnp.int32)
    return out.at[jnp.where(keep, pos, T)].set(
        jnp.where(keep, ids, -1), mode="drop")
