"""MFCC feature extraction (paper §2.1 / Fig. 3), pure JAX.

Pipeline: pre-emphasis -> 25ms/10ms framing -> Hamming window -> |FFT|^2
-> mel filterbank (80 banks) -> log -> DCT-II -> 80-dim MFCC.
The hot post-FFT stages (mel matmul + log + DCT matmul) have a fused
Pallas kernel (kernels/logmel.py); this module is the reference/driver.

Streaming: `frames_producible` is the setup-thread arithmetic (paper §3.2)
— how many whole frames fit in the buffered signal; `extract_frames`
consumes exactly that many shifts and returns the leftover samples.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.tds_asr import FeatureConfig

# shared default (defaults are evaluated once anyway; a named constant
# keeps that explicit and call-free — flake8-bugbear B008)
DEFAULT_FEATURE_CONFIG = FeatureConfig()


def hz_to_mel(f):
    return 2595.0 * np.log10(1.0 + f / 700.0)


def mel_to_hz(m):
    return 700.0 * (10.0 ** (m / 2595.0) - 1.0)


@functools.lru_cache()
def mel_filterbank(cfg: FeatureConfig) -> np.ndarray:
    """(n_fft//2+1, n_mels) triangular filterbank."""
    n_bins = cfg.n_fft // 2 + 1
    freqs = np.linspace(0, cfg.sample_rate / 2, n_bins)
    mels = np.linspace(hz_to_mel(cfg.fmin), hz_to_mel(cfg.fmax), cfg.n_mels + 2)
    pts = mel_to_hz(mels)
    fb = np.zeros((n_bins, cfg.n_mels), np.float32)
    for m in range(cfg.n_mels):
        lo, c, hi = pts[m], pts[m + 1], pts[m + 2]
        up = (freqs - lo) / max(c - lo, 1e-9)
        down = (hi - freqs) / max(hi - c, 1e-9)
        fb[:, m] = np.maximum(0.0, np.minimum(up, down))
    return fb


@functools.lru_cache()
def dct_matrix(n_in: int, n_out: int) -> np.ndarray:
    """Orthonormal DCT-II, (n_in, n_out)."""
    k = np.arange(n_out)[None, :]
    n = np.arange(n_in)[:, None]
    m = np.cos(np.pi * k * (2 * n + 1) / (2 * n_in)) * math.sqrt(2.0 / n_in)
    m[:, 0] *= 1.0 / math.sqrt(2.0)
    return m.astype(np.float32)


def frames_producible(n_samples: int, cfg: FeatureConfig) -> int:
    """Setup-thread arithmetic: whole frames extractable from n samples."""
    if n_samples < cfg.frame_len:
        return 0
    return 1 + (n_samples - cfg.frame_len) // cfg.frame_shift


def consumed_samples(n_frames: int, cfg: FeatureConfig) -> int:
    """Samples that can be retired after emitting n_frames (keep overlap)."""
    return n_frames * cfg.frame_shift


def mfcc(signal: jax.Array, cfg: FeatureConfig = DEFAULT_FEATURE_CONFIG,
         use_pallas: bool = False, kernels=None,
         hot: bool = False) -> jax.Array:
    """signal: (..., n_samples) f32 -> (..., n_frames, n_mfcc) f32.

    Leading axes are batch (the serving engine extracts every slot's
    window in one call — B slots fold into the logmel matmul's row
    dimension).  use_pallas routes the mel+log+DCT tail through the
    fused logmel kernel, dispatched by the `kernels` KernelPolicy
    (None = auto; `hot` marks the call as decode-hot-path so auto never
    picks the interpreter)."""
    n = frames_producible(signal.shape[-1], cfg)
    assert n > 0, "not enough samples for one frame"
    # pre-emphasis
    sig = jnp.concatenate(
        [signal[..., :1], signal[..., 1:] - cfg.preemphasis * signal[..., :-1]],
        axis=-1)
    idx = (jnp.arange(n)[:, None] * cfg.frame_shift
           + jnp.arange(cfg.frame_len)[None, :])
    frames = jnp.take(sig, idx, axis=-1)             # (..., n, frame_len)
    win = jnp.asarray(np.hamming(cfg.frame_len).astype(np.float32))
    frames = frames * win
    spec = jnp.fft.rfft(frames, n=cfg.n_fft, axis=-1)
    power = jnp.square(jnp.abs(spec)).astype(jnp.float32)    # (..., n, n_bins)
    fb = jnp.asarray(mel_filterbank(cfg))
    dct = jnp.asarray(dct_matrix(cfg.n_mels, cfg.n_mfcc))
    if use_pallas:
        from repro.kernels import ops
        rows = power.reshape(-1, power.shape[-1])
        out = ops.logmel(rows, fb, dct, policy=kernels, hot=hot)
        return out.reshape(power.shape[:-1] + (out.shape[-1],))
    logmel = jnp.log(jnp.maximum(power @ fb, 1e-10))
    return logmel @ dct


def deltas(feats: jax.Array, window: int = 2) -> jax.Array:
    """Regression-based dynamic features (paper §2.1: "dynamic features,
    such as delta and delta-delta, can be appended").

    feats: (T, C) -> (T, C) delta coefficients:
        d_t = sum_n n·(x_{t+n} - x_{t-n}) / (2·sum_n n^2),  edge-padded.
    """
    T, C = feats.shape
    denom = 2.0 * sum(n * n for n in range(1, window + 1))
    padded = jnp.concatenate([
        jnp.repeat(feats[:1], window, axis=0), feats,
        jnp.repeat(feats[-1:], window, axis=0)], axis=0)
    out = jnp.zeros_like(feats)
    for n in range(1, window + 1):
        out = out + n * (padded[window + n:window + n + T]
                         - padded[window - n:window - n + T])
    return out / denom


def mfcc_with_deltas(signal: jax.Array,
                     cfg: FeatureConfig = DEFAULT_FEATURE_CONFIG) -> jax.Array:
    """(n_frames, 3*n_mfcc): static + delta + delta-delta."""
    static = mfcc(signal, cfg)
    d1 = deltas(static)
    d2 = deltas(d1)
    return jnp.concatenate([static, d1, d2], axis=-1)
