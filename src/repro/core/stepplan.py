"""Static decoding-step schedule — the paper's setup threads (§3.2).

JAX needs static shapes, so the per-kernel setup arithmetic — how many
outputs are producible from buffered inputs, what to retire, how many
threads to launch — runs at plan time and fixes the steady-state
schedule; a step whose buffers cannot produce a single output returns
early exactly like a setup thread returning zero.  The plan doubles as
the driver for the paper's instruction-count performance model
(benchmarks/asrpu_model.py) and as the `Program` schedule of the serving
engine (repro.serving): one `StepPlan` per configured acoustic program.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List

import numpy as np

from repro.configs.tds_asr import (FEATURE_CONFIG, TDS_CONFIG, FeatureConfig,
                                   TDSConfig)
from repro.models import tds


@dataclass
class PlannedKernel:
    """One kernel execution inside a decoding step (Fig. 6)."""
    name: str
    kind: str
    n_threads: int          # threads launched by the ASR controller
    n_frames: int           # output frames this step
    macs_per_thread: int    # inner-loop MACs (setup thread metadata)
    weight_bytes: int
    n_subkernels: int


@dataclass
class StepPlan:
    """Static steady-state decoding-step schedule (the setup threads)."""
    samples_per_step: int
    feat_frames_per_step: int
    acoustic_frames_per_step: int   # hyp-expansion repetitions (Fig. 6)
    kernels: List[PlannedKernel]

    def total_threads(self) -> int:
        return sum(k.n_threads for k in self.kernels)


def make_step_plan(tds_cfg: TDSConfig = TDS_CONFIG,
                   feat_cfg: FeatureConfig = FEATURE_CONFIG,
                   step_ms: float = 80.0, beam_k: int = 128) -> StepPlan:
    """The setup-thread arithmetic for one steady-state decoding step."""
    samples = int(feat_cfg.sample_rate * step_ms / 1000)
    feat_frames = int(step_ms / feat_cfg.shift_ms)          # 8 @ 80ms
    sub = tds_cfg.total_subsample
    assert feat_frames % sub == 0, (feat_frames, sub)
    out_frames = feat_frames // sub
    kernels = [PlannedKernel(
        "mfcc", "feature", n_threads=feat_frames, n_frames=feat_frames,
        macs_per_thread=(feat_cfg.frame_len                  # window+preemph
                         + feat_cfg.n_fft * int(np.log2(feat_cfg.n_fft))
                         + (feat_cfg.n_fft // 2 + 1) * feat_cfg.n_mels
                         + feat_cfg.n_mels * feat_cfg.n_mfcc),
        weight_bytes=0, n_subkernels=1)]
    t = feat_frames
    for spec in tds.build_kernel_specs(tds_cfg):
        t_out = t // spec.stride
        if spec.kind == "layernorm":
            kernels.append(PlannedKernel(
                spec.name, spec.kind, n_threads=t_out, n_frames=t_out,
                macs_per_thread=2 * spec.n_out, weight_bytes=0,
                n_subkernels=1))
        else:
            # one thread per output neuron per frame (paper §3.1)
            kernels.append(PlannedKernel(
                spec.name, spec.kind, n_threads=t_out * spec.n_out,
                n_frames=t_out, macs_per_thread=spec.n_in,
                weight_bytes=spec.weight_bytes,
                n_subkernels=spec.n_subkernels))
        t = t_out
    assert t == out_frames, (t, out_frames)
    return StepPlan(samples, feat_frames, out_frames, kernels)
