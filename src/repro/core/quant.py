"""int8 quantization — the TPU mapping of ASRPU's 8-wide int8 MAC (fp32 acc).

Block-wise symmetric int8 over the last dim (block 128 = MXU lane width).
Used by: kernels/int8_matmul (weight quantization for serving), optim/adamw
(8-bit optimizer moments), parallel/compress (gradient compression).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 128


def quantize(x: jax.Array, block: int = BLOCK) -> dict:
    """x: (..., D) -> {'q': int8 (..., D), 'scale': f32 (..., D/block)}."""
    D = x.shape[-1]
    pad = (-D) % block
    xf = x.astype(jnp.float32)
    if pad:
        xf = jnp.pad(xf, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    nb = xf.shape[-1] // block
    xb = xf.reshape(*xf.shape[:-1], nb, block)
    scale = jnp.max(jnp.abs(xb), axis=-1) / 127.0            # (..., nb)
    q = jnp.round(xb / jnp.maximum(scale[..., None], 1e-12))
    q = jnp.clip(q, -127, 127).astype(jnp.int8)
    q = q.reshape(*xf.shape[:-1], nb * block)[..., :D]
    return {"q": q, "scale": scale}


def dequantize(qs: dict, block: int = BLOCK) -> jax.Array:
    q, scale = qs["q"], qs["scale"]
    D = q.shape[-1]
    pad = (-D) % block
    qf = q.astype(jnp.float32)
    if pad:
        qf = jnp.pad(qf, [(0, 0)] * (q.ndim - 1) + [(0, pad)])
    nb = qf.shape[-1] // block
    xb = qf.reshape(*qf.shape[:-1], nb, block) * scale[..., None]
    return xb.reshape(*qf.shape[:-1], nb * block)[..., :D]
