"""CTC loss (Graves et al. 2006) — forward algorithm in log space.

The training counterpart of the decoder: wav2letter-style systems
(paper §4) train the TDS acoustic model with CTC.  Standard extended
label sequence (blank-interleaved), alpha recursion as a lax.scan over
time, logsumexp accumulation, -1-padded labels supported.

`ctc_loss` is validated against a brute-force path enumeration on small
cases (tests/test_ctc.py) and used by the end-to-end ASR training test
(train tiny TDS on synthetic utterances -> WER drops).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

NEG = -1e30


def ctc_loss(log_probs: jax.Array, labels: jax.Array,
             blank_id: int = 0) -> jax.Array:
    """log_probs: (T, V) log-softmax outputs; labels: (L,) int32, -1 pad.

    Returns scalar negative log likelihood of the label sequence.
    """
    T, V = log_probs.shape
    L = labels.shape[0]
    n_lab = jnp.sum(labels >= 0)
    lab = jnp.where(labels >= 0, labels, blank_id)
    # extended sequence: blank, l1, blank, l2, ..., blank  (len 2L+1)
    S = 2 * L + 1
    ext = jnp.full((S,), blank_id, jnp.int32)
    ext = ext.at[1::2].set(lab)
    valid = jnp.arange(S) < 2 * n_lab + 1
    # allow skip from s-2 when ext[s] != blank and ext[s] != ext[s-2]
    ext_m2 = jnp.concatenate([jnp.full((2,), -2, jnp.int32), ext[:-2]])
    can_skip = (jnp.arange(S) % 2 == 1) & (ext != ext_m2)

    alpha0 = jnp.full((S,), NEG)
    alpha0 = alpha0.at[0].set(log_probs[0, blank_id])
    alpha0 = alpha0.at[1].set(jnp.where(n_lab > 0, log_probs[0, lab[0]], NEG))

    def step(alpha, lp):
        stay = alpha
        prev = jnp.concatenate([jnp.full((1,), NEG), alpha[:-1]])
        skip = jnp.where(can_skip,
                         jnp.concatenate([jnp.full((2,), NEG), alpha[:-2]]),
                         NEG)
        a = jnp.logaddexp(jnp.logaddexp(stay, prev), skip)
        a = a + lp[ext]
        a = jnp.where(valid, a, NEG)
        return a, None

    alpha, _ = lax.scan(step, alpha0, log_probs[1:])
    end1 = alpha[2 * n_lab]          # final blank
    end2 = jnp.where(n_lab > 0, alpha[2 * n_lab - 1], NEG)
    return -jnp.logaddexp(end1, end2)


def ctc_loss_batch(log_probs: jax.Array, labels: jax.Array,
                   blank_id: int = 0) -> jax.Array:
    """(B, T, V) x (B, L) -> mean CTC loss."""
    return jnp.mean(jax.vmap(lambda lp, lb: ctc_loss(lp, lb, blank_id))(
        log_probs, labels))


def edit_distance(ref, hyp) -> int:
    """Levenshtein distance between two int sequences (python lists)."""
    ref, hyp = list(ref), list(hyp)
    dp = list(range(len(hyp) + 1))
    for i, r in enumerate(ref, 1):
        prev = dp[0]
        dp[0] = i
        for j, h in enumerate(hyp, 1):
            cur = dp[j]
            dp[j] = min(dp[j] + 1, dp[j - 1] + 1, prev + (r != h))
            prev = cur
    return dp[-1]


def wer(refs, hyps) -> float:
    """Word error rate over a corpus of (ref, hyp) id sequences."""
    errs = sum(edit_distance(r, h) for r, h in zip(refs, hyps))
    n = sum(len(r) for r in refs)
    return errs / max(n, 1)
