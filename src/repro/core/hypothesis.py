"""The Hypothesis Unit (paper §3.5), JAX-native.

ASRPU's hypothesis unit is a hardware block that (a) stores hypotheses
between decoding steps, (b) receives candidate hypotheses from expansion
threads, (c) merges duplicates (same hash), and (d) sorts + prunes by
score against the beam threshold.  Here a hypothesis set is a fixed-K
struct-of-arrays (the 24 KB hypothesis memory maps to fixed K with -inf
padding).

The whole merge -> threshold -> top-k operation is ONE fused op
(`kernels/ops.hypothesis_unit`): a batched argsort orders candidates by
prefix hash, then a single Pallas kernel (or its pure-jnp ref twin,
selected by `KernelPolicy`) does the segmented logsumexp merge, beam
threshold, and top-k selection per stream slot.  This module keeps the
candidate struct, the payload gathering around the fused op, and the
legacy `merge_duplicates`/`select` stages (still property-tested as the
semantic spec of the fused path).

Scores are kept as two CTC channels (blank / non-blank); the merge
logsumexps each channel independently, which is exactly CTC prefix-beam
merging.  `total = logaddexp(pb, pnb)` orders hypotheses.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30

# dead candidates sort under an out-of-range uint32 sentinel: a VALID
# candidate whose 31-bit hash happens to equal 2**31 - 1 used to collide
# with the old int32 sentinel and be silently dropped
_SENTINEL = jnp.uint32(0xFFFFFFFF)


class Candidates(NamedTuple):
    """Flat candidate set produced by one hypothesis-expansion execution."""
    hash: jax.Array      # (N,) int32 prefix hash (identity for merging)
    pb: jax.Array        # (N,) f32 log-prob ending in blank
    pnb: jax.Array       # (N,) f32 log-prob ending in non-blank
    fields: dict         # str -> (N, ...) programmer-defined payload


def total_score(pb: jax.Array, pnb: jax.Array) -> jax.Array:
    return jnp.logaddexp(pb, pnb)


def merge_duplicates(c: Candidates) -> Candidates:
    """logsumexp-merge candidates with equal hash (same prefix).

    After the merge, one representative per hash keeps the combined
    channels; the rest drop to -inf.  Payload fields of duplicates are
    identical by construction (same prefix), so the representative's
    payload is exact.  (Legacy stage: the decode hot path uses the fused
    `kernels/ops.hypothesis_unit` instead.)
    """
    n = c.hash.shape[0]
    valid = total_score(c.pb, c.pnb) > NEG_INF / 2
    key = jnp.where(valid, c.hash.astype(jnp.uint32), _SENTINEL)
    order = jnp.argsort(key)
    sk = key[order]
    seg_start = jnp.concatenate([jnp.ones((1,), bool), sk[1:] != sk[:-1]])
    seg_id = jnp.cumsum(seg_start) - 1                       # (N,)

    def seg_lse(x):
        m = jax.ops.segment_max(x, seg_id, num_segments=n)
        mx = m[seg_id]
        s = jax.ops.segment_sum(jnp.exp(x - mx), seg_id, num_segments=n)
        out = m + jnp.log(jnp.maximum(s, 1e-37))
        return jnp.where(m > NEG_INF / 2, out, NEG_INF)

    pb_m = seg_lse(c.pb[order])[seg_id]
    pnb_m = seg_lse(c.pnb[order])[seg_id]
    keep = seg_start & (sk != _SENTINEL)
    pb_new = jnp.where(keep, pb_m, NEG_INF)
    pnb_new = jnp.where(keep, pnb_m, NEG_INF)
    inv = jnp.argsort(order)
    fields = c.fields  # unpermuted; scatter merged scores back
    return Candidates(c.hash, pb_new[inv], pnb_new[inv], fields)


def select(c: Candidates, k: int, beam_threshold: float) -> dict:
    """Sort + prune: top-k by total score, then beam-threshold prune.

    Returns the new hypothesis set: dict of (k,)-arrays + 'valid' mask.
    (Legacy stage — see `merge_duplicates`.)
    """
    tot = total_score(c.pb, c.pnb)
    if k > tot.shape[0]:      # pad candidate set up to the beam size
        c = _pad_candidates(c, k - tot.shape[0])
        tot = total_score(c.pb, c.pnb)
    top, idx = jax.lax.top_k(tot, k)
    best = top[0]
    valid = (top > NEG_INF / 2) & (top >= best - beam_threshold)
    out = {"hash": c.hash[idx], "pb": c.pb[idx], "pnb": c.pnb[idx],
           "valid": valid}
    for name, arr in c.fields.items():
        out[name] = arr[idx]
    # invalidate pruned slots
    out["pb"] = jnp.where(valid, out["pb"], NEG_INF)
    out["pnb"] = jnp.where(valid, out["pnb"], NEG_INF)
    return out


def _pad_candidates(c: Candidates, pad: int) -> Candidates:
    return Candidates(
        jnp.pad(c.hash, [(0, 0)] * (c.hash.ndim - 1) + [(0, pad)]),
        jnp.pad(c.pb, [(0, 0)] * (c.pb.ndim - 1) + [(0, pad)],
                constant_values=NEG_INF),
        jnp.pad(c.pnb, [(0, 0)] * (c.pnb.ndim - 1) + [(0, pad)],
                constant_values=NEG_INF),
        {n: jnp.pad(a, [(0, 0)] * (c.hash.ndim - 1) + [(0, pad)]
                    + [(0, 0)] * (a.ndim - c.hash.ndim))
         for n, a in c.fields.items()})


def hypothesis_unit_step_batched(c: Candidates, k: int,
                                 beam_threshold: float,
                                 kernels=None) -> dict:
    """Fused hypothesis-unit operation over a batch of candidate rows.

    Candidate leaves carry a leading stream axis: hash/pb/pnb (B, N),
    fields (B, N, ...).  Returns dict of (B, k, ...) arrays + 'valid'.
    The merge/threshold/top-k itself is one `ops.hypothesis_unit` call
    (Pallas kernel or pure-jnp ref, per `kernels` policy); payload
    fields are gathered once with the returned representative indices.
    """
    from repro.kernels import ops

    if k > c.hash.shape[-1]:   # pad candidate set up to the beam size
        c = _pad_candidates(c, k - c.hash.shape[-1])
    sel = ops.hypothesis_unit(c.hash, c.pb, c.pnb, k, beam_threshold,
                              policy=kernels)
    idx = sel["idx"]                                       # (B, k)
    out = {"pb": sel["pb"], "pnb": sel["pnb"], "valid": sel["valid"],
           "hash": jnp.take_along_axis(c.hash, idx, axis=1)}
    for name, arr in c.fields.items():
        ix = idx.reshape(idx.shape + (1,) * (arr.ndim - 2))
        out[name] = jnp.take_along_axis(arr, ix, axis=1)
    return out


def hypothesis_unit_step(c: Candidates, k: int, beam_threshold: float,
                         kernels=None) -> dict:
    """Full hypothesis-unit operation: merge -> threshold -> top-k,
    fused (single-row convenience over the batched op)."""
    batched = Candidates(c.hash[None], c.pb[None], c.pnb[None],
                         {n: a[None] for n, a in c.fields.items()})
    out = hypothesis_unit_step_batched(batched, k, beam_threshold, kernels)
    return {name: a[0] for name, a in out.items()}
