"""The Hypothesis Unit (paper §3.5), JAX-native.

ASRPU's hypothesis unit is a hardware block that (a) stores hypotheses
between decoding steps, (b) receives candidate hypotheses from expansion
threads, (c) merges duplicates (same hash), and (d) sorts + prunes by
score against the beam threshold.  Here a hypothesis set is a fixed-K
struct-of-arrays (the 24 KB hypothesis memory maps to fixed K with -inf
padding); merging is a sort-by-hash + segment-logsumexp; selection is a
top_k + beam threshold.  The threshold prune itself also exists as a
Pallas kernel (kernels/beam_prune.py).

Scores are kept as two CTC channels (blank / non-blank); the merge
logsumexps each channel independently, which is exactly CTC prefix-beam
merging.  `total = logaddexp(pb, pnb)` orders hypotheses.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


class Candidates(NamedTuple):
    """Flat candidate set produced by one hypothesis-expansion execution."""
    hash: jax.Array      # (N,) int32 prefix hash (identity for merging)
    pb: jax.Array        # (N,) f32 log-prob ending in blank
    pnb: jax.Array       # (N,) f32 log-prob ending in non-blank
    fields: dict         # str -> (N, ...) programmer-defined payload


def total_score(pb: jax.Array, pnb: jax.Array) -> jax.Array:
    return jnp.logaddexp(pb, pnb)


def merge_duplicates(c: Candidates) -> Candidates:
    """logsumexp-merge candidates with equal hash (same prefix).

    After the merge, one representative per hash keeps the combined
    channels; the rest drop to -inf.  Payload fields of duplicates are
    identical by construction (same prefix), so the representative's
    payload is exact.
    """
    n = c.hash.shape[0]
    valid = total_score(c.pb, c.pnb) > NEG_INF / 2
    key = jnp.where(valid, c.hash, jnp.int32(2**31 - 1))
    order = jnp.argsort(key)
    sk = key[order]
    seg_start = jnp.concatenate([jnp.ones((1,), bool), sk[1:] != sk[:-1]])
    seg_id = jnp.cumsum(seg_start) - 1                       # (N,)

    def seg_lse(x):
        m = jax.ops.segment_max(x, seg_id, num_segments=n)
        mx = m[seg_id]
        s = jax.ops.segment_sum(jnp.exp(x - mx), seg_id, num_segments=n)
        out = m + jnp.log(jnp.maximum(s, 1e-37))
        return jnp.where(m > NEG_INF / 2, out, NEG_INF)

    pb_m = seg_lse(c.pb[order])[seg_id]
    pnb_m = seg_lse(c.pnb[order])[seg_id]
    keep = seg_start & (sk != 2**31 - 1)
    pb_new = jnp.where(keep, pb_m, NEG_INF)
    pnb_new = jnp.where(keep, pnb_m, NEG_INF)
    inv = jnp.argsort(order)
    fields = c.fields  # unpermuted; scatter merged scores back
    return Candidates(c.hash, pb_new[inv], pnb_new[inv], fields)


def select(c: Candidates, k: int, beam_threshold: float) -> dict:
    """Sort + prune: top-k by total score, then beam-threshold prune.

    Returns the new hypothesis set: dict of (k,)-arrays + 'valid' mask.
    """
    tot = total_score(c.pb, c.pnb)
    if k > tot.shape[0]:      # pad candidate set up to the beam size
        pad = k - tot.shape[0]
        c = Candidates(
            jnp.pad(c.hash, (0, pad)),
            jnp.pad(c.pb, (0, pad), constant_values=NEG_INF),
            jnp.pad(c.pnb, (0, pad), constant_values=NEG_INF),
            {n: jnp.pad(a, [(0, pad)] + [(0, 0)] * (a.ndim - 1))
             for n, a in c.fields.items()})
        tot = total_score(c.pb, c.pnb)
    top, idx = jax.lax.top_k(tot, k)
    best = top[0]
    valid = (top > NEG_INF / 2) & (top >= best - beam_threshold)
    out = {"hash": c.hash[idx], "pb": c.pb[idx], "pnb": c.pnb[idx],
           "valid": valid}
    for name, arr in c.fields.items():
        out[name] = arr[idx]
    # invalidate pruned slots
    out["pb"] = jnp.where(valid, out["pb"], NEG_INF)
    out["pnb"] = jnp.where(valid, out["pnb"], NEG_INF)
    return out


def hypothesis_unit_step(c: Candidates, k: int, beam_threshold: float,
                         use_pallas_prune: bool = False) -> dict:
    """Full hypothesis-unit operation: merge -> sort -> prune."""
    merged = merge_duplicates(c)
    if use_pallas_prune:
        from repro.kernels import ops
        tot = total_score(merged.pb, merged.pnb)
        pruned = ops.beam_prune(tot, beam_threshold)
        merged = Candidates(merged.hash,
                            jnp.where(pruned > NEG_INF / 2, merged.pb, NEG_INF),
                            jnp.where(pruned > NEG_INF / 2, merged.pnb, NEG_INF),
                            merged.fields)
    return select(merged, k, beam_threshold)
