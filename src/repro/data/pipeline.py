"""Deterministic, resumable data pipeline.

Production shape: the pipeline is a pure function of (seed, step, shard),
so restart-after-failure resumes bit-identically from the checkpointed
step counter with no state files, and elastic re-sharding (different
host count on restart) re-partitions the same global stream.

Two sources:
  * SyntheticLM  — zipf-ish token stream for LM training (CPU smoke /
    benchmarks; next-token labels built here, -1 padding).
  * SyntheticASR — synthetic utterances (sine mixtures + noise) with
    token transcripts over a lexicon, for the ASR case study.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_shards: int = 1
    shard: int = 0


class SyntheticLM:
    """Deterministic zipf token stream; batch(step) is pure."""

    def __init__(self, cfg: DataConfig):
        assert cfg.global_batch % cfg.n_shards == 0
        self.cfg = cfg
        self.local_batch = cfg.global_batch // cfg.n_shards

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        out_tok = np.empty((self.local_batch, cfg.seq_len + 1), np.int64)
        for i in range(self.local_batch):
            g = cfg.global_batch * step + cfg.shard * self.local_batch + i
            rng = np.random.default_rng((cfg.seed << 32) ^ g)
            out_tok[i] = rng.zipf(1.3, cfg.seq_len + 1) % cfg.vocab_size
        tokens = out_tok[:, :-1].astype(np.int32)
        labels = out_tok[:, 1:].astype(np.int32)
        return {"tokens": tokens, "labels": labels}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


class SyntheticASR:
    """Synthetic utterances: each token renders as a tone segment; the
    transcript is a word sequence from a small lexicon."""

    def __init__(self, words: dict, sample_rate: int = 16000,
                 tok_ms: float = 120.0, seed: int = 0):
        self.words = list(words.items())
        self.sr = sample_rate
        self.tok_samples = int(sample_rate * tok_ms / 1000)
        self.seed = seed

    def utterance(self, idx: int, n_words: int = 3) -> dict:
        rng = np.random.default_rng((self.seed << 32) ^ idx)
        wids = rng.integers(0, len(self.words), n_words)
        toks = []
        for w in wids:
            toks.extend(self.words[w][1])
        sig = []
        for t in toks:
            f = 200.0 + 37.0 * (t + 1)
            n = self.tok_samples
            tt = np.arange(n) / self.sr
            seg = (np.sin(2 * np.pi * f * tt)
                   + 0.3 * np.sin(2 * np.pi * 2 * f * tt))
            seg *= np.hanning(n)
            sig.append(seg)
        audio = np.concatenate(sig).astype(np.float32)
        audio += rng.normal(0, 0.01, audio.shape).astype(np.float32)
        return {"audio": audio, "words": np.asarray(wids, np.int32),
                "tokens": np.asarray(toks, np.int32)}
