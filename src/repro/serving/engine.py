"""Engine/Session base: slot pool, admission queue, session lifecycle.

One `Engine` owns a fixed pool of `n_slots` decoding slots advanced by a
single fused (and, for ASR, vmapped) step — the shape both serving modes
share.  Callers never touch slots: they `open()` a `Session`, stream
input with `push`, read output with `poll`, and signal end-of-input with
`finish`.  The engine admits queued sessions into freed slots
(continuous batching), steps every slot that can make progress, and
harvests finished sessions back off the pool.

Scheduling contract: `push` only buffers and admits (so concurrently
opened sessions share batched steps instead of being drained one by
one); `poll`/`finish` drive the admit -> step -> harvest loop to
quiescence.  Per-slot trajectories are independent of scheduling, so
results are identical however pushes and polls interleave — that is the
parity property tests/test_serving.py and tests/test_multistream.py pin
down.

Subclasses implement the slot mechanics:
  _admit_to_slot(session, slot)  load a queued session's pending input
  _step() -> bool                one fused step; False = nothing to do.
                                 Which slots it advances (all of them,
                                 a gathered sub-batch, ...) is the
                                 subclass's scheduling policy — the
                                 only contract is that per-slot
                                 trajectories are schedule-independent
  _ready_to_close(session, slot) session's slot work is exhausted
  _finalize_slot(slot) -> dict   result payload for a closing session
  _poll_active(session) -> dict  live (non-final) output for a session
"""
from __future__ import annotations

import functools
import threading
from typing import Iterator, List, Optional

import numpy as np

from repro.serving.metrics import EngineMetrics


def worker_only(method):
    """Marks an engine method that mutates pool state (the admit ->
    step -> harvest pump and reset): when the engine is owned by an
    `EngineWorker` thread (`_owner_thread` set), calling it from any
    other thread raises instead of racing the pump.  In-process use
    (tests, launchers, `Session.poll` driving `_advance`) has no owner
    thread and is unaffected.  `python -m repro.analysis` (rule RPL004)
    statically rejects calls to annotated methods from asyncio handlers
    outside a worker submit/call thunk."""
    @functools.wraps(method)
    def wrapper(self, *args, **kwargs):
        owner = getattr(self, "_owner_thread", None)
        if owner is not None and threading.current_thread() is not owner:
            raise RuntimeError(
                f"{type(self).__name__}.{method.__name__} called from "
                f"thread {threading.current_thread().name!r}, but the "
                f"engine is owned by worker thread {owner.name!r}: "
                "submit a thunk through the EngineWorker instead")
        return method(self, *args, **kwargs)
    wrapper._worker_only = True
    return wrapper


class AdmissionRejected(RuntimeError):
    """Typed backpressure error: the engine's admission queue is at
    `EngineConfig.max_queue` and no slot is free, so `open()` refuses
    the session instead of queueing it unboundedly.  Carries the depth
    observed and the configured bound so callers (e.g. the network
    front-end's 503 response) can report both."""

    def __init__(self, queue_depth: int, max_queue: int):
        super().__init__(
            f"admission rejected: queue depth {queue_depth} at "
            f"max_queue={max_queue} with every slot busy")
        self.queue_depth = queue_depth
        self.max_queue = max_queue


class SessionFaulted(RuntimeError):
    """Typed per-session failure: the engine evicted ONE session —
    poison input isolated by bisection retry, a failed prefill, or a
    whole-pool quarantine — without taking the pool down.  The session
    handle raises this from `push`/`poll`/`finish`, done-watchers
    resolve with it, and the network front-end maps it to an in-stream
    error chunk (`/asr`) or a 500 (`/lm`).  `__cause__` carries the
    original exception when one exists."""

    def __init__(self, sid: int, reason: str,
                 cause: Optional[BaseException] = None):
        super().__init__(f"session {sid} faulted: {reason}")
        self.sid = sid
        self.reason = reason
        if cause is not None:
            self.__cause__ = cause


class DeadlineExceeded(SessionFaulted):
    """A session outlived `EngineConfig.session_deadline` and was reaped
    by the pump to free its slot/queue entry."""


class SessionQueue:
    """Order-preserving admission queue with O(1) removal.

    `deque.remove(sess)` is O(position) — draining hundreds of queued
    sessions (the load-generator regime) went quadratic whenever the
    removed session was not at the head (LM sessions waiting on a
    prompt, the finished-but-unadmittable harvest path).  A dict keyed
    by the session handles preserves insertion order (guaranteed since
    Python 3.7) and deletes in O(1)."""

    def __init__(self):
        self._d: dict = {}

    def append(self, session) -> None:
        self._d[session] = None

    def remove(self, session) -> None:
        del self._d[session]

    def clear(self) -> None:
        self._d.clear()

    def __iter__(self) -> Iterator:
        return iter(self._d)

    def __len__(self) -> int:
        return len(self._d)

    def __contains__(self, session) -> bool:
        return session in self._d


def copy_result(res: dict) -> dict:
    """Defensive copy of a result payload.  The engine keeps the stored
    result for later polls; handing out the stored numpy arrays (or the
    LM token list) would let a caller's in-place mutation corrupt every
    subsequent poll of the same session."""
    return {k: v.copy() if isinstance(v, np.ndarray)
            else list(v) if isinstance(v, list) else v
            for k, v in res.items()}


class Session:
    """Handle for one connection to an engine's slot pool.

    States: queued (no slot yet) -> active (owns a slot) -> done
    (result available).  `push` feeds input, `poll` reads the current
    output, `finish` declares end-of-input and returns the final result
    once the engine has drained the session (None while it is still
    waiting on a slot held by other sessions)."""

    def __init__(self, engine: "Engine", sid: int):
        self._engine = engine
        self.sid = sid
        self.slot: Optional[int] = None
        self.finished = False          # finish() called; no more input
        self.detached = False          # engine was reset under the session
        self.fault: Optional[SessionFaulted] = None
        self.result: Optional[dict] = None
        self._pending = None           # mode-specific input awaiting a slot
        # metric timestamps, stamped by engine.metrics (see metrics.py)
        self._t_open = self._t_admit = None
        self._t_first = self._t_finish = None

    @property
    def admitted(self) -> bool:
        return self.slot is not None

    @property
    def done(self) -> bool:
        return self.result is not None

    @property
    def faulted(self) -> bool:
        return self.fault is not None

    def _check_attached(self):
        if self.fault is not None:
            raise self.fault
        if self.detached and not self.done:
            raise RuntimeError(
                f"session {self.sid}: engine was reset; session detached")

    def push(self, data):
        """Stream input into the session (audio chunk / token prompt)."""
        self._check_attached()
        if self.finished:
            raise RuntimeError(f"session {self.sid}: push after finish()")
        self._engine._push(self, data)
        return self

    def poll(self) -> dict:
        """Drive the engine and return this session's current output."""
        self._check_attached()
        out = self._engine._poll(self)
        if self.fault is not None:     # faulted during this very drive
            raise self.fault
        return out

    def finish(self, wait: bool = True) -> Optional[dict]:
        """End-of-input: flush, finalize, free the slot.  Returns the
        final result, or None if the session is still queued behind
        unfinished sessions (poll() later to collect it).  wait=False
        only marks end-of-input without driving the engine — the
        network front-end uses it so its dedicated engine thread keeps
        sole ownership of the step loop."""
        self._check_attached()
        self.finished = True
        self._engine.metrics.on_finish(self)
        if wait:
            self._engine._advance()
            if self.fault is not None:  # faulted during this very drive
                raise self.fault
        return None if self.result is None else copy_result(self.result)

    def __repr__(self):
        state = ("done" if self.done else
                 "active" if self.admitted else "queued")
        return f"<Session {self.sid} {state}>"


class Engine:
    """Slot pool + admission queue; see module docstring for the split
    between this base and the AsrEngine/LmEngine slot mechanics."""

    def __init__(self, config):
        self.config = config
        self.n_slots: int = config.n_slots
        self.max_queue: Optional[int] = getattr(config, "max_queue", None)
        self.session_deadline: Optional[float] = getattr(
            config, "session_deadline", None)
        self._faults = getattr(config, "faults", None)
        self._fault_log: List[dict] = []   # bounded by _fault_session
        self.n_steps = 0               # fused steps taken since reset
        self._queue = SessionQueue()
        self._owner: List[Optional[Session]] = [None] * self.n_slots
        self._next_sid = 0
        self._owner_thread = None      # set by EngineWorker (see worker_only)
        self.metrics = EngineMetrics()

    # ---- session front-end -------------------------------------------
    def open(self) -> Session:
        """Open a connection; the session queues for a slot immediately.
        With `EngineConfig.max_queue` set, a full queue while every slot
        is busy raises `AdmissionRejected` (typed backpressure) instead
        of queueing unboundedly."""
        if (self.max_queue is not None
                and len(self._queue) >= self.max_queue
                and all(o is not None for o in self._owner)):
            self.metrics.on_reject()
            raise AdmissionRejected(len(self._queue), self.max_queue)
        s = Session(self, self._next_sid)
        self._next_sid += 1
        self._queue.append(s)
        self.metrics.on_open(s)
        self.metrics.sample_queue_depth(len(self._queue))
        self._admit()
        return s

    def _push(self, session: Session, data) -> None:
        raise NotImplementedError

    def _poll(self, session: Session) -> dict:
        raise NotImplementedError

    # ---- the serve loop ----------------------------------------------
    @worker_only
    def _advance(self) -> None:
        """Admit -> step -> harvest until no progress is possible."""
        while self._pump_once():
            pass

    @worker_only
    def _pump_once(self) -> bool:
        """One quarantined admit -> step -> harvest round (the unit both
        `_advance` and the network `EngineWorker` loop drive).

        Fault containment is layered: the subclasses attribute step /
        prefill failures to a single session where possible (bisection
        retry in `AsrEngine._step_isolated` / `LmEngine._prefill_group`)
        and evict only it; anything that still escapes here is an
        UNATTRIBUTABLE pool failure — the pool state can no longer be
        trusted, so every live session is faulted and the pool is
        rebuilt (`_fail_all`).  Either way the pump survives: one bad
        session or one bad round never kills the serve loop.
        `BaseException`s (worker shutdown, injected `WorkerKilled`) pass
        through — those model thread death, which only the worker
        supervisor may handle."""
        try:
            did = self._admit()
            did |= self._step()
            did |= self._harvest()
        except Exception as exc:
            self._fail_all(exc)
            did = False
        return self._reap_deadlines() or did

    @worker_only
    def _fault_session(self, sess: Session, exc: SessionFaulted,
                       release: bool = True) -> None:
        """Evict ONE session with a typed fault: remove it from the
        queue or its slot, record the fault on the handle (push/poll/
        finish raise it; done-watchers resolve with it), and — when the
        pool state is still trustworthy — release the slot for reuse.
        `release=False` is the whole-pool quarantine path, where
        `_fail_all` rebuilds the pool instead of touching per-slot
        state that may itself be corrupt."""
        sess.fault = exc
        if sess in self._queue:
            self._queue.remove(sess)
        slot = sess.slot
        sess.slot = None
        if slot is not None:
            self._owner[slot] = None
            if release:
                self._release_slot(slot)
        if len(self._fault_log) < 4096:     # bounded forensic record
            self._fault_log.append({
                "sid": sess.sid, "slot": slot, "reason": exc.reason,
                "deadline": isinstance(exc, DeadlineExceeded)})
        if isinstance(exc, DeadlineExceeded):
            self.metrics.on_deadline(sess)
        else:
            self.metrics.on_fault(sess)
        self.metrics.sample_queue_depth(len(self._queue))

    @worker_only
    def _fail_all(self, cause: BaseException) -> None:
        """Unattributable pump failure: fault every live session and
        rebuild the pool from scratch.  Per-slot release is skipped —
        the failure may have corrupted arbitrary pool state, so nothing
        short of `_reset_pool` is safe to trust afterwards."""
        for sess in list(self._queue) + [o for o in self._owner
                                         if o is not None]:
            self._fault_session(
                sess, SessionFaulted(sess.sid,
                                     f"pool quarantined: {cause}",
                                     cause=cause),
                release=False)
        self._queue.clear()
        self._owner = [None] * self.n_slots
        self.n_steps = 0
        self._reset_pool()

    @worker_only
    def _reap_deadlines(self) -> bool:
        """Evict sessions older than `EngineConfig.session_deadline`
        (open -> now, on the metrics clock so tests inject time).  Runs
        every pump round; a stuck client or a session starved behind a
        pathological queue frees its slot/queue entry instead of
        holding it forever."""
        deadline = self.session_deadline
        if deadline is None:
            return False
        now = self.metrics._clock()
        did = False
        for sess in list(self._queue) + [o for o in self._owner
                                         if o is not None]:
            if (sess._t_open is not None
                    and now - sess._t_open > deadline):
                self._fault_session(sess, DeadlineExceeded(
                    sess.sid,
                    f"exceeded session_deadline={deadline}s"))
                did = True
        return did

    @worker_only
    def _admit(self) -> bool:
        did = False
        for slot in range(self.n_slots):
            if self._owner[slot] is None and self._queue:
                sess = next((s for s in self._queue if self._admittable(s)),
                            None)
                if sess is None:
                    break
                self._queue.remove(sess)
                self._owner[slot] = sess
                sess.slot = slot
                self._admit_to_slot(sess, slot)
                sess._pending = None
                self.metrics.on_admit(sess)
                did = True
        if did:
            self.metrics.sample_queue_depth(len(self._queue))
        return did

    @worker_only
    def _harvest(self) -> bool:
        did = False
        for slot, sess in enumerate(self._owner):
            if sess is not None and self._ready_to_close(sess, slot):
                sess.result = self._finalize_slot(slot)
                sess.slot = None
                self._owner[slot] = None
                self.metrics.on_done(sess)
                did = True
        # finished sessions that can never be admitted (e.g. an LM
        # session with no prompt) close from the queue with an empty
        # result instead of waiting forever
        for sess in [s for s in self._queue
                     if s.finished and not self._admittable(s)]:
            sess.result = self._empty_result()
            self._queue.remove(sess)
            self.metrics.on_done(sess)
            did = True
        if did:
            self.metrics.sample_queue_depth(len(self._queue))
        return did

    @worker_only
    def reset(self) -> None:
        """Drop all sessions (queued and active) and zero the pool.
        Dropped sessions are detached: their handles raise on further
        use instead of silently swallowing input."""
        for sess in list(self._queue) + self._owner:
            if sess is not None:
                sess.detached = True
                sess.slot = None
        self._queue.clear()
        self._owner = [None] * self.n_slots
        self.n_steps = 0
        self._reset_pool()

    # ---- slot mechanics (subclass responsibility) --------------------
    def _admittable(self, session: Session) -> bool:
        """Whether a queued session may take a slot now (LM sessions
        must have pushed their prompt first; ASR sessions always may)."""
        return True

    def _empty_result(self) -> dict:
        """Result for a session finished with no input at all."""
        raise NotImplementedError

    def _admit_to_slot(self, session: Session, slot: int) -> None:
        raise NotImplementedError

    def _step(self) -> bool:
        raise NotImplementedError

    def _ready_to_close(self, session: Session, slot: int) -> bool:
        raise NotImplementedError

    def _finalize_slot(self, slot: int) -> dict:
        raise NotImplementedError

    def _release_slot(self, slot: int) -> None:
        """Scrub one slot after its session was evicted mid-flight
        (fault/deadline) so the next admission sees a fresh slot."""
        raise NotImplementedError

    def _reset_pool(self) -> None:
        raise NotImplementedError
