"""Engine/Session base: slot pool, admission queue, session lifecycle.

One `Engine` owns a fixed pool of `n_slots` decoding slots advanced by a
single fused (and, for ASR, vmapped) step — the shape both serving modes
share.  Callers never touch slots: they `open()` a `Session`, stream
input with `push`, read output with `poll`, and signal end-of-input with
`finish`.  The engine admits queued sessions into freed slots
(continuous batching), steps every slot that can make progress, and
harvests finished sessions back off the pool.

Scheduling contract: `push` only buffers and admits (so concurrently
opened sessions share batched steps instead of being drained one by
one); `poll`/`finish` drive the admit -> step -> harvest loop to
quiescence.  Per-slot trajectories are independent of scheduling, so
results are identical however pushes and polls interleave — that is the
parity property tests/test_serving.py and tests/test_multistream.py pin
down.

Subclasses implement the slot mechanics:
  _admit_to_slot(session, slot)  load a queued session's pending input
  _step() -> bool                one fused step; False = nothing to do.
                                 Which slots it advances (all of them,
                                 a gathered sub-batch, ...) is the
                                 subclass's scheduling policy — the
                                 only contract is that per-slot
                                 trajectories are schedule-independent
  _ready_to_close(session, slot) session's slot work is exhausted
  _finalize_slot(slot) -> dict   result payload for a closing session
  _poll_active(session) -> dict  live (non-final) output for a session
"""
from __future__ import annotations

from collections import deque
from typing import List, Optional


class Session:
    """Handle for one connection to an engine's slot pool.

    States: queued (no slot yet) -> active (owns a slot) -> done
    (result available).  `push` feeds input, `poll` reads the current
    output, `finish` declares end-of-input and returns the final result
    once the engine has drained the session (None while it is still
    waiting on a slot held by other sessions)."""

    def __init__(self, engine: "Engine", sid: int):
        self._engine = engine
        self.sid = sid
        self.slot: Optional[int] = None
        self.finished = False          # finish() called; no more input
        self.detached = False          # engine was reset under the session
        self.result: Optional[dict] = None
        self._pending = None           # mode-specific input awaiting a slot

    @property
    def admitted(self) -> bool:
        return self.slot is not None

    @property
    def done(self) -> bool:
        return self.result is not None

    def _check_attached(self):
        if self.detached and not self.done:
            raise RuntimeError(
                f"session {self.sid}: engine was reset; session detached")

    def push(self, data):
        """Stream input into the session (audio chunk / token prompt)."""
        self._check_attached()
        if self.finished:
            raise RuntimeError(f"session {self.sid}: push after finish()")
        self._engine._push(self, data)
        return self

    def poll(self) -> dict:
        """Drive the engine and return this session's current output."""
        self._check_attached()
        return self._engine._poll(self)

    def finish(self) -> Optional[dict]:
        """End-of-input: flush, finalize, free the slot.  Returns the
        final result, or None if the session is still queued behind
        unfinished sessions (poll() later to collect it)."""
        self._check_attached()
        self.finished = True
        self._engine._advance()
        return self.result

    def __repr__(self):
        state = ("done" if self.done else
                 "active" if self.admitted else "queued")
        return f"<Session {self.sid} {state}>"


class Engine:
    """Slot pool + admission queue; see module docstring for the split
    between this base and the AsrEngine/LmEngine slot mechanics."""

    def __init__(self, config):
        self.config = config
        self.n_slots: int = config.n_slots
        self.n_steps = 0               # fused steps taken since reset
        self._queue: deque = deque()
        self._owner: List[Optional[Session]] = [None] * self.n_slots
        self._next_sid = 0

    # ---- session front-end -------------------------------------------
    def open(self) -> Session:
        """Open a connection; the session queues for a slot immediately."""
        s = Session(self, self._next_sid)
        self._next_sid += 1
        self._queue.append(s)
        self._admit()
        return s

    def _push(self, session: Session, data) -> None:
        raise NotImplementedError

    def _poll(self, session: Session) -> dict:
        raise NotImplementedError

    # ---- the serve loop ----------------------------------------------
    def _advance(self) -> None:
        """Admit -> step -> harvest until no progress is possible."""
        progressed = True
        while progressed:
            progressed = self._admit()
            progressed |= self._step()
            progressed |= self._harvest()

    def _admit(self) -> bool:
        did = False
        for slot in range(self.n_slots):
            if self._owner[slot] is None and self._queue:
                sess = next((s for s in self._queue if self._admittable(s)),
                            None)
                if sess is None:
                    break
                self._queue.remove(sess)
                self._owner[slot] = sess
                sess.slot = slot
                self._admit_to_slot(sess, slot)
                sess._pending = None
                did = True
        return did

    def _harvest(self) -> bool:
        did = False
        for slot, sess in enumerate(self._owner):
            if sess is not None and self._ready_to_close(sess, slot):
                sess.result = self._finalize_slot(slot)
                sess.slot = None
                self._owner[slot] = None
                did = True
        # finished sessions that can never be admitted (e.g. an LM
        # session with no prompt) close from the queue with an empty
        # result instead of waiting forever
        for sess in [s for s in self._queue
                     if s.finished and not self._admittable(s)]:
            sess.result = self._empty_result()
            self._queue.remove(sess)
            did = True
        return did

    def reset(self) -> None:
        """Drop all sessions (queued and active) and zero the pool.
        Dropped sessions are detached: their handles raise on further
        use instead of silently swallowing input."""
        for sess in list(self._queue) + self._owner:
            if sess is not None:
                sess.detached = True
                sess.slot = None
        self._queue.clear()
        self._owner = [None] * self.n_slots
        self.n_steps = 0
        self._reset_pool()

    # ---- slot mechanics (subclass responsibility) --------------------
    def _admittable(self, session: Session) -> bool:
        """Whether a queued session may take a slot now (LM sessions
        must have pushed their prompt first; ASR sessions always may)."""
        return True

    def _empty_result(self) -> dict:
        """Result for a session finished with no input at all."""
        raise NotImplementedError

    def _admit_to_slot(self, session: Session, slot: int) -> None:
        raise NotImplementedError

    def _step(self) -> bool:
        raise NotImplementedError

    def _ready_to_close(self, session: Session, slot: int) -> bool:
        raise NotImplementedError

    def _finalize_slot(self, slot: int) -> dict:
        raise NotImplementedError

    def _reset_pool(self) -> None:
        raise NotImplementedError
