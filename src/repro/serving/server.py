"""Network serving front-end: asyncio server over the engine slot pools.

One `EngineServer` exposes an `AsrEngine` and/or `LmEngine` over plain
HTTP/1.1 on an asyncio event loop — no third-party web framework, just
`asyncio.start_server` plus hand-rolled chunked transfer encoding (both
ends of the protocol live in this module, so the wire format only has
to be self-consistent).

Threading contract: the event loop NEVER touches an engine.  Each
engine is owned by one `EngineWorker` — a dedicated daemon thread that
executes submitted commands (open/push/finish/readout) between pump
iterations of the engine's admit -> step -> harvest loop.  Network I/O
therefore never blocks a fused decoding step and a slow fused step
never stalls accepting connections; the asyncio side bridges with
`asyncio.wrap_future` over `concurrent.futures.Future`s.

Wire protocol:

  * ``POST /asr`` with chunked request body — one streaming session.
    Each request chunk is a JSON command (``{"op": "push", "audio":
    [...]}``, ``{"op": "poll"}``, ``{"op": "finish"}``) and each
    response chunk is the JSON reply to the command in order (poll ->
    current best hypothesis; finish -> the final result).  The response
    status line is sent as soon as the session is admitted or queued,
    so rejection is visible before any audio is shipped.
  * ``POST /lm`` with a JSON body ``{"prompt": [...]}`` — one batched
    generation request; responds with the final token payload.
  * ``GET /metrics`` — JSON `EngineMetrics.snapshot()` per engine.
  * Admission backpressure (`AdmissionRejected`, i.e. the engine queue
    is at `EngineConfig.max_queue` with every slot busy) maps to a
    ``503`` JSON response carrying the observed depth and the bound;
    the client helpers raise it as `ServerRejected`.

Client helpers (`AsrClient`, `lm_generate`, `fetch_metrics`) speak the
same protocol and are what tests/test_serving_server.py and
benchmarks/load.py drive.
"""
from __future__ import annotations

import asyncio
import concurrent.futures
import json
import queue
import threading
from typing import Callable, List, Optional, Tuple

import numpy as np

from repro.serving.engine import AdmissionRejected, Engine, copy_result


# ---- JSON payloads ----------------------------------------------------

def jsonable(x):
    """Result payloads carry numpy arrays/scalars; the wire carries
    JSON.  Both ends are Python's json module, so non-finite floats
    (-inf hypothesis scores) survive as ``-Infinity`` literals."""
    if isinstance(x, dict):
        return {k: jsonable(v) for k, v in x.items()}
    if isinstance(x, np.ndarray):
        return x.tolist()
    if isinstance(x, np.generic):
        return x.item()
    if isinstance(x, (list, tuple)):
        return [jsonable(v) for v in x]
    return x


# ---- chunked-transfer framing ----------------------------------------

async def _write_chunk(writer: asyncio.StreamWriter, data: bytes) -> None:
    writer.write(b"%x\r\n" % len(data) + data + b"\r\n")
    await writer.drain()


async def _write_last_chunk(writer: asyncio.StreamWriter) -> None:
    writer.write(b"0\r\n\r\n")
    await writer.drain()


async def _read_chunk(reader: asyncio.StreamReader) -> Optional[bytes]:
    """One chunk of a chunked body; None on the terminating 0-chunk."""
    line = await reader.readline()
    if not line:
        raise ConnectionError("peer closed mid-stream")
    n = int(line.strip().split(b";")[0], 16)
    if n == 0:
        await reader.readline()        # blank line after last-chunk
        return None
    data = await reader.readexactly(n)
    await reader.readexactly(2)        # trailing \r\n
    return data


async def _read_head(reader: asyncio.StreamReader) -> Tuple[str, dict]:
    """Request/response head: first line + lowercased header dict."""
    head = await reader.readuntil(b"\r\n\r\n")
    lines = head.decode("latin-1").split("\r\n")
    headers = {}
    for ln in lines[1:]:
        if ":" in ln:
            k, v = ln.split(":", 1)
            headers[k.strip().lower()] = v.strip()
    return lines[0], headers


async def _read_sized_body(reader: asyncio.StreamReader,
                           headers: dict) -> bytes:
    return await reader.readexactly(int(headers.get("content-length", 0)))


_STATUS = {200: "OK", 400: "Bad Request", 404: "Not Found",
           503: "Service Unavailable"}


def _head_bytes(status: int, chunked: bool,
                content_length: Optional[int] = None) -> bytes:
    lines = [f"HTTP/1.1 {status} {_STATUS[status]}",
             "Content-Type: application/json"]
    if chunked:
        lines.append("Transfer-Encoding: chunked")
    else:
        lines.append(f"Content-Length: {content_length}")
        lines.append("Connection: close")
    return ("\r\n".join(lines) + "\r\n\r\n").encode()


async def _respond_json(writer: asyncio.StreamWriter, status: int,
                        payload: dict) -> None:
    body = json.dumps(jsonable(payload)).encode()
    writer.write(_head_bytes(status, chunked=False,
                             content_length=len(body)) + body)
    await writer.drain()


# ---- the engine thread -----------------------------------------------

class EngineWorker:
    """Dedicated thread owning ONE engine: the only code that ever calls
    into the engine.  Submitted commands (thunks taking the engine) run
    between pump iterations of admit -> step -> harvest, and registered
    done-watchers resolve as soon as their session's result is
    harvested — so `Session.finish(wait=False)` plus a watcher replaces
    the in-process blocking `finish()` without the network side ever
    driving the step loop."""

    def __init__(self, engine: Engine, name: str = "engine-worker",
                 idle_wait: float = 0.02):
        self.engine = engine
        self._idle_wait = idle_wait
        self._cmds: queue.SimpleQueue = queue.SimpleQueue()
        self._watchers: List[Tuple[object, concurrent.futures.Future]] = []
        self._stopping = threading.Event()
        self._thread = threading.Thread(target=self._run, name=name,
                                        daemon=True)
        # claim the engine: @worker_only methods now refuse every other
        # thread (claimed before start so no pump can beat the claim)
        engine._owner_thread = self._thread
        self._thread.start()

    # -- submission (any thread) --
    def submit(self, fn: Callable[[Engine], object]
               ) -> concurrent.futures.Future:
        fut: concurrent.futures.Future = concurrent.futures.Future()
        self._cmds.put((fn, fut))
        return fut

    async def call(self, fn: Callable[[Engine], object]):
        return await asyncio.wrap_future(self.submit(fn))

    def watch_done(self, session) -> concurrent.futures.Future:
        """Future resolving with a defensive copy of `session.result`
        once the engine harvests it (exception if the session is
        detached by a reset first)."""
        fut: concurrent.futures.Future = concurrent.futures.Future()
        self.submit(lambda eng: self._watchers.append((session, fut)))
        return fut

    def close(self, timeout: float = 5.0) -> None:
        self._stopping.set()
        self._thread.join(timeout=timeout)
        if not self._thread.is_alive():
            self.engine._owner_thread = None   # release for in-process use

    # -- the loop (worker thread only) --
    def _run(self) -> None:
        busy = False
        while not self._stopping.is_set():
            try:
                item = self._cmds.get(
                    timeout=0.001 if busy else self._idle_wait)
            except queue.Empty:
                item = None
            while item is not None:
                self._exec(*item)
                try:
                    item = self._cmds.get_nowait()
                except queue.Empty:
                    item = None
            busy = self._pump()
            self._resolve_watchers()
        self._drain_on_stop()

    def _exec(self, fn, fut: concurrent.futures.Future) -> None:
        if not fut.set_running_or_notify_cancel():
            return
        try:
            fut.set_result(fn(self.engine))
        except BaseException as exc:          # typed errors cross the bridge
            fut.set_exception(exc)

    def _pump(self) -> bool:
        eng = self.engine
        did = eng._admit()
        did |= eng._step()
        did |= eng._harvest()
        return did

    def _resolve_watchers(self) -> None:
        if not self._watchers:
            return
        keep = []
        for sess, fut in self._watchers:
            if sess.done:
                fut.set_result(copy_result(sess.result))
            elif sess.detached:
                fut.set_exception(RuntimeError(
                    f"session {sess.sid}: engine reset before finalize"))
            else:
                keep.append((sess, fut))
        self._watchers = keep

    def _drain_on_stop(self) -> None:
        exc = RuntimeError("engine worker stopped")
        while True:
            try:
                _, fut = self._cmds.get_nowait()
            except queue.Empty:
                break
            if fut.set_running_or_notify_cancel():
                fut.set_exception(exc)
        for _, fut in self._watchers:
            if not fut.done():
                fut.set_exception(exc)
        self._watchers = []


def _asr_readout(session) -> dict:
    """Current best hypothesis WITHOUT driving the engine (the worker's
    pump loop owns stepping; the in-process `Session.poll` would run
    `_advance` to quiescence inside a network request)."""
    eng = session._engine
    if session.done:
        return copy_result(session.result)
    if session.admitted:
        # same contract as AsrEngine._poll: slot_best hands back
        # zero-copy (read-only) views over engine-owned buffers, so the
        # payload must be copied before it leaves the engine
        res = eng.slot_best(session.slot)
        res["steps"] = int(eng._slot_steps[session.slot])
        return copy_result(res)
    return eng._empty_result()


# ---- the server -------------------------------------------------------

class EngineServer:
    """Asyncio front-end over an `AsrEngine` and/or `LmEngine` (each on
    its own `EngineWorker` thread).  `await start()` binds the socket
    (port 0 picks a free port, read back from `.port`); `await
    aclose()` stops the listener and the workers."""

    def __init__(self, asr_engine: Optional[Engine] = None,
                 lm_engine: Optional[Engine] = None,
                 host: str = "127.0.0.1", port: int = 0):
        if asr_engine is None and lm_engine is None:
            raise ValueError("EngineServer needs at least one engine")
        self._asr_engine = asr_engine
        self._lm_engine = lm_engine
        self.host = host
        self.port = port
        self._asr_worker: Optional[EngineWorker] = None
        self._lm_worker: Optional[EngineWorker] = None
        self._server: Optional[asyncio.AbstractServer] = None

    async def start(self) -> "EngineServer":
        if self._asr_engine is not None:
            self._asr_worker = EngineWorker(self._asr_engine, "asr-worker")
        if self._lm_engine is not None:
            self._lm_worker = EngineWorker(self._lm_engine, "lm-worker")
        self._server = await asyncio.start_server(self._handle, self.host,
                                                  self.port)
        self.port = self._server.sockets[0].getsockname()[1]
        return self

    async def aclose(self) -> None:
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
        for worker in (self._asr_worker, self._lm_worker):
            if worker is not None:
                worker.close()

    async def serve_forever(self) -> None:
        assert self._server is not None, "call start() first"
        await self._server.serve_forever()

    # -- connection handling --
    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        try:
            first, headers = await _read_head(reader)
            parts = first.split()
            method, path = (parts[0], parts[1]) if len(parts) >= 2 else \
                ("", "")
            if method == "POST" and path == "/asr":
                await self._handle_asr(reader, writer)
            elif method == "POST" and path == "/lm":
                await self._handle_lm(reader, writer, headers)
            elif method == "GET" and path == "/metrics":
                await self._handle_metrics(writer)
            else:
                await _respond_json(writer, 404, {"error": "not found"})
        except (ConnectionError, asyncio.IncompleteReadError,
                asyncio.LimitOverrunError):
            pass                    # client went away mid-request
        finally:
            try:
                writer.close()
                await writer.wait_closed()
            except (ConnectionError, OSError):
                pass

    async def _handle_asr(self, reader: asyncio.StreamReader,
                          writer: asyncio.StreamWriter) -> None:
        worker = self._asr_worker
        if worker is None:
            await _respond_json(writer, 404, {"error": "no ASR engine"})
            return
        try:
            sess = await worker.call(lambda eng: eng.open())
        except AdmissionRejected as exc:
            await _respond_json(writer, 503, {
                "error": "admission_rejected",
                "queue_depth": exc.queue_depth,
                "max_queue": exc.max_queue})
            return
        writer.write(_head_bytes(200, chunked=True))
        await writer.drain()
        try:
            while True:
                data = await _read_chunk(reader)
                if data is None:              # client hung up cleanly
                    break
                cmd = json.loads(data)
                op = cmd.get("op")
                final = False
                if op == "push":
                    audio = np.asarray(cmd["audio"], np.float32)
                    await worker.call(lambda eng: sess.push(audio))
                    out = {"ok": True}
                elif op == "poll":
                    out = jsonable(await worker.call(
                        lambda eng: _asr_readout(sess)))
                elif op == "finish":
                    watcher = worker.watch_done(sess)
                    await worker.call(lambda eng: sess.finish(wait=False))
                    out = jsonable(await asyncio.wrap_future(watcher))
                    final = True
                else:
                    out = {"error": f"unknown op: {op!r}"}
                await _write_chunk(writer, json.dumps(out).encode())
                if final:
                    break
            await _write_last_chunk(writer)
        finally:
            if not sess.done and not sess.detached:
                # disconnect mid-stream: free the slot/queue entry
                worker.submit(lambda eng: sess.finish(wait=False))

    async def _handle_lm(self, reader: asyncio.StreamReader,
                         writer: asyncio.StreamWriter,
                         headers: dict) -> None:
        worker = self._lm_worker
        if worker is None:
            await _respond_json(writer, 404, {"error": "no LM engine"})
            return
        body = await _read_sized_body(reader, headers)
        try:
            prompt = np.asarray(json.loads(body)["prompt"], np.int32)
        except (KeyError, ValueError, json.JSONDecodeError) as exc:
            await _respond_json(writer, 400, {"error": str(exc)})
            return
        try:
            sess = await worker.call(lambda eng: eng.open())
        except AdmissionRejected as exc:
            await _respond_json(writer, 503, {
                "error": "admission_rejected",
                "queue_depth": exc.queue_depth,
                "max_queue": exc.max_queue})
            return
        try:
            watcher = worker.watch_done(sess)
            await worker.call(lambda eng: sess.push(prompt))
            await worker.call(lambda eng: sess.finish(wait=False))
            res = await asyncio.wrap_future(watcher)
        except Exception as exc:
            await _respond_json(writer, 400, {"error": str(exc)})
            worker.submit(lambda eng: sess.finish(wait=False))
            return
        await _respond_json(writer, 200, res)

    async def _handle_metrics(self, writer: asyncio.StreamWriter) -> None:
        out = {}
        if self._asr_worker is not None:
            out["asr"] = await self._asr_worker.call(
                lambda eng: eng.metrics.snapshot())
        if self._lm_worker is not None:
            out["lm"] = await self._lm_worker.call(
                lambda eng: eng.metrics.snapshot())
        await _respond_json(writer, 200, out)


# ---- client helpers ---------------------------------------------------

class ServerRejected(RuntimeError):
    """Client-side image of a 503 admission rejection."""

    def __init__(self, payload: dict):
        self.queue_depth = payload.get("queue_depth")
        self.max_queue = payload.get("max_queue")
        super().__init__(
            f"server rejected session: queue depth {self.queue_depth} "
            f"at max_queue={self.max_queue}")


def _parse_status(first_line: str) -> int:
    return int(first_line.split()[1])


async def _raise_for_error(status: int, reader: asyncio.StreamReader,
                           headers: dict) -> None:
    body = await _read_sized_body(reader, headers)
    payload = json.loads(body) if body else {}
    if status == 503:
        raise ServerRejected(payload)
    raise RuntimeError(f"server error {status}: {payload}")


class AsrClient:
    """One streaming ASR session over the wire: lockstep JSON-chunk RPC
    (each command chunk gets exactly one response chunk)."""

    def __init__(self, reader: asyncio.StreamReader,
                 writer: asyncio.StreamWriter):
        self._reader = reader
        self._writer = writer
        self._closed = False

    @classmethod
    async def open(cls, host: str, port: int) -> "AsrClient":
        reader, writer = await asyncio.open_connection(host, port)
        writer.write((f"POST /asr HTTP/1.1\r\nHost: {host}:{port}\r\n"
                      "Content-Type: application/json\r\n"
                      "Transfer-Encoding: chunked\r\n\r\n").encode())
        await writer.drain()
        first, headers = await _read_head(reader)
        status = _parse_status(first)
        if status != 200:
            try:
                await _raise_for_error(status, reader, headers)
            finally:
                writer.close()
        return cls(reader, writer)

    async def _rpc(self, obj: dict) -> dict:
        await _write_chunk(self._writer, json.dumps(obj).encode())
        data = await _read_chunk(self._reader)
        if data is None:
            raise ConnectionError("server ended the response stream")
        return json.loads(data)

    async def push(self, audio) -> dict:
        return await self._rpc(
            {"op": "push",
             "audio": np.asarray(audio, np.float32).tolist()})

    async def poll(self) -> dict:
        return await self._rpc({"op": "poll"})

    async def finish(self) -> dict:
        res = await self._rpc({"op": "finish"})
        await _read_chunk(self._reader)       # server's terminating chunk
        await self.aclose()
        return res

    async def aclose(self) -> None:
        if self._closed:
            return
        self._closed = True
        try:
            await _write_last_chunk(self._writer)
        except (ConnectionError, OSError):
            pass
        self._writer.close()
        try:
            await self._writer.wait_closed()
        except (ConnectionError, OSError):
            pass


async def _post_json(host: str, port: int, path: str,
                     payload: dict) -> dict:
    reader, writer = await asyncio.open_connection(host, port)
    try:
        body = json.dumps(jsonable(payload)).encode()
        writer.write((f"POST {path} HTTP/1.1\r\nHost: {host}:{port}\r\n"
                      "Content-Type: application/json\r\n"
                      f"Content-Length: {len(body)}\r\n\r\n").encode()
                     + body)
        await writer.drain()
        first, headers = await _read_head(reader)
        status = _parse_status(first)
        if status != 200:
            await _raise_for_error(status, reader, headers)
        return json.loads(await _read_sized_body(reader, headers))
    finally:
        writer.close()
        try:
            await writer.wait_closed()
        except (ConnectionError, OSError):
            pass


async def lm_generate(host: str, port: int, prompt) -> dict:
    """One-shot LM generation over the wire."""
    return await _post_json(host, port, "/lm",
                            {"prompt": np.asarray(prompt).tolist()})


async def fetch_metrics(host: str, port: int) -> dict:
    reader, writer = await asyncio.open_connection(host, port)
    try:
        writer.write((f"GET /metrics HTTP/1.1\r\nHost: {host}:{port}"
                      "\r\n\r\n").encode())
        await writer.drain()
        first, headers = await _read_head(reader)
        status = _parse_status(first)
        if status != 200:
            await _raise_for_error(status, reader, headers)
        return json.loads(await _read_sized_body(reader, headers))
    finally:
        writer.close()
        try:
            await writer.wait_closed()
        except (ConnectionError, OSError):
            pass
