"""Network serving front-end: asyncio server over the engine slot pools.

One `EngineServer` exposes an `AsrEngine` and/or `LmEngine` over plain
HTTP/1.1 on an asyncio event loop — no third-party web framework, just
`asyncio.start_server` plus hand-rolled chunked transfer encoding (both
ends of the protocol live in this module, so the wire format only has
to be self-consistent).

Threading contract: the event loop NEVER touches an engine.  Each
engine is owned by one `EngineWorker` — a dedicated daemon thread that
executes submitted commands (open/push/finish/readout) between pump
iterations of the engine's admit -> step -> harvest loop.  Network I/O
therefore never blocks a fused decoding step and a slow fused step
never stalls accepting connections; the asyncio side bridges with
`asyncio.wrap_future` over `concurrent.futures.Future`s.

Wire protocol:

  * ``POST /asr`` with chunked request body — one streaming session.
    Each request chunk is a JSON command (``{"op": "push", "audio":
    [...]}``, ``{"op": "poll"}``, ``{"op": "finish"}``) and each
    response chunk is the JSON reply to the command in order (poll ->
    current best hypothesis; finish -> the final result).  The response
    status line is sent as soon as the session is admitted or queued,
    so rejection is visible before any audio is shipped.
  * ``POST /lm`` with a JSON body ``{"prompt": [...]}`` — one batched
    generation request; responds with the final token payload.
  * ``GET /metrics`` — JSON `EngineMetrics.snapshot()` per engine.
  * Admission backpressure (`AdmissionRejected`, i.e. the engine queue
    is at `EngineConfig.max_queue` with every slot busy) maps to a
    ``503`` JSON response carrying the observed depth and the bound;
    the client helpers raise it as `ServerRejected`.

Client helpers (`AsrClient`, `lm_generate`, `fetch_metrics`) speak the
same protocol and are what tests/test_serving_server.py and
benchmarks/load.py drive.
"""
from __future__ import annotations

import asyncio
import concurrent.futures
import json
import queue
import random
import threading
import time
import warnings
from typing import Callable, List, Optional, Tuple

import numpy as np

from repro.serving.engine import (AdmissionRejected, Engine,
                                  SessionFaulted, copy_result)
from repro.serving.faults import WorkerKilled


# ---- JSON payloads ----------------------------------------------------

def jsonable(x):
    """Result payloads carry numpy arrays/scalars; the wire carries
    JSON.  Both ends are Python's json module, so non-finite floats
    (-inf hypothesis scores) survive as ``-Infinity`` literals."""
    if isinstance(x, dict):
        return {k: jsonable(v) for k, v in x.items()}
    if isinstance(x, np.ndarray):
        return x.tolist()
    if isinstance(x, np.generic):
        return x.item()
    if isinstance(x, (list, tuple)):
        return [jsonable(v) for v in x]
    return x


class ProtocolError(ValueError):
    """Malformed bytes on the wire (garbage chunk-size line, unparsable
    status line, bad content-length).  A `ValueError` subclass so
    callers that already guard ValueError keep working, but typed so
    the server can answer 400 where a response is still possible
    instead of leaking an unretrieved task exception."""


# ---- chunked-transfer framing ----------------------------------------

async def _write_chunk(writer: asyncio.StreamWriter, data: bytes) -> None:
    writer.write(b"%x\r\n" % len(data) + data + b"\r\n")
    await writer.drain()


async def _write_last_chunk(writer: asyncio.StreamWriter) -> None:
    writer.write(b"0\r\n\r\n")
    await writer.drain()


async def _read_chunk(reader: asyncio.StreamReader) -> Optional[bytes]:
    """One chunk of a chunked body; None on the terminating 0-chunk."""
    line = await reader.readline()
    if not line:
        raise ConnectionError("peer closed mid-stream")
    try:
        n = int(line.strip().split(b";")[0], 16)
    except (ValueError, IndexError):
        raise ProtocolError(
            f"malformed chunk-size line: {line[:64]!r}") from None
    if n == 0:
        await reader.readline()        # blank line after last-chunk
        return None
    data = await reader.readexactly(n)
    await reader.readexactly(2)        # trailing \r\n
    return data


async def _read_head(reader: asyncio.StreamReader) -> Tuple[str, dict]:
    """Request/response head: first line + lowercased header dict."""
    head = await reader.readuntil(b"\r\n\r\n")
    lines = head.decode("latin-1").split("\r\n")
    headers = {}
    for ln in lines[1:]:
        if ":" in ln:
            k, v = ln.split(":", 1)
            headers[k.strip().lower()] = v.strip()
    return lines[0], headers


async def _read_sized_body(reader: asyncio.StreamReader,
                           headers: dict) -> bytes:
    try:
        n = int(headers.get("content-length", 0))
    except ValueError:
        raise ProtocolError(
            "malformed content-length: "
            f"{headers.get('content-length')!r}") from None
    return await reader.readexactly(n)


_STATUS = {200: "OK", 400: "Bad Request", 404: "Not Found",
           500: "Internal Server Error", 503: "Service Unavailable"}


def _head_bytes(status: int, chunked: bool,
                content_length: Optional[int] = None) -> bytes:
    lines = [f"HTTP/1.1 {status} {_STATUS[status]}",
             "Content-Type: application/json"]
    if chunked:
        lines.append("Transfer-Encoding: chunked")
    else:
        lines.append(f"Content-Length: {content_length}")
        lines.append("Connection: close")
    return ("\r\n".join(lines) + "\r\n\r\n").encode()


async def _respond_json(writer: asyncio.StreamWriter, status: int,
                        payload: dict) -> None:
    body = json.dumps(jsonable(payload)).encode()
    writer.write(_head_bytes(status, chunked=False,
                             content_length=len(body)) + body)
    await writer.drain()


# ---- the engine thread -----------------------------------------------

class WorkerDied(RuntimeError):
    """Typed error resolved into every in-flight future/watcher of an
    `EngineWorker` whose thread died or wedged: the callers' work was
    lost, not merely delayed, and they must not wait on the old
    thread."""


class EngineWorker:
    """Dedicated thread owning ONE engine: the only code that ever calls
    into the engine.  Submitted commands (thunks taking the engine) run
    between pump iterations of admit -> step -> harvest, and registered
    done-watchers resolve as soon as their session's result is
    harvested — so `Session.finish(wait=False)` plus a watcher replaces
    the in-process blocking `finish()` without the network side ever
    driving the step loop.

    Liveness contract: `heartbeat` is bumped once per loop iteration;
    `EngineServer._supervise` reads `heartbeat_age()` + `is_alive()` to
    detect a wedged or dead worker and restart it.  A crashing thread
    fails its own in-flight futures on the way out (`_crash`) so no
    caller ever blocks on a thread that will never run again, and
    `submit` fast-fails once the worker is known dead."""

    def __init__(self, engine: Engine, name: str = "engine-worker",
                 idle_wait: float = 0.02):
        self.engine = engine
        self._idle_wait = idle_wait
        self._cmds: queue.SimpleQueue = queue.SimpleQueue()
        self._watchers: List[Tuple[object, concurrent.futures.Future]] = []
        self._stopping = threading.Event()
        self._dead = False
        self._death: Optional[BaseException] = None
        self.heartbeat = time.monotonic()
        self._thread = threading.Thread(target=self._run, name=name,
                                        daemon=True)
        # claim the engine: @worker_only methods now refuse every other
        # thread (claimed before start so no pump can beat the claim).
        # On a supervisor restart this RECLAIMS the engine from the
        # dead/wedged predecessor — if that thread ever wakes again, its
        # next engine call raises instead of racing the new owner.
        engine._owner_thread = self._thread
        self._thread.start()

    @property
    def name(self) -> str:
        return self._thread.name

    def is_alive(self) -> bool:
        return self._thread.is_alive() and not self._dead

    def heartbeat_age(self) -> float:
        return time.monotonic() - self.heartbeat

    # -- submission (any thread) --
    def submit(self, fn: Callable[[Engine], object]
               ) -> concurrent.futures.Future:
        fut: concurrent.futures.Future = concurrent.futures.Future()
        if self._dead:
            fut.set_exception(self._death)
            return fut
        self._cmds.put((fn, fut))
        if self._dead:
            # lost race with a concurrent crash: the dying thread may
            # have drained before our put landed, so drain again
            self._fail_pending(self._death)
        return fut

    async def call(self, fn: Callable[[Engine], object]):
        return await asyncio.wrap_future(self.submit(fn))

    def watch_done(self, session) -> concurrent.futures.Future:
        """Future resolving with a defensive copy of `session.result`
        once the engine harvests it (exception if the session faults or
        is detached by a reset first)."""
        fut: concurrent.futures.Future = concurrent.futures.Future()
        reg = self.submit(lambda eng: self._watchers.append((session, fut)))

        def _propagate(rf: concurrent.futures.Future) -> None:
            # registration itself failed (dead worker): the watcher
            # would otherwise never resolve
            exc = None if rf.cancelled() else rf.exception()
            if exc is not None and not fut.done():
                fut.set_exception(exc)

        reg.add_done_callback(_propagate)
        return fut

    def close(self, timeout: float = 5.0) -> None:
        self._stopping.set()
        self._thread.join(timeout=timeout)
        if self._thread.is_alive():
            # wedged: the join timed out.  KEEP the engine ownership
            # claim — releasing it would let other threads race a pump
            # that may still wake up — and say so instead of silently
            # leaking the thread.
            warnings.warn(
                f"EngineWorker thread {self._thread.name!r} did not stop "
                f"within {timeout}s; leaking it with the engine ownership "
                "claim held so worker_only keeps fencing the pool",
                RuntimeWarning, stacklevel=2)
            return
        if self.engine._owner_thread is self._thread:
            self.engine._owner_thread = None   # release for in-process use

    def abandon(self, exc: BaseException) -> None:
        """Supervisor path: declare this worker lost.  Marks it dead
        (submit fast-fails), asks a merely-wedged thread to exit when
        it wakes, and fails every in-flight future/watcher with `exc`
        so no caller waits on work that will never run."""
        self._death = exc
        self._dead = True
        self._stopping.set()
        self._fail_pending(exc)

    def _fail_pending(self, exc: BaseException) -> None:
        while True:
            try:
                _, fut = self._cmds.get_nowait()
            except queue.Empty:
                break
            if fut.set_running_or_notify_cancel():
                fut.set_exception(exc)
        for _, fut in list(self._watchers):
            if not fut.done():
                fut.set_exception(exc)
        self._watchers = []

    # -- the loop (worker thread only) --
    def _run(self) -> None:
        try:
            busy = False
            while not self._stopping.is_set():
                try:
                    item = self._cmds.get(
                        timeout=0.001 if busy else self._idle_wait)
                except queue.Empty:
                    item = None
                while item is not None:
                    self._exec(*item)
                    try:
                        item = self._cmds.get_nowait()
                    except queue.Empty:
                        item = None
                busy = self._pump()
                self._resolve_watchers()
                self.heartbeat = time.monotonic()
        except BaseException as exc:
            # the pump itself died (per-session faults are contained
            # inside Engine._pump_once; what reaches here is thread
            # death — e.g. an injected WorkerKilled).  Fail in-flight
            # work on the way out so nobody blocks on this thread.
            self._crash(exc)
            return
        self._drain_on_stop()

    def _crash(self, cause: BaseException) -> None:
        self._death = WorkerDied(
            f"engine worker {self._thread.name!r} died: {cause!r}")
        self._death.__cause__ = cause
        self._dead = True
        self._fail_pending(self._death)

    def _exec(self, fn, fut: concurrent.futures.Future) -> None:
        if not fut.set_running_or_notify_cancel():
            return
        try:
            fut.set_result(fn(self.engine))
        except WorkerKilled as exc:
            # injected thread death must kill the LOOP, not the thunk —
            # resolve the future with the typed death first so its
            # awaiter is not left hanging
            fut.set_exception(WorkerDied(
                f"engine worker {self._thread.name!r} died: {exc!r}"))
            raise
        except BaseException as exc:          # typed errors cross the bridge
            fut.set_exception(exc)

    def _pump(self) -> bool:
        faults = getattr(self.engine, "_faults", None)
        if faults is not None:
            faults.check("pump", worker=self._thread.name)
        return self.engine._pump_once()

    def _resolve_watchers(self) -> None:
        if not self._watchers:
            return
        keep = []
        for sess, fut in self._watchers:
            if sess.done:
                fut.set_result(copy_result(sess.result))
            elif sess.fault is not None:
                fut.set_exception(sess.fault)
            elif sess.detached:
                fut.set_exception(RuntimeError(
                    f"session {sess.sid}: engine reset before finalize"))
            else:
                keep.append((sess, fut))
        self._watchers = keep

    def _drain_on_stop(self) -> None:
        self._fail_pending(RuntimeError("engine worker stopped"))


def _asr_readout(session) -> dict:
    """Current best hypothesis WITHOUT driving the engine (the worker's
    pump loop owns stepping; the in-process `Session.poll` would run
    `_advance` to quiescence inside a network request)."""
    eng = session._engine
    if session.done:
        return copy_result(session.result)
    if session.admitted:
        # same contract as AsrEngine._poll: slot_best hands back
        # zero-copy (read-only) views over engine-owned buffers, so the
        # payload must be copied before it leaves the engine
        res = eng.slot_best(session.slot)
        res["steps"] = int(eng._slot_steps[session.slot])
        return copy_result(res)
    return eng._empty_result()


# ---- the server -------------------------------------------------------

class EngineServer:
    """Asyncio front-end over an `AsrEngine` and/or `LmEngine` (each on
    its own `EngineWorker` thread).  `await start()` binds the socket
    (port 0 picks a free port, read back from `.port`); `await
    aclose()` stops the listener and the workers — `aclose(drain=True)`
    first lets in-flight connections finish and the engines go
    quiescent (graceful drain: no admitted session loses its result).

    Supervision: a background task watches each worker's thread
    liveness and heartbeat age (`EngineConfig.worker_watchdog`); a dead
    or wedged worker has its in-flight futures failed with `WorkerDied`,
    its engine's pool quarantined and rebuilt, and a fresh worker
    thread started in its place.  `GET /healthz` reports 200/503 with
    per-engine heartbeat ages.

    `asr_idle_timeout` bounds how long `/asr` waits for the next
    command chunk: a silent client gets an in-stream error chunk and
    its slot freed instead of holding the pool hostage."""

    def __init__(self, asr_engine: Optional[Engine] = None,
                 lm_engine: Optional[Engine] = None,
                 host: str = "127.0.0.1", port: int = 0,
                 asr_idle_timeout: Optional[float] = None,
                 watch_interval: float = 0.1):
        if asr_engine is None and lm_engine is None:
            raise ValueError("EngineServer needs at least one engine")
        self._asr_engine = asr_engine
        self._lm_engine = lm_engine
        self.host = host
        self.port = port
        self.asr_idle_timeout = asr_idle_timeout
        self._watch_interval = watch_interval
        self._asr_worker: Optional[EngineWorker] = None
        self._lm_worker: Optional[EngineWorker] = None
        self._server: Optional[asyncio.AbstractServer] = None
        self._supervisor: Optional[asyncio.Task] = None
        self._conns: set = set()
        self._restarts = {"asr": 0, "lm": 0}
        self._draining = False
        self._closing = False

    def _workers(self):
        for role in ("asr", "lm"):
            worker = getattr(self, f"_{role}_worker")
            if worker is not None:
                yield role, worker

    async def start(self) -> "EngineServer":
        if self._asr_engine is not None:
            self._asr_worker = EngineWorker(self._asr_engine, "asr-worker")
        if self._lm_engine is not None:
            self._lm_worker = EngineWorker(self._lm_engine, "lm-worker")
        self._server = await asyncio.start_server(self._handle, self.host,
                                                  self.port)
        self.port = self._server.sockets[0].getsockname()[1]
        self._supervisor = asyncio.create_task(self._supervise())
        return self

    # -- worker supervision --
    async def _supervise(self) -> None:
        """Detect dead/wedged workers and restart them.  A dead thread
        (`is_alive()` False outside a clean close) restarts
        immediately; a wedged one only when its heartbeat outages the
        engine's `worker_watchdog` (None = wedge detection off)."""
        while not self._closing:
            await asyncio.sleep(self._watch_interval)
            for role, worker in list(self._workers()):
                if self._closing:
                    return
                watchdog = getattr(worker.engine.config,
                                   "worker_watchdog", None)
                if not worker.is_alive():
                    self._watchdog_restart(role, worker, "thread died")
                elif (watchdog is not None
                      and worker.heartbeat_age() > watchdog):
                    self._watchdog_restart(
                        role, worker,
                        f"wedged: heartbeat {worker.heartbeat_age():.2f}s "
                        f"> worker_watchdog={watchdog}s")

    def _watchdog_restart(self, role: str, old: EngineWorker,
                          why: str) -> None:
        """Replace a lost worker: fail its in-flight work, reclaim the
        engine from the old thread, start a fresh worker (whose
        construction takes the ownership claim — a wedged old thread
        that wakes later is fenced out by worker_only), and quarantine
        the pool through the NEW worker so in-flight sessions resolve
        with a typed fault instead of hanging."""
        eng = old.engine
        exc = WorkerDied(f"{role} engine worker {old.name!r} {why}")
        old.abandon(exc)
        eng._owner_thread = None      # reclaim from the lost thread
        self._restarts[role] += 1
        new = EngineWorker(
            eng, f"{role}-worker-r{self._restarts[role]}")
        new.submit(lambda e: e._fail_all(exc))
        setattr(self, f"_{role}_worker", new)
        eng.metrics.on_worker_restart()

    # -- shutdown --
    async def aclose(self, drain: bool = False,
                     timeout: Optional[float] = None) -> None:
        """Stop the server.  `drain=True` stops ACCEPTING first, then
        waits for in-flight connections to complete and the engines to
        go quiescent (every admitted/queued session harvested) before
        stopping the workers — no result is lost.  `timeout` bounds the
        drain wait (None = wait as long as the clients take)."""
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
        if drain:
            self._draining = True
            await self._drain(timeout)
        self._closing = True
        if self._supervisor is not None:
            self._supervisor.cancel()
            try:
                await self._supervisor
            except asyncio.CancelledError:
                pass
            self._supervisor = None
        for _, worker in self._workers():
            worker.close()

    async def _drain(self, timeout: Optional[float]) -> None:
        deadline = (None if timeout is None
                    else asyncio.get_running_loop().time() + timeout)

        def remaining():
            if deadline is None:
                return None
            return max(0.0, deadline - asyncio.get_running_loop().time())

        conns = {t for t in self._conns if t is not asyncio.current_task()}
        if conns:
            await asyncio.wait(conns, timeout=remaining())
        for _, worker in self._workers():
            while worker.is_alive():
                if await worker.call(
                        lambda eng: not eng._queue
                        and all(o is None for o in eng._owner)):
                    break
                if deadline is not None and remaining() == 0.0:
                    break
                await asyncio.sleep(0.01)

    async def serve_forever(self) -> None:
        assert self._server is not None, "call start() first"
        await self._server.serve_forever()

    # -- connection handling --
    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        task = asyncio.current_task()
        if task is not None:
            self._conns.add(task)   # aclose(drain=True) awaits these
        try:
            first, headers = await _read_head(reader)
            parts = first.split()
            method, path = (parts[0], parts[1]) if len(parts) >= 2 else \
                ("", "")
            if method == "POST" and path == "/asr":
                await self._handle_asr(reader, writer)
            elif method == "POST" and path == "/lm":
                await self._handle_lm(reader, writer, headers)
            elif method == "GET" and path == "/metrics":
                await self._handle_metrics(writer)
            elif method == "GET" and path == "/healthz":
                await self._handle_healthz(writer)
            else:
                await _respond_json(writer, 404, {"error": "not found"})
        except (ConnectionError, asyncio.IncompleteReadError,
                asyncio.LimitOverrunError):
            pass                    # client went away mid-request
        except ProtocolError as exc:
            # garbage bytes in the framing (chunk-size line,
            # content-length): answer 400 if the head has not been
            # committed yet; if it has, the connection just closes
            try:
                await _respond_json(writer, 400, {"error": str(exc)})
            except (ConnectionError, OSError):
                pass
        finally:
            if task is not None:
                self._conns.discard(task)
            try:
                writer.close()
                await writer.wait_closed()
            except (ConnectionError, OSError):
                pass

    async def _handle_asr(self, reader: asyncio.StreamReader,
                          writer: asyncio.StreamWriter) -> None:
        worker = self._asr_worker
        if worker is None:
            await _respond_json(writer, 404, {"error": "no ASR engine"})
            return
        try:
            sess = await worker.call(lambda eng: eng.open())
        except AdmissionRejected as exc:
            await _respond_json(writer, 503, {
                "error": "admission_rejected",
                "queue_depth": exc.queue_depth,
                "max_queue": exc.max_queue})
            return
        writer.write(_head_bytes(200, chunked=True))
        await writer.drain()
        try:
            while True:
                try:
                    if self.asr_idle_timeout is not None:
                        data = await asyncio.wait_for(
                            _read_chunk(reader), self.asr_idle_timeout)
                    else:
                        data = await _read_chunk(reader)
                except asyncio.TimeoutError:
                    # silent client: free the slot, tell it why
                    await _write_chunk(writer, json.dumps({
                        "error": "idle timeout: no command within "
                                 f"{self.asr_idle_timeout}s",
                        "final": True}).encode())
                    break
                except ProtocolError as exc:
                    # garbage in the chunk framing: the byte stream is
                    # unrecoverable, but the head is already committed —
                    # best-effort in-stream error, then terminate
                    await _write_chunk(writer, json.dumps(
                        {"error": str(exc), "final": True}).encode())
                    break
                if data is None:              # client hung up cleanly
                    break
                final = False
                try:
                    cmd = json.loads(data)
                    if not isinstance(cmd, dict):
                        raise ValueError(
                            f"command must be a JSON object, got "
                            f"{type(cmd).__name__}")
                    op = cmd.get("op")
                    if op == "push":
                        audio = np.asarray(cmd["audio"], np.float32)
                        await worker.call(lambda eng: sess.push(audio))
                        out = {"ok": True}
                    elif op == "poll":
                        out = jsonable(await worker.call(
                            lambda eng: _asr_readout(sess)))
                    elif op == "finish":
                        watcher = worker.watch_done(sess)
                        await worker.call(
                            lambda eng: sess.finish(wait=False))
                        out = jsonable(await asyncio.wrap_future(watcher))
                        final = True
                    else:
                        out = {"error": f"unknown op: {op!r}"}
                except (json.JSONDecodeError, UnicodeDecodeError, KeyError,
                        TypeError, ValueError) as exc:
                    # malformed command (bad JSON, missing/non-numeric
                    # audio, validation reject): in-stream error reply,
                    # session stays alive for well-formed commands
                    out = {"error": f"bad command: {exc}"}
                except SessionFaulted as exc:
                    # the engine evicted this session (poison step,
                    # deadline, pool quarantine): typed final error chunk
                    out = {"error": str(exc), "faulted": True}
                    final = True
                except WorkerDied as exc:
                    out = {"error": str(exc), "faulted": True}
                    final = True
                await _write_chunk(writer, json.dumps(out).encode())
                if final:
                    break
            await _write_last_chunk(writer)
        finally:
            if not sess.done and not sess.detached and sess.fault is None:
                # disconnect mid-stream: free the slot/queue entry (a
                # failed submit on a dead worker resolves the future
                # with WorkerDied; nothing awaits it)
                worker.submit(lambda eng: sess.finish(wait=False))

    async def _handle_lm(self, reader: asyncio.StreamReader,
                         writer: asyncio.StreamWriter,
                         headers: dict) -> None:
        worker = self._lm_worker
        if worker is None:
            await _respond_json(writer, 404, {"error": "no LM engine"})
            return
        body = await _read_sized_body(reader, headers)
        try:
            prompt = np.asarray(json.loads(body)["prompt"], np.int32)
        except (KeyError, ValueError, json.JSONDecodeError) as exc:
            await _respond_json(writer, 400, {"error": str(exc)})
            return
        try:
            sess = await worker.call(lambda eng: eng.open())
        except AdmissionRejected as exc:
            await _respond_json(writer, 503, {
                "error": "admission_rejected",
                "queue_depth": exc.queue_depth,
                "max_queue": exc.max_queue})
            return
        try:
            watcher = worker.watch_done(sess)
            await worker.call(lambda eng: sess.push(prompt))
            await worker.call(lambda eng: sess.finish(wait=False))
            res = await asyncio.wrap_future(watcher)
        except (SessionFaulted, WorkerDied) as exc:
            # engine-side failure (quarantined session / lost worker),
            # not a bad request: 500, typed
            await _respond_json(writer, 500,
                                {"error": str(exc), "faulted": True})
            return
        except Exception as exc:
            await _respond_json(writer, 400, {"error": str(exc)})
            if sess.fault is None:
                worker.submit(lambda eng: sess.finish(wait=False))
            return
        await _respond_json(writer, 200, res)

    async def _handle_healthz(self, writer: asyncio.StreamWriter) -> None:
        """Liveness: 200 iff every engine worker is alive and within
        its heartbeat watchdog and the server is not draining, else
        503.  Reads thread state and counters directly — a health
        check must not queue behind (or hang on) the very worker it is
        diagnosing."""
        engines, ok = {}, True
        for role, worker in self._workers():
            watchdog = getattr(worker.engine.config,
                               "worker_watchdog", None)
            age = worker.heartbeat_age()
            alive = worker.is_alive()
            healthy = alive and (watchdog is None or age <= watchdog)
            engines[role] = {
                "alive": alive,
                "healthy": healthy,
                "heartbeat_age_s": round(age, 4),
                "watchdog_s": watchdog,
                "restarts": self._restarts[role],
                "faulted_sessions":
                    worker.engine.metrics.faulted_sessions,
            }
            ok = ok and healthy
        status = 200 if ok and not self._draining else 503
        await _respond_json(writer, status, {
            "ok": status == 200, "draining": self._draining,
            "engines": engines})

    async def _handle_metrics(self, writer: asyncio.StreamWriter) -> None:
        out = {}
        for role, worker in self._workers():
            try:
                out[role] = await worker.call(
                    lambda eng: eng.metrics.snapshot())
            except WorkerDied:
                # dead worker isn't mutating anything: read directly
                out[role] = worker.engine.metrics.snapshot()
        await _respond_json(writer, 200, out)


# ---- client helpers ---------------------------------------------------

class ServerRejected(RuntimeError):
    """Client-side image of a 503 admission rejection."""

    def __init__(self, payload: dict):
        self.queue_depth = payload.get("queue_depth")
        self.max_queue = payload.get("max_queue")
        super().__init__(
            f"server rejected session: queue depth {self.queue_depth} "
            f"at max_queue={self.max_queue}")


def _parse_status(first_line: str) -> int:
    try:
        return int(first_line.split()[1])
    except (IndexError, ValueError):
        raise ProtocolError(
            f"malformed status line: {first_line[:64]!r}") from None


def _backoff_delay(rng: random.Random, attempt: int, base: float,
                   cap: float) -> float:
    """Jittered exponential backoff: min(cap, base * 2^attempt) scaled
    by a uniform [0.5, 1.5) draw from the caller's seeded rng (no
    wall-clock, no global RNG — retry schedules replay exactly)."""
    return min(cap, base * (2 ** attempt)) * (0.5 + rng.random())


async def _raise_for_error(status: int, reader: asyncio.StreamReader,
                           headers: dict) -> None:
    body = await _read_sized_body(reader, headers)
    payload = json.loads(body) if body else {}
    if status == 503:
        raise ServerRejected(payload)
    raise RuntimeError(f"server error {status}: {payload}")


class AsrClient:
    """One streaming ASR session over the wire: lockstep JSON-chunk RPC
    (each command chunk gets exactly one response chunk)."""

    def __init__(self, reader: asyncio.StreamReader,
                 writer: asyncio.StreamWriter):
        self._reader = reader
        self._writer = writer
        self._closed = False

    @classmethod
    async def open(cls, host: str, port: int, retries: int = 0,
                   backoff: float = 0.05, backoff_cap: float = 2.0,
                   seed: int = 0) -> "AsrClient":
        """Open a session; with `retries` > 0, 503 backpressure
        rejections and connection failures (a worker restart / drain
        window) are retried with seeded jittered exponential backoff —
        deterministic per `seed`, so a load harness replays the same
        schedule."""
        rng = random.Random(seed)
        attempt = 0
        while True:
            try:
                return await cls._open_once(host, port)
            except (ServerRejected, ConnectionError, OSError):
                if attempt >= retries:
                    raise
                await asyncio.sleep(_backoff_delay(
                    rng, attempt, backoff, backoff_cap))
                attempt += 1

    @classmethod
    async def _open_once(cls, host: str, port: int) -> "AsrClient":
        reader, writer = await asyncio.open_connection(host, port)
        writer.write((f"POST /asr HTTP/1.1\r\nHost: {host}:{port}\r\n"
                      "Content-Type: application/json\r\n"
                      "Transfer-Encoding: chunked\r\n\r\n").encode())
        await writer.drain()
        first, headers = await _read_head(reader)
        status = _parse_status(first)
        if status != 200:
            try:
                await _raise_for_error(status, reader, headers)
            finally:
                writer.close()
        return cls(reader, writer)

    async def _rpc(self, obj: dict) -> dict:
        await _write_chunk(self._writer, json.dumps(obj).encode())
        data = await _read_chunk(self._reader)
        if data is None:
            raise ConnectionError("server ended the response stream")
        return json.loads(data)

    async def push(self, audio) -> dict:
        return await self._rpc(
            {"op": "push",
             "audio": np.asarray(audio, np.float32).tolist()})

    async def poll(self) -> dict:
        return await self._rpc({"op": "poll"})

    async def finish(self) -> dict:
        res = await self._rpc({"op": "finish"})
        await _read_chunk(self._reader)       # server's terminating chunk
        await self.aclose()
        return res

    async def aclose(self) -> None:
        if self._closed:
            return
        self._closed = True
        try:
            await _write_last_chunk(self._writer)
        except (ConnectionError, OSError):
            pass
        self._writer.close()
        try:
            await self._writer.wait_closed()
        except (ConnectionError, OSError):
            pass


async def _post_json(host: str, port: int, path: str,
                     payload: dict) -> dict:
    reader, writer = await asyncio.open_connection(host, port)
    try:
        body = json.dumps(jsonable(payload)).encode()
        writer.write((f"POST {path} HTTP/1.1\r\nHost: {host}:{port}\r\n"
                      "Content-Type: application/json\r\n"
                      f"Content-Length: {len(body)}\r\n\r\n").encode()
                     + body)
        await writer.drain()
        first, headers = await _read_head(reader)
        status = _parse_status(first)
        if status != 200:
            await _raise_for_error(status, reader, headers)
        return json.loads(await _read_sized_body(reader, headers))
    finally:
        writer.close()
        try:
            await writer.wait_closed()
        except (ConnectionError, OSError):
            pass


async def lm_generate(host: str, port: int, prompt, retries: int = 0,
                      backoff: float = 0.05, backoff_cap: float = 2.0,
                      seed: int = 0) -> dict:
    """One-shot LM generation over the wire; `retries` > 0 retries 503
    backpressure / connection failures with seeded jittered backoff
    (same schedule contract as `AsrClient.open`)."""
    rng = random.Random(seed)
    attempt = 0
    while True:
        try:
            return await _post_json(host, port, "/lm",
                                    {"prompt": np.asarray(prompt).tolist()})
        except (ServerRejected, ConnectionError, OSError):
            if attempt >= retries:
                raise
            await asyncio.sleep(_backoff_delay(
                rng, attempt, backoff, backoff_cap))
            attempt += 1


async def fetch_healthz(host: str, port: int) -> Tuple[int, dict]:
    """GET /healthz, returning (status, payload) WITHOUT raising on 503
    — a health probe wants the degraded payload, not an exception."""
    reader, writer = await asyncio.open_connection(host, port)
    try:
        writer.write((f"GET /healthz HTTP/1.1\r\nHost: {host}:{port}"
                      "\r\n\r\n").encode())
        await writer.drain()
        first, headers = await _read_head(reader)
        status = _parse_status(first)
        body = await _read_sized_body(reader, headers)
        return status, (json.loads(body) if body else {})
    finally:
        writer.close()
        try:
            await writer.wait_closed()
        except (ConnectionError, OSError):
            pass


async def fetch_metrics(host: str, port: int) -> dict:
    reader, writer = await asyncio.open_connection(host, port)
    try:
        writer.write((f"GET /metrics HTTP/1.1\r\nHost: {host}:{port}"
                      "\r\n\r\n").encode())
        await writer.drain()
        first, headers = await _read_head(reader)
        status = _parse_status(first)
        if status != 200:
            await _raise_for_error(status, reader, headers)
        return json.loads(await _read_sized_body(reader, headers))
    finally:
        writer.close()
        try:
            await writer.wait_closed()
        except (ConnectionError, OSError):
            pass
