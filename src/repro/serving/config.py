"""Declarative serving configuration: one frozen `Program` per workload.

The paper's accelerator is configured by a mutable command sequence
(ConfigureASR_AcousticScoring -> ConfigureASR_HypExpansion ->
ConfigureBeamWidth).  The serving engine replaces that with a single
frozen spec: an `AsrProgram` (acoustic model + hypothesis expansion +
decoding step geometry, compiled into a static `StepPlan`) or an
`LmProgram` (LM arch + cache/generation budget), wrapped in an
`EngineConfig` that adds the slot-pool size.  A configured engine never
mutates its program — reconfiguration means building a new engine.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Optional, Tuple, Union

import numpy as np
from jax.sharding import Mesh

from repro.configs.base import ModelConfig
from repro.configs.tds_asr import (DECODER_CONFIG, FEATURE_CONFIG,
                                   DecoderConfig, FeatureConfig, TDSConfig)
from repro.core.lexicon import BigramLM, Lexicon
from repro.core.stepplan import StepPlan, make_step_plan
from repro.kernels.policy import KernelPolicy


@dataclass(frozen=True)
class AsrProgram:
    """The streaming ASR decoding program (paper §3: one small decoder
    program per stage — acoustic scoring then hypothesis expansion)."""
    tds_cfg: TDSConfig
    lex: Lexicon
    lm: BigramLM
    feat_cfg: FeatureConfig = FEATURE_CONFIG
    dec_cfg: DecoderConfig = DECODER_CONFIG
    use_int8: bool = False
    step_ms: float = 80.0
    # Upper bound on how many buffered step_ms windows ONE fused decoding
    # step may consume (powers of two below it are the step buckets, like
    # LmProgram.prefill_buckets).  Live streaming still steps window by
    # window; bulk decoding (whole utterances buffered) folds up to this
    # many windows into the acoustic forward's row dimension, reading
    # each FC weight matrix once per multi-window step instead of once
    # per 80 ms window.  1 disables fusion.
    max_windows_per_step: int = 4
    # On finish(), a session whose buffer still holds samples no decoded
    # frame has covered (more than the frame_len - frame_shift overlap a
    # step retains) gets that trailing partial window zero-padded and
    # decoded by one last step before finalize — without it, up to
    # ~step_ms of tail audio (often the end of the last word) is
    # silently dropped.  The deprecated ASRPU command shims disable it:
    # the paper's DecodingStep/best commands have no end-of-input signal
    # and only ever decode whole windows.
    flush_tail: bool = True
    # Per-push input cap (samples): one push may not exceed this many
    # audio samples (default ~60 s at the paper's 16 kHz).  Admission
    # validation, not a stream-length bound — a session may push many
    # capped chunks.
    max_push_samples: int = 960_000

    def step_buckets(self) -> Tuple[int, ...]:
        """Descending window counts a fused step may take (one jit entry
        each, traced lazily on first use)."""
        out, b = [], 1
        while b <= self.max_windows_per_step:
            out.append(b)
            b *= 2
        return tuple(reversed(out))

    def step_plan(self) -> StepPlan:
        """The static setup-thread schedule for one decoding step."""
        return make_step_plan(self.tds_cfg, self.feat_cfg, self.step_ms,
                              self.dec_cfg.beam_size)

    def prepare_params(self, params, mesh=None):
        """Build-time weight preparation for the decoding step, returning
        `(params, prepared)`:

          * int8 programs quantize every FC/head weight matrix ONCE
            (`tds.quantize_params`) into `prepared` so the hot path only
            quantizes activations; fp32 programs get `prepared=None`.
          * with a `mesh` (the engine's model-parallel spec), both trees
            are PLACED with `param_shardings`-style NamedShardings —
            FC/head matmuls (and their int8 `wq` payloads) split on the
            feature axis over the 'model' mesh axis, everything else
            replicated — so each device of the sharded engine step holds
            only its weight shard (`parallel.sharding.tds_param_specs`).

        The engine passes both results straight into
        `tds.forward_batched`."""
        prepared = None
        if self.use_int8:
            from repro.models import tds
            prepared = tds.quantize_params(params, self.tds_cfg)
        if mesh is not None:
            from repro.parallel import sharding as shlib
            params = shlib.place_tree(
                params, shlib.tds_param_specs(self.tds_cfg, mesh), mesh)
            if prepared is not None:
                prepared = shlib.place_tree(
                    prepared, shlib.tds_prepared_specs(self.tds_cfg, mesh),
                    mesh)
        return params, prepared

    def validate_input(self, chunk: np.ndarray) -> None:
        """Admission-time validation of one pushed audio chunk: the
        fused step trusts its inputs (a NaN sample poisons the slot's
        beam scores irrecoverably and a huge chunk is an allocation
        attack), so the session front-end rejects bad input HERE —
        before anything is buffered — instead of letting it fault the
        co-batched step later."""
        chunk = np.asarray(chunk)
        if chunk.ndim != 1:
            raise ValueError(
                f"audio chunk must be 1-D samples, got shape "
                f"{chunk.shape}")
        if not np.issubdtype(chunk.dtype, np.floating):
            raise ValueError(
                f"audio chunk must be float samples, got dtype "
                f"{chunk.dtype}")
        if chunk.shape[0] > self.max_push_samples:
            raise ValueError(
                f"audio chunk of {chunk.shape[0]} samples exceeds "
                f"max_push_samples={self.max_push_samples}")
        if chunk.shape[0] and not np.isfinite(chunk).all():
            raise ValueError("audio chunk contains NaN/Inf samples")

    def with_beam_width(self, beam: float) -> "AsrProgram":
        """ConfigureBeamWidth as a pure derivation, not a mutation."""
        return replace(self, dec_cfg=replace(self.dec_cfg,
                                             beam_threshold=beam))


@dataclass(frozen=True)
class LmProgram:
    """Batched LM serving program: arch + pooled-cache geometry.

    `prefill_buckets` bounds admission-time compilation: prompts are
    right-padded to the smallest covering bucket and prefilled through
    one jit entry per bucket (a masked multi-row prefill), instead of
    one jit entry per distinct prompt length.  Empty = derive powers of
    two from 8 up to the first one covering the longest legal prompt.
    """
    model_cfg: ModelConfig
    cache_len: int
    max_new: int
    prefill_buckets: Tuple[int, ...] = ()

    @property
    def max_prompt_len(self) -> int:
        return self.cache_len - self.max_new

    def buckets(self) -> Tuple[int, ...]:
        if self.prefill_buckets:
            bs = tuple(sorted(set(int(b) for b in self.prefill_buckets)))
            if bs[-1] < self.max_prompt_len:
                raise ValueError(
                    f"largest prefill bucket {bs[-1]} does not cover the "
                    f"longest legal prompt ({self.max_prompt_len})")
        else:
            out, b = [8], 8
            while b < self.max_prompt_len:
                b *= 2
                out.append(b)
            bs = tuple(out)
        # prefill chunking (attention chunks, SSD chunk size) requires
        # every bucket S to satisfy S % min(chunk, S) == 0
        chunks = [self.model_cfg.attn_chunk_q, self.model_cfg.attn_chunk_kv]
        if self.model_cfg.ssm is not None:
            chunks.append(self.model_cfg.ssm.chunk_size)
        for b in bs:
            for c in chunks:
                if b % min(c, b):
                    raise ValueError(
                        f"prefill bucket {b} not divisible by chunk {c}")
        return bs

    def validate_prompt(self, prompt_len: int) -> None:
        if prompt_len < 1:
            raise ValueError("prompt must contain at least one token")
        if prompt_len + self.max_new > self.cache_len:
            raise ValueError(
                f"prompt_len={prompt_len} + max_new={self.max_new} exceeds "
                f"cache_len={self.cache_len}")

    def validate_input(self, prompt: np.ndarray) -> None:
        """Admission-time validation of a pushed prompt: token ids must
        be an integral 1-D vector inside the vocabulary — an
        out-of-range id indexes garbage through the embedding gather
        (or faults the device) inside the shared prefill batch, so it
        is rejected before it can be co-batched."""
        prompt = np.asarray(prompt)
        if prompt.ndim != 1:
            raise ValueError(
                f"prompt must be a 1-D token vector, got shape "
                f"{prompt.shape}")
        if not np.issubdtype(prompt.dtype, np.integer):
            raise ValueError(
                f"prompt must hold integer token ids, got dtype "
                f"{prompt.dtype}")
        self.validate_prompt(prompt.shape[0])
        vocab = self.model_cfg.vocab_size
        if prompt.size and (prompt.min() < 0 or prompt.max() >= vocab):
            raise ValueError(
                f"prompt token ids must be in [0, {vocab}), got range "
                f"[{prompt.min()}, {prompt.max()}]")


Program = Union[AsrProgram, LmProgram]


@dataclass(frozen=True)
class EngineConfig:
    """A program plus the slot-pool size it is served over.

    `kernels` selects how Pallas-backed decode ops execute (ref /
    interpret / Mosaic, resolved per backend by default) — it replaced
    the old per-call `use_pallas_prune` bool threaded through the
    decoder; see repro.kernels.policy.KernelPolicy.

    `mesh` is the parallel spec: a `jax.sharding.Mesh` with a 'model'
    axis, and optionally a 'data' axis.  The ASR engine then places
    FC/head weights as feature-axis shards over 'model' and runs its
    fused step under `shard_map`, so each device reads only its weight
    shard (the B=1 fp32 step is bound by the per-window FC weight
    traffic; see ROADMAP).  With a 'data' axis the SLOT POOL is sharded
    too: each data shard holds `n_slots / n_data` slots' stream state,
    beam, and gathered sub-batch rows end-to-end (beam expansion is
    embarrassingly parallel across slots, so the only collectives stay
    the 'model'-axis psums), which is what scales serve throughput with
    device count instead of just splitting weight reads.  `n_slots`
    must divide evenly over the 'data' axis.  None (the default) keeps
    the exact single-device step — not a 1-device mesh, the same
    unsharded jit as before — and 1D ('model',) meshes keep PR 5's
    replicated-pool step bitwise.

    `overlap_psum` switches the sharded step's model-parallel
    contractions to the latency-hiding output-column split
    (`ops.psum_overlap_matmul`): each layer's all-reduce is chunked so
    it can complete under the next chunk's local matmul on backends
    with async collectives.  Numerically ~1e-6-equal to the default
    synchronous psum, which stays the parity reference.  A no-op
    without a mesh (there is nothing to overlap).

    `max_queue` is the admission backpressure bound: with every slot
    busy and this many sessions already queued, `Engine.open()` raises
    `AdmissionRejected` (a typed error the network front-end maps to
    503) instead of queueing unboundedly.  None (default) keeps the
    unbounded in-process behavior; 0 means "never queue — reject unless
    a slot is free".

    Fault-tolerance knobs (see README "Fault tolerance"):

    `session_deadline` — wall-clock seconds a session may live from
    `open()` before the pump reaps it (`DeadlineExceeded`, a typed
    `SessionFaulted`), freeing its slot/queue entry.  None = no
    deadline.

    `worker_watchdog` — seconds an `EngineWorker`'s heartbeat may age
    before the server's supervisor declares the worker wedged, fails
    its in-flight futures, rebuilds the pool, and restarts the thread.
    None disables the wedge detection (a DEAD thread is still detected
    and restarted).

    `faults` — an armed `repro.serving.faults.FaultPolicy` consulted at
    the engine's injection sites; None (production) skips every check.
    """
    program: Program
    n_slots: int = 1
    kernels: KernelPolicy = field(default_factory=KernelPolicy)
    mesh: Optional[Mesh] = None
    max_queue: Optional[int] = None
    overlap_psum: bool = False
    session_deadline: Optional[float] = None
    worker_watchdog: Optional[float] = None
    faults: Optional[object] = None    # FaultPolicy; object() keeps the
                                       # config module import-light

    def __post_init__(self):
        if self.n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {self.n_slots}")
        if self.max_queue is not None and self.max_queue < 0:
            raise ValueError(
                f"max_queue must be None or >= 0, got {self.max_queue}")
        if self.session_deadline is not None and self.session_deadline <= 0:
            raise ValueError(
                f"session_deadline must be None or > 0, got "
                f"{self.session_deadline}")
        if self.worker_watchdog is not None and self.worker_watchdog <= 0:
            raise ValueError(
                f"worker_watchdog must be None or > 0, got "
                f"{self.worker_watchdog}")
        if self.mesh is not None:
            if "model" not in self.mesh.axis_names:
                raise ValueError(
                    f"serving mesh needs a 'model' axis, got {self.mesh}")
            extra = [a for a in self.mesh.axis_names
                     if a not in ("data", "model")]
            if extra:
                raise ValueError(
                    f"serving mesh axes must be ('data', 'model') or "
                    f"('model',), got extra axes {extra} in {self.mesh}")
            if "data" in self.mesh.axis_names:
                nd = self.mesh.shape["data"]
                if self.n_slots % nd != 0:
                    raise ValueError(
                        f"n_slots={self.n_slots} must divide evenly over "
                        f"the 'data' mesh axis (size {nd}): each data "
                        f"shard owns n_slots/n_data pool slots")


def make_engine(config: EngineConfig, params):
    """Build the engine matching `config.program`'s workload type."""
    from repro.serving.asr import AsrEngine
    from repro.serving.lm import LmEngine

    if isinstance(config.program, AsrProgram):
        return AsrEngine(config, params)
    if isinstance(config.program, LmProgram):
        return LmEngine(config, params)
    raise TypeError(f"unknown program type: {type(config.program)!r}")
