"""Streaming ASR engine: B utterance slots, ONE slot-native decoding step.

The fused decoding step (paper §3.1: acoustic scoring — MFCC + the TDS
kernel sequence — then one hypothesis expansion per emitted acoustic
frame) is pure in all carried state, and slot-native END TO END:
acoustic scoring runs through `tds.forward_batched` (the slot axis
folds into the row dimension of every FC/LayerNorm matmul and conv tap
— no per-slot vmap), the MFCC tail is the fused logmel kernel, int8
programs use weights pre-quantized ONCE at engine build
(`AsrProgram.prepare_params`), and hypothesis expansion is natively
slot-batched (`decoder.expand_step_batched`): the shared lexicon trie /
bigram table are gathered once over the flattened slot index set and
the fused Pallas hypothesis unit runs with a batch grid axis.  Every
pytree leaf of the TDS left-context state and of the `BeamState`
carries a leading slot axis, each slot keeps its own sample buffer, and
one jitted step advances every slot that has a full window buffered.
Slots without a window are masked out — their carried state passes
through unchanged — so each slot's trajectory is exactly the
single-stream decoder's.

Window bookkeeping is the setup-thread arithmetic from core/features:
`frames_producible` decides whether a slot can step (enough buffered
samples for plan.feat_frames_per_step whole frames) and
`consumed_samples` decides how many samples a step retires (the MFCC
framing overlap stays buffered).  When a slot has several whole windows
buffered (bulk decoding — `serve(utterances)`), one fused step consumes
up to `AsrProgram.max_windows_per_step` of them at once: each window's
samples are extracted exactly as a w=1 step would see them, so the fold
is bit-identical to stepping windows one at a time, but every TDS
weight matrix is read once per multi-window step instead of once per
80 ms window (the acoustic forward is weight-bandwidth-bound at B=1).

Each step runs on a GATHERED sub-batch, not the full masked pool: the
scheduler picks the window count w maximizing retired windows
(w x eligible slots, largest w on ties), gathers exactly the eligible
slots into the smallest covering slot bucket (powers of two up to
n_slots), and scatters their new state back.  Skipped slots are simply
never written — per-slot trajectories are untouched (the acoustic
forward and the expansion are row-independent in the slot axis, pinned
bitwise by tests).  The old full-pool masked step paid B=n_slots
compute however few slots were eligible, which made the ragged tail of
a utterance batch SLOWER than sequential decoding (a one-eligible-slot
w=4 step cost ~4x its B=1 equivalent; see BENCH_decode.json's
serve_asr_batched_b4 history).

With `EngineConfig.mesh` set (a Mesh with a 'model' axis), the fused
step runs under `shard_map`: FC/head weights live as feature-axis
shards (`AsrProgram.prepare_params` places them), each device contracts
its shard and psums partial products (`tds.forward_batched(axis=)`),
and everything else — convs, LayerNorms, MFCC, hypothesis expansion —
stays replicated.  mesh=None is the exact single-device path.

A 2D ('data', 'model') mesh additionally shards the SLOT POOL: each
data shard owns n_slots/n_data contiguous slots — their TDS
left-context state, beam, and gathered sub-batch rows
(`parallel.sharding.asr_state_specs`) — and steps them end-to-end
without any 'data'-axis collective (beam expansion is embarrassingly
parallel across slots; only the 'model'-axis matmul psums remain).
The scheduler keeps the gather/scatter shard-aligned: eligible slots
group by home shard, every shard runs the same per-shard pow-2 bucket,
and pad rows carry index -1 so their garbage update is dropped on
scatter-back.  Per-slot trajectories stay bit-identical to mesh=None.
`EngineConfig.overlap_psum` swaps the model-axis psums for the
latency-hiding output-column split (`ops.psum_overlap_matmul`).

Two API layers:
  * slot level — `feed_slot` / `pump` / `slot_best` / `reset_slot`:
    direct slot addressing for the deprecated ASRPU command shims
    (core/scheduler.py).  Do not mix with sessions on the same engine.
  * session level — `open()` -> Session.push/poll/finish, plus the
    `serve(utterances)` convenience (continuous batching over whole
    utterances, results in input order).
"""
from __future__ import annotations

from collections import deque
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import decoder as dec
from repro.core import features
from repro.models import tds
from repro.analysis.guards import no_implicit_transfers
from repro.serving.config import AsrProgram, EngineConfig
from repro.serving.engine import (Engine, Session, SessionFaulted,
                                 copy_result, worker_only)


def empty_hypothesis() -> dict:
    """Readout when no beam exists yet (nothing decoded): same keys as a
    real `decoder.materialize_best` payload, -inf score."""
    return {"words": np.zeros((0,), np.int32),
            "tokens": np.zeros((0,), np.int32), "score": -np.inf}


class AsrEngine(Engine):
    def __init__(self, config: EngineConfig, params):
        assert isinstance(config.program, AsrProgram), config.program
        super().__init__(config)
        self.program: AsrProgram = config.program
        self.plan = self.program.step_plan()
        fc = self.program.feat_cfg
        nfr = self.plan.feat_frames_per_step
        # samples retired per step / needed buffered for a full window
        self._spp = features.consumed_samples(nfr, fc)
        self._need = fc.frame_len + (nfr - 1) * fc.frame_shift
        # samples a step retains for MFCC framing overlap: buffered
        # samples beyond this were never covered by a decoded frame
        self._overlap = self._need - self._spp
        assert self._spp == self.plan.samples_per_step, \
            (self._spp, self.plan.samples_per_step)
        assert features.frames_producible(self._need, fc) == nfr
        mesh = config.mesh
        # 2D ('data','model') mesh: the slot pool itself is sharded —
        # each data shard owns n_slots/n_data contiguous pool slots
        # (slot s lives on shard s // slots_per_shard) and carries them
        # end-to-end through the fused step; 'model' keeps PR 5's
        # feature-axis weight shards.  mesh=None / 1D stay the exact
        # replicated-pool paths.
        self._data_axis = ("data" if mesh is not None
                           and "data" in mesh.axis_names else None)
        self._n_data = mesh.shape["data"] if self._data_axis else 1
        self._slots_per_shard = self.n_slots // self._n_data
        # per-step batch/idx uploads are placed EXPLICITLY with the
        # step's in_specs sharding: jnp.asarray would commit them to one
        # device and every dispatch would then reshard them through an
        # implicit transfer (caught by no_implicit_transfers(strict=True))
        if mesh is not None:
            from jax.sharding import NamedSharding
            from jax.sharding import PartitionSpec as P
            dspec = ((P("data", None, None), P("data"))
                     if self._data_axis else (P(), P()))
            self._input_shardings = tuple(
                NamedSharding(mesh, s) for s in dspec)
        else:
            self._input_shardings = None
        self._buckets = self.program.step_buckets()
        self._slot_buckets = self._make_slot_buckets()
        # int8 weights are quantized exactly ONCE, here — the decoding
        # step then only quantizes activations (ops.int8_matmul_prepared)
        # — and, under a mesh, weights are PLACED as feature-axis shards
        self.params, self._prepared = self.program.prepare_params(
            params, config.mesh)
        self._jit_step = self._build_step()
        self._jit_reset = jax.jit(self._reset_slot_fn())
        self._jit_best = jax.jit(self._slot_best_fn(final=False))
        self._jit_best_final = jax.jit(self._slot_best_fn(final=True))
        self._reset_pool()

    # ---- the fused decoding-step program -----------------------------
    def _make_slot_buckets(self):
        """Ascending PER-SHARD sub-batch sizes a gathered step may run
        at (powers of two, topped by slots_per_shard) — one jit entry
        per (b, w) pair, traced lazily, mirroring
        `AsrProgram.step_buckets`.  Without a 'data' mesh axis,
        slots_per_shard == n_slots and these are the total sub-batch
        sizes as before; with one, the dispatched batch is
        bucket * n_data rows (every shard steps the same local bucket,
        so the gather/scatter stays shard-aligned — a multiple of
        n_data by construction)."""
        out, b = [], 1
        while b < self._slots_per_shard:
            out.append(b)
            b *= 2
        out.append(self._slots_per_shard)
        return tuple(sorted(set(out)))

    def _step_fn(self):
        """One slot-native decoding step over a GATHERED sub-batch:
        acoustic scoring (the fused logmel MFCC tail + the TDS kernel
        sequence) runs natively over the gathered slot axis — every
        FC/head/LayerNorm sees one (b*T, w*c)-row matmul and every conv
        tap one (b*T*w, c)-row matmul — then each emitted acoustic
        frame runs ONE natively batched hypothesis expansion (shared
        lexicon/LM gathers over the flattened slot index set + the
        fused hypothesis unit).  Only the gathered slots are written
        back; every other slot's carried state is untouched."""
        prog = self.program
        nfr = self.plan.feat_frames_per_step
        kernels = self.config.kernels
        axis = "model" if self.config.mesh is not None else None
        data_axis = self._data_axis
        spshard = self._slots_per_shard
        overlap = self.config.overlap_psum

        def step(params, prepared, stream_state, beam_state, samples,
                 slots):
            # samples: (b, w, samples_per_window) — w buffered 80 ms
            # windows for each of the b gathered slots, extracted window
            # by window (each row is exactly the signal a w=1 step would
            # see, so fusing windows is bit-identical to stepping them
            # one at a time).  slots: (b,) int32 pool indices; bucket
            # padding repeats a real slot, whose duplicate rows compute
            # an identical update, so the scatter-back stays exact.
            #
            # With a 'data' mesh axis, this body sees one data shard's
            # view: stream_state/beam_state are its slots_per_shard
            # local pool rows, samples/slots its rows of the gathered
            # sub-batch.  slots stay GLOBAL pool indices (shard d owns
            # [d*spshard, (d+1)*spshard)); bucket padding is -1 — pad
            # rows gather local row 0, compute a garbage update, and
            # are dropped by the out-of-range scatter, so every real
            # slot's trajectory is bit-identical to the unsharded step.
            b, w, _ = samples.shape
            if data_axis is not None:
                d = jax.lax.axis_index(data_axis)
                loc = slots - d * spshard
                valid = slots >= 0
                gidx = jnp.where(valid, loc, 0)
            else:
                gidx = slots
            ss = jax.tree.map(lambda a: a[gidx], stream_state)
            bs = jax.tree.map(lambda a: a[gidx], beam_state)
            feats = features.mfcc(samples, prog.feat_cfg, use_pallas=True,
                                  kernels=kernels, hot=True)[:, :, :nfr]
            feats = feats.reshape(b, w * nfr, -1)
            logp, new_ss = tds.forward_batched(
                params, prog.tds_cfg, feats, ss,
                use_int8=prog.use_int8, kernels=kernels, prepared=prepared,
                axis=axis, overlap=overlap)

            def expand(bst, lp):           # lp: (b, V) — one frame, all slots
                return dec.expand_step_batched(bst, lp, prog.lex, prog.lm,
                                               prog.dec_cfg, kernels), None
            new_bs, _ = jax.lax.scan(expand, bs, jnp.swapaxes(logp, 0, 1))

            if data_axis is not None:
                # out-of-range rows (pad, or another shard's slot — the
                # scheduler never builds those) drop instead of writing
                widx = jnp.where(valid, loc, spshard)

                def put(full, new):
                    return full.at[widx].set(new, mode="drop")
            else:
                def put(full, new):
                    return full.at[slots].set(new)
            return (jax.tree.map(put, stream_state, new_ss),
                    jax.tree.map(put, beam_state, new_bs))

        return step

    def _build_step(self):
        """jit the fused step; with a mesh, wrap it in `shard_map` so
        each device reads only its FC/head weight shard (psum-reduced
        contractions inside `tds.forward_batched`).  On a 1D ('model',)
        mesh, slot state, samples, and the expansion stay replicated
        (PR 5's layout, bitwise-preserved); on a 2D ('data','model')
        mesh, the pool state and the gathered sub-batch are sharded on
        their slot axis over 'data' (`asr_state_specs`) and come back
        out still sharded — expansion is slot-parallel, so the step has
        no 'data'-axis collectives at all."""
        step = self._step_fn()
        mesh = self.config.mesh
        if mesh is None:
            return jax.jit(step)
        from jax.sharding import PartitionSpec as P

        from repro import compat
        from repro.parallel import sharding as shlib
        pspecs = shlib.tds_param_specs(self.program.tds_cfg, mesh)
        qspecs = (shlib.tds_prepared_specs(self.program.tds_cfg, mesh)
                  if self._prepared is not None else P())
        if self._data_axis is not None:
            ss_t, bs_t = jax.eval_shape(
                lambda: (tds.init_batched_stream_state(
                            self.program.tds_cfg, self.n_slots),
                         dec.init_batched_state(
                            self.n_slots, self.program.dec_cfg.beam_size,
                            self.program.lm)))
            sspecs = shlib.asr_state_specs(ss_t, mesh)
            bspecs = shlib.asr_state_specs(bs_t, mesh)
            return jax.jit(compat.shard_map(
                step, mesh=mesh,
                in_specs=(pspecs, qspecs, sspecs, bspecs,
                          P("data", None, None), P("data")),
                out_specs=(sspecs, bspecs), check_vma=False))
        rep = P()
        return jax.jit(compat.shard_map(
            step, mesh=mesh,
            in_specs=(pspecs, qspecs, rep, rep, rep, rep),
            out_specs=(rep, rep), check_vma=False))

    def _reset_slot_fn(self):
        """One fused slot reset (utterance boundary): writing the fresh
        left-context + beam leaves slot-by-slot in eager mode costs an
        un-jitted scatter per pytree leaf, which dominated sequential
        serving; fusing them makes admission O(one dispatch)."""
        prog = self.program

        def reset(stream_state, beam, slot):
            return (tds.reset_stream_slot(stream_state, slot, prog.tds_cfg),
                    dec.reset_slot(beam, slot, prog.lm))

        return reset

    def _slot_best_fn(self, final: bool):
        """Fused slot-slice (+ optional finalize) + argmax readout: the
        eager version paid one dispatch per BeamState leaf per poll."""
        prog = self.program

        def f(beam, slot):
            st = dec.slot_state(beam, slot)
            if final:
                st = dec.finalize(st, prog.lex, prog.lm, prog.dec_cfg)
            return dec.best(st)

        return f

    # ---- slot-pool state ---------------------------------------------
    def _reset_pool(self) -> None:
        self._slot_bufs: List[np.ndarray] = [
            np.zeros((0,), np.float32) for _ in range(self.n_slots)]
        self._slot_steps = np.zeros((self.n_slots,), np.int64)
        self._stream_state = None
        self._beam = None
        # (n_active, slot bucket b, window bucket w) per fused step —
        # scheduling introspection for tests and benchmarks; bounded so
        # a long-lived streaming engine doesn't accumulate one tuple
        # per 80 ms step forever
        self.step_shapes: deque = deque(maxlen=4096)

    def _ensure_state(self) -> None:
        if self._stream_state is not None:
            return
        # build + place locally, commit both attrs only once everything
        # succeeded: a device_put failure must not leave the pool with a
        # stream state but no beam (commit discipline, RPL008's pattern)
        stream_state = tds.init_batched_stream_state(
            self.program.tds_cfg, self.n_slots)
        beam = dec.init_batched_state(
            self.n_slots, self.program.dec_cfg.beam_size,
            self.program.lm)
        if self._data_axis is not None:
            # place the pool slot-axis-sharded from the start so the
            # sharded step never reshards it (outputs keep the
            # sharding via out_specs; resets/readouts go through
            # plain jit, which GSPMD handles on sharded inputs)
            from repro.parallel import sharding as shlib
            mesh = self.config.mesh
            stream_state = shlib.place_tree(
                stream_state,
                shlib.asr_state_specs(stream_state, mesh), mesh)
            beam = shlib.place_tree(
                beam, shlib.asr_state_specs(beam, mesh), mesh)
        self._stream_state = stream_state
        self._beam = beam

    def adopt_state(self, old: "AsrEngine") -> None:
        """Take over another engine's in-flight slot-pool state (sample
        buffers, left context, beam, step counts).  Used by the
        deprecated configure-command shims, which must rebuild the
        engine on reconfiguration without losing mid-utterance state."""
        assert old.n_slots == self.n_slots, (old.n_slots, self.n_slots)
        self._slot_bufs = old._slot_bufs
        self._slot_steps = old._slot_steps
        self._stream_state = old._stream_state
        self._beam = old._beam
        self.n_steps = old.n_steps

    def reset_slot(self, slot: int) -> None:
        """Utterance boundary in one slot: clear its buffer, left
        context, and hypothesis memory; other slots are untouched.

        The jitted reset dispatch runs FIRST: it can raise (OOM, a
        poisoned donated buffer), and committing the cleared host-side
        buffers before it would leave the slot half-reset — empty
        buffer, stale beam (RPL008)."""
        if self._stream_state is not None:
            new_stream, new_beam = self._jit_reset(
                self._stream_state, self._beam, slot)
            self._stream_state, self._beam = new_stream, new_beam
        self._slot_bufs[slot] = np.zeros((0,), np.float32)
        self._slot_steps[slot] = 0

    def feed_slot(self, slot: int, samples) -> None:
        """Append raw samples to one slot's stream buffer.  Feeding marks
        decoding intent, so carried state is initialized here — a best
        readout after a partial first chunk sees a fresh beam (score 0,
        no words) rather than the unconfigured -inf sentinel."""
        self._ensure_state()
        self._slot_bufs[slot] = np.concatenate(
            [self._slot_bufs[slot], np.asarray(samples, np.float32)])

    def slot_windows(self, slot: int) -> int:
        """Setup-thread check: whole step_ms windows buffered in a slot."""
        return features.frames_producible(
            self._slot_bufs[slot].shape[0],
            self.program.feat_cfg) // self.plan.feat_frames_per_step

    def slot_can_step(self, slot: int) -> bool:
        """A full window of whole frames buffered."""
        return self.slot_windows(slot) >= 1

    @worker_only
    def _step(self) -> bool:
        """One fused decoding step over a gathered sub-batch.  The
        scheduler picks the step bucket `w` retiring the most buffered
        windows in one dispatch — w x (slots holding >= w windows),
        largest w on ties (bulk decoding amortizes weight reads; live
        streaming naturally runs w=1) — then gathers exactly the
        eligible slots into the smallest covering slot bucket.  Slots
        with fewer than w windows wait for a later, smaller-w pump
        round and are NOT stepped (no masked full-pool compute: a
        ragged tail of draining utterances steps at b=1/2, not
        b=n_slots).  False (and nothing runs) when no slot can produce
        output — all setup threads returned zero."""
        self._flush_finished_tails()
        avail = np.array([self.slot_windows(s)
                          for s in range(self.n_slots)])
        if not (avail >= 1).any():
            return False
        w = max((b for b in self._buckets if (avail >= b).any()),
                key=lambda b: (b * int((avail >= b).sum()), b))
        slots = [s for s in range(self.n_slots) if avail[s] >= w]
        self._ensure_state()
        self._step_isolated(slots, w)
        return True

    def _step_isolated(self, slots, w) -> None:
        """Run one gathered step with poison-slot isolation.  On
        failure the step is REPLAYED on bisected halves in probe mode
        (`_step_slots(..., commit=False)`) until the failure pins to
        single slots — probes commit nothing, and assembly is
        non-destructive, so every replay sees the exact same inputs.
        The pinned sessions alone are evicted with a typed
        `SessionFaulted`, then the surviving slots step TOGETHER in one
        committed call: the survivor set pads to the same slot bucket a
        fault-free pump would use, and each batch row depends only on
        its own slot, so survivor trajectories land bitwise identical
        to a fault-free run.  (Committing the probe halves instead
        would step survivors at smaller batch shapes, whose low-order
        float bits differ.)  A failure no probe can reproduce gets one
        committed full-set retry (a transient, not a poison slot); a
        second failure propagates to `_pump_once`'s pool quarantine.
        Slot-level callers (the deprecated command shims) have no
        session to attribute a pinned fault to, so the fault re-raises
        there."""
        try:
            self._step_slots(slots, w)
            return
        except Exception as exc:
            if len(slots) == 1:
                sess = self._owner[slots[0]]
                if sess is None:      # slot-level API: nothing to evict
                    raise
                self._fault_session(sess, SessionFaulted(
                    sess.sid, f"decoding step failed: {exc}", cause=exc))
                return
            root = exc
        mid = len(slots) // 2              # the full set just failed:
        bad = (self._probe_step_faults(slots[:mid], w)     # probe halves
               + self._probe_step_faults(slots[mid:], w))
        if not bad:
            # unreproducible under probes: transient — one committed
            # full-set retry, then give up to the pool quarantine
            try:
                self._step_slots(slots, w)
            except Exception:
                raise root
            return
        for s, exc in bad:
            sess = self._owner[s]
            if sess is None:          # slot-level API: nothing to evict
                raise exc
            self._fault_session(sess, SessionFaulted(
                sess.sid, f"decoding step failed: {exc}", cause=exc))
        survivors = [s for s in slots if s not in {b for b, _ in bad}]
        if survivors:
            self._step_isolated(survivors, w)

    def _probe_step_faults(self, slots, w):
        """Bisection probe: non-committing `_step_slots` replays that
        pin a gathered-step failure to its slots.  Returns
        [(slot, exc)] for every slot whose singleton replay fails."""
        try:
            self._step_slots(slots, w, commit=False)
            return []
        except Exception as exc:
            if len(slots) == 1:
                return [(slots[0], exc)]
            mid = len(slots) // 2
            return (self._probe_step_faults(slots[:mid], w)
                    + self._probe_step_faults(slots[mid:], w))

    def _step_slots(self, slots, w, commit: bool = True) -> None:
        """One fused step over exactly `slots` at window count `w`,
        committed ONLY on success: the jitted step is functional (new
        state comes back as fresh arrays), so a raise before the final
        assignments leaves pool state, sample buffers, and metrics
        exactly as they were — the invariant `_step_isolated`'s
        bisection replay depends on.  `commit=False` runs the step and
        discards the result (the isolation probe)."""
        batch, idx = self._assemble_batch(slots, w)
        b = idx.shape[0]
        if self._faults is not None:
            self._faults.check(
                "asr_step", slots=tuple(slots),
                sids=tuple(self._owner[s].sid for s in slots
                           if self._owner[s] is not None))
        # transfer-guarded: the batch/idx uploads are the ONLY intended
        # host->device traffic per step; anything implicit (a stray
        # numpy weight, a scalar readback inside dispatch) is a bug
        with no_implicit_transfers():
            if self._input_shardings is not None:
                batch_d, idx_d = jax.device_put(
                    (batch, idx), self._input_shardings)
            else:
                batch_d, idx_d = jnp.asarray(batch), jnp.asarray(idx)
            new_ss, new_beam = self._jit_step(
                self.params, self._prepared, self._stream_state, self._beam,
                batch_d, idx_d)
        if not commit:
            return
        self._stream_state, self._beam = new_ss, new_beam
        self._retire(slots, w)
        self._slot_steps[slots] += w
        self.n_steps += 1
        self.step_shapes.append((len(slots), b, w))
        self.metrics.on_step(len(slots), b)
        for s in slots:
            if self._owner[s] is not None:      # slot-level API has no owner
                self.metrics.on_first_result(self._owner[s])

    def _assemble_batch(self, slots, w):
        """Gather each eligible slot's next `w` buffered windows into a
        bucket-padded (b, w, samples_per_window) batch plus its (b,)
        slot-index vector.  Assembly is NON-destructive — the consumed
        samples are retired by `_retire` only after the fused step
        succeeds, so a faulted step can be replayed on bisected halves
        from unchanged buffers.

        Unsharded / 1D mesh: b is the smallest pow-2 slot bucket
        covering len(slots); padding duplicates row 0 (its repeated
        slot index recomputes an identical update, so the scatter-back
        stays exact).  With a 'data' mesh axis the batch is
        SHARD-ALIGNED: slots group by home shard (slot s lives on shard
        s // slots_per_shard), every shard gets the same local bucket
        `bloc` (smallest covering the largest group) so b = bloc*n_data
        is a multiple of n_data and rows [d*bloc, (d+1)*bloc) land on
        shard d under the step's P('data') in_specs; pad rows are
        zeros with index -1, which the sharded step drops on
        scatter-back (duplicate-padding would be wrong here — a shard
        with no eligible slots has no real row to duplicate)."""
        if self._data_axis is None:
            b = next(x for x in self._slot_buckets if x >= len(slots))
            batch = np.zeros((b, w, self._need), np.float32)
            for j, s in enumerate(slots):
                self._fill_row(batch, j, s, w)
            batch[len(slots):] = batch[0]  # bucket padding: duplicate rows
            idx = np.array(slots + slots[:1] * (b - len(slots)), np.int32)
            return batch, idx
        spshard = self._slots_per_shard
        groups = [[s for s in slots if s // spshard == d]
                  for d in range(self._n_data)]
        bloc = next(x for x in self._slot_buckets
                    if x >= max(len(g) for g in groups))
        batch = np.zeros((bloc * self._n_data, w, self._need), np.float32)
        idx = np.full((bloc * self._n_data,), -1, np.int32)
        for d, group in enumerate(groups):
            for j, s in enumerate(group):
                self._fill_row(batch, d * bloc + j, s, w)
                idx[d * bloc + j] = s
        return batch, idx

    def _fill_row(self, batch, row, slot, w):
        """Extract slot's next w windows into one batch row (window by
        window, exactly as w=1 steps would see them).  The slot buffer
        is NOT consumed here — see `_retire`."""
        for i in range(w):
            off = i * self._spp
            batch[row, i] = self._slot_bufs[slot][off:off + self._need]

    def _retire(self, slots, w):
        """Retire the samples a successful step consumed, keeping the
        MFCC framing overlap buffered.  Separate from `_fill_row` so a
        step that FAULTS retires nothing and the bisection retry sees
        the identical buffers."""
        for s in slots:
            self._slot_bufs[s] = self._slot_bufs[s][w * self._spp:]

    def _flush_finished_tails(self) -> None:
        """Zero-pad the trailing partial window of finished slots so the
        next fused step decodes it.  Without this, `_ready_to_close`
        dropped up to ~step_ms of tail samples (often the end of the
        last word) the moment no FULL window was buffered.  Only slots
        whose buffer holds samples never covered by a decoded frame
        (more than the retained framing overlap) are padded; padding to
        exactly one full window leaves the pure overlap after that step,
        so a flush runs at most once per session and utterances ending
        on a window boundary are untouched (bit-identical to the
        unflushed path)."""
        if not self.program.flush_tail:
            return
        for slot, sess in enumerate(self._owner):
            if sess is None or not sess.finished:
                continue
            n = self._slot_bufs[slot].shape[0]
            if n > self._overlap and not self.slot_can_step(slot):
                self._slot_bufs[slot] = np.concatenate(
                    [self._slot_bufs[slot],
                     np.zeros((self._need - n,), np.float32)])

    def pump(self) -> int:
        """Run decoding steps until no slot has a full window left."""
        n = 0
        while self._step():
            n += 1
        return n

    def slot_best(self, slot: int, final: bool = False) -> dict:
        """Best hypothesis of one slot; final=True commits a pending
        utterance-final word (pure — the stored beam is not advanced)."""
        if self._beam is None:
            return empty_hypothesis()
        fn = self._jit_best_final if final else self._jit_best
        return dec.materialize_best(fn(self._beam, slot))

    # ---- session mechanics -------------------------------------------
    def _push(self, session: Session, chunk) -> None:
        chunk = np.asarray(chunk, np.float32)
        # reject poison input BEFORE buffering: the raise reaches only
        # the pushing caller, nothing was mutated, and the session stays
        # usable for well-formed pushes
        self.program.validate_input(chunk)
        if session.admitted:
            self.feed_slot(session.slot, chunk)
        elif session._pending is None:
            session._pending = chunk
        else:
            session._pending = np.concatenate([session._pending, chunk])
        self._admit()          # fill freed slots; stepping waits for poll

    def _poll(self, session: Session) -> dict:
        self._advance()
        if session.done:
            return copy_result(session.result)
        if session.admitted:
            # slot_best materializes zero-copy views over the jitted
            # readout's device buffers: copy so the caller owns a
            # writable result (and can't see a later step through it)
            res = self.slot_best(session.slot)
            res["steps"] = int(self._slot_steps[session.slot])
            return copy_result(res)
        return self._empty_result()

    def _empty_result(self) -> dict:
        return dict(empty_hypothesis(), steps=0)

    def _admit_to_slot(self, session: Session, slot: int) -> None:
        self.reset_slot(slot)
        if session._pending is not None:
            self.feed_slot(slot, session._pending)

    def _ready_to_close(self, session: Session, slot: int) -> bool:
        if not (session.finished and not self.slot_can_step(slot)):
            return False
        # not closeable while a tail flush is pending: samples beyond
        # the framing overlap still await their zero-padded final step
        return (not self.program.flush_tail
                or self._slot_bufs[slot].shape[0] <= self._overlap)

    def _finalize_slot(self, slot: int) -> dict:
        self._ensure_state()   # finish() before any step still finalizes
        res = self.slot_best(slot, final=True)
        res["steps"] = int(self._slot_steps[slot])
        return copy_result(res)   # stored as session.result: must own it

    def _release_slot(self, slot: int) -> None:
        # eviction mid-utterance: same scrub as an utterance boundary
        self.reset_slot(slot)

    # ---- whole-utterance convenience ---------------------------------
    def serve(self, utterances) -> List[dict]:
        """Continuous batching over whole utterances (audio arrays):
        queued utterances are admitted into freed slots, one vmapped
        step advances every active slot, drained slots are finalized and
        reused.  Results come back in input order."""
        sessions = [self.open() for _ in utterances]
        for sess, audio in zip(sessions, utterances):
            sess.push(audio)       # buffers + admits only — no steps yet,
        for sess in sessions:      # so admitted slots step batched below
            sess.finish()
        assert all(sess.done for sess in sessions), sessions
        return [copy_result(sess.result) for sess in sessions]
