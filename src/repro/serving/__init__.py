"""Unified streaming serving API over the ASRPU slot pool.

Three public layers (see ROADMAP.md "Serving architecture"):
  * `Session`      — one connection: push(chunk)/poll()/finish() for ASR
                     audio, push(prompt)/poll() for LM tokens.
  * `Engine`       — owns the slot pool, admission queue, and the single
                     fused (vmapped) step: `AsrEngine` / `LmEngine`.
  * `EngineConfig` — frozen declarative spec (`AsrProgram`/`LmProgram`)
                     replacing the mutable configure_* command sequence.

The network front-end (`EngineServer` in repro.serving.server) exposes
engines over asyncio HTTP chunked streaming, with each engine's step
loop on its own `EngineWorker` thread; `EngineConfig.max_queue` turns
overload into typed `AdmissionRejected` backpressure (HTTP 503), and
`Engine.metrics` (an `EngineMetrics`) tracks first-result / finalize
latency, queue depth, and step-shape occupancy.

Fault tolerance (README "Fault tolerance"): per-session quarantine
(`SessionFaulted` / `DeadlineExceeded`, bisection isolation of poison
slots in a fused step), worker supervision (heartbeat watchdog +
restart, `WorkerDied`, `GET /healthz`), graceful drain
(`EngineServer.aclose(drain=True)`), and the deterministic
fault-injection harness (`FaultPolicy`/`FaultSpec` in
repro.serving.faults, driven by tests/test_faults.py).

The deprecated command-API shims (`ASRPU`, `MultiStreamASRPU` in
repro.core.scheduler) are thin wrappers over `AsrEngine`.
"""
from repro.serving.asr import AsrEngine
from repro.serving.config import (AsrProgram, EngineConfig, LmProgram,
                                  Program, make_engine)
from repro.serving.engine import (AdmissionRejected, DeadlineExceeded,
                                  Engine, Session, SessionFaulted,
                                  copy_result)
from repro.serving.faults import (FaultPolicy, FaultSpec, InjectedFault,
                                  WorkerKilled)
from repro.serving.lm import LmEngine
from repro.serving.metrics import EngineMetrics
from repro.serving.server import (AsrClient, EngineServer, ProtocolError,
                                  ServerRejected, WorkerDied,
                                  fetch_healthz, fetch_metrics,
                                  lm_generate)

__all__ = [
    "AdmissionRejected", "AsrClient", "AsrEngine", "AsrProgram",
    "DeadlineExceeded", "Engine", "EngineConfig", "EngineMetrics",
    "EngineServer", "FaultPolicy", "FaultSpec", "InjectedFault",
    "LmEngine", "LmProgram", "Program", "ProtocolError", "ServerRejected",
    "Session", "SessionFaulted", "WorkerDied", "WorkerKilled",
    "copy_result", "fetch_healthz", "fetch_metrics", "lm_generate",
    "make_engine",
]
