"""Unified streaming serving API over the ASRPU slot pool.

Three public layers (see ROADMAP.md "Serving architecture"):
  * `Session`      — one connection: push(chunk)/poll()/finish() for ASR
                     audio, push(prompt)/poll() for LM tokens.
  * `Engine`       — owns the slot pool, admission queue, and the single
                     fused (vmapped) step: `AsrEngine` / `LmEngine`.
  * `EngineConfig` — frozen declarative spec (`AsrProgram`/`LmProgram`)
                     replacing the mutable configure_* command sequence.

The deprecated command-API shims (`ASRPU`, `MultiStreamASRPU` in
repro.core.scheduler) are thin wrappers over `AsrEngine`.
"""
from repro.serving.asr import AsrEngine
from repro.serving.config import (AsrProgram, EngineConfig, LmProgram,
                                  Program, make_engine)
from repro.serving.engine import Engine, Session
from repro.serving.lm import LmEngine

__all__ = [
    "AsrEngine", "AsrProgram", "Engine", "EngineConfig", "LmEngine",
    "LmProgram", "Program", "Session", "make_engine",
]
