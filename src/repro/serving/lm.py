"""Batched LM serving engine: a fixed (batch, cache) slot pool.

Admission prefills requests into their slots of the pooled decode cache
through BUCKETED prefill: prompts are right-padded to the smallest
covering length bucket (`LmProgram.buckets()`) and run through ONE
masked multi-row prefill per bucket — the model reads each row's logits
at its true last token, stops recurrent state before the padding, and
returns per-row cache metadata (see `LM.prefill(lengths=...)`).  The
prefill batch is padded to the smallest covering pow-2 BATCH sub-bucket
(like the ASR step's slot buckets) instead of always `n_slots`, so
admitting one request pays a 1-row prefill, not an n_slots-row one,
while staggered admissions with arbitrary prompt lengths still compile
at most one jit entry per (length bucket, batch bucket) pair — asserted
at runtime after every prefill (the old path compiled one entry per
distinct prompt length and prefilled one request at a time).  Every engine step is one fused
`decode_step` over all slots (idle slots decode garbage that is simply
never read).  Cache position metadata is PER SLOT — `kpos` is (B, Sc)
and `offset` is (B,) — so staggered admissions with unequal prompt
lengths keep correct rotary positions and cache-write slots per stream
(the global-metadata version clobbered every stream's offset on each
admit; regression-tested in tests/test_serving.py).

Session protocol: `push(prompt)` submits the request (prefill happens at
admission); `poll()` drives the engine — admitted requests generate
their full `program.max_new` tokens, batched across slots — and returns
this session's tokens (`done=False` only while no prompt has been
pushed).  `finish()` is optional for LM sessions; finishing a session
that never pushed a prompt closes it with an empty result.
"""
from __future__ import annotations

from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import LM
from repro.analysis.guards import no_implicit_transfers
from repro.serving.config import EngineConfig, LmProgram
from repro.serving.engine import (Engine, Session, SessionFaulted,
                                 copy_result, worker_only)


class LmEngine(Engine):
    def __init__(self, config: EngineConfig, params):
        assert isinstance(config.program, LmProgram), config.program
        if config.mesh is not None:
            raise NotImplementedError(
                "EngineConfig.mesh (model-parallel serving) is wired for "
                "the ASR engine; LM serving shards through launch/steps.py "
                "build_cell instead")
        super().__init__(config)
        self.program: LmProgram = config.program
        self.lm = LM(self.program.model_cfg)
        self.params = params
        self._buckets = self.program.buckets()
        self._batch_buckets = self._make_batch_buckets()
        # sliding-window archs clamp the allocated ring to attn_window;
        # all admission-time position metadata must use the real width
        ring = self.lm.cache_len(self.program.cache_len)
        self._jit_decode = jax.jit(self.lm.decode_step)
        self._jit_prefill = jax.jit(
            lambda p, tokens, lengths: self.lm.prefill(
                p, {"tokens": tokens}, lengths=lengths, cache_len=ring))
        self._reset_pool()
        assert self._ring == ring, (self._ring, ring)

    def _make_batch_buckets(self):
        """Ascending prefill batch sizes (powers of two, topped by
        n_slots) — an admission group is padded to the smallest
        covering one, so a lone admit prefills 1 row instead of
        n_slots.  Mirrors `AsrEngine._make_slot_buckets`; the jit cache
        is bounded by len(buckets) * len(batch_buckets) entries."""
        out, b = [], 1
        while b < self.n_slots:
            out.append(b)
            b *= 2
        out.append(self.n_slots)
        return tuple(sorted(set(out)))

    def prefill_cache_entries(self) -> Optional[int]:
        """Number of compiled prefill variants (None if the jit cache
        does not expose its size) — bounded by len(program.buckets())."""
        size = getattr(self._jit_prefill, "_cache_size", None)
        return size() if callable(size) else None

    # ---- slot-pool state ---------------------------------------------
    def _reset_pool(self) -> None:
        B = self.n_slots
        self.cache = self.lm.init_cache(B, self.program.cache_len,
                                        per_slot=True)
        self._ring = int(self.cache["kpos"].shape[1])
        self._tokens = jnp.zeros((B, 1), jnp.int32)
        self._gen: List[Optional[list]] = [None] * B
        self._rem = np.zeros((B,), np.int64)

    # ---- session mechanics -------------------------------------------
    def _admittable(self, session: Session) -> bool:
        return session._pending is not None    # prompt pushed

    def _push(self, session: Session, prompt) -> None:
        if session._pending is not None or session.admitted or session.done:
            raise RuntimeError(
                f"session {session.sid}: LM sessions take one prompt")
        # validate BEFORE the int32 cast (which would mask a
        # float/garbage dtype and silently truncate) and BEFORE any
        # reshape (which would mask a matrix pushed where a token
        # vector belongs): out-of-vocab/garbage ids must never reach
        # the co-batched prefill gather
        self.program.validate_input(np.asarray(prompt))
        prompt = np.asarray(prompt, np.int32)
        session._pending = prompt
        self._admit()          # prefill now if a slot is free

    def _poll(self, session: Session) -> dict:
        self._advance()
        if session.done:
            return copy_result(session.result)
        # _advance runs admitted generation to completion and drains the
        # queue through freed slots, so the only session left un-done is
        # one whose prompt has not been pushed yet
        return {"tokens": [], "done": False}

    def _empty_result(self) -> dict:
        return {"tokens": [], "done": True}

    # ---- bucketed admission ------------------------------------------
    def _bucket(self, plen: int) -> int:
        for b in self._buckets:
            if plen <= b:
                return b
        return self._buckets[-1]   # unreachable: validate_prompt caps plen

    @worker_only
    def _admit(self) -> bool:
        """Admit every admissible queued session into the free slots,
        grouped by prompt-length bucket: one masked multi-row prefill
        per bucket (batch padded to n_slots so the jit cache stays at
        one entry per bucket)."""
        free = [s for s in range(self.n_slots) if self._owner[s] is None]
        ready = [s for s in self._queue if self._admittable(s)][:len(free)]
        if not ready:
            return False
        groups: dict = {}
        for sess, slot in zip(ready, free):
            self._queue.remove(sess)
            self._owner[slot] = sess
            sess.slot = slot
            b = self._bucket(int(sess._pending.shape[0]))
            groups.setdefault(b, []).append((sess, slot))
        for b, group in sorted(groups.items()):
            self._prefill_isolated(b, group)
        for sess in ready:
            if sess.fault is None:      # prefill isolation may have evicted
                sess._pending = None
                self.metrics.on_admit(sess)
        self.metrics.sample_queue_depth(len(self._queue))
        return True

    def _prefill_isolated(self, bucket: int, group) -> None:
        """Run one bucket's batched prefill with poison-prompt
        isolation: on failure, bisection PROBES
        (`_prefill_group(..., commit=False)`) pin the failure to its
        (session, slot) rows, only those sessions are evicted
        (`SessionFaulted`; their slots release for the next admit), and
        the healthy rest re-prefills together in one committed call —
        the same group composition a fault-free admit would run, so
        survivors see identical prefill numerics.  Replays are safe
        because probes write nothing and the committed prefill rewrites
        its group's cache rows wholesale from the still-pending
        prompts.  A failure no probe reproduces gets one committed
        full-group retry, then propagates to the pool quarantine."""
        try:
            self._prefill_group(bucket, group)
            return
        except Exception as exc:
            if len(group) == 1:
                sess, _slot = group[0]
                self._fault_session(sess, SessionFaulted(
                    sess.sid, f"prefill failed: {exc}", cause=exc))
                return
            root = exc
        mid = len(group) // 2              # the full group just failed:
        bad = (self._probe_prefill_faults(bucket, group[:mid])
               + self._probe_prefill_faults(bucket, group[mid:]))
        if not bad:
            try:
                self._prefill_group(bucket, group)
            except Exception:
                raise root
            return
        for (sess, _slot), exc in bad:
            self._fault_session(sess, SessionFaulted(
                sess.sid, f"prefill failed: {exc}", cause=exc))
        bad_sids = {sess.sid for (sess, _slot), _ in bad}
        survivors = [(s, slot) for s, slot in group
                     if s.sid not in bad_sids]
        if survivors:
            self._prefill_isolated(bucket, survivors)

    def _probe_prefill_faults(self, bucket: int, group):
        """Bisection probe: non-committing `_prefill_group` replays
        that pin a batched-prefill failure to its rows.  Returns
        [((sess, slot), exc)] for every row whose singleton replay
        fails."""
        try:
            self._prefill_group(bucket, group, commit=False)
            return []
        except Exception as exc:
            if len(group) == 1:
                return [(group[0], exc)]
            mid = len(group) // 2
            return (self._probe_prefill_faults(bucket, group[:mid])
                    + self._probe_prefill_faults(bucket, group[mid:]))

    def _admit_to_slot(self, session: Session, slot: int) -> None:
        # kept for the Engine slot-mechanics contract; the overridden
        # `_admit` batches admissions, so this is the 1-session case
        self._prefill_group(self._bucket(int(session._pending.shape[0])),
                            [(session, slot)])

    def _prefill_group(self, bucket: int, group, commit: bool = True) -> None:
        # pad to the smallest covering batch sub-bucket: jit entries ∝
        # (length buckets) x (batch buckets), and a 1-request admission
        # runs a 1-row prefill instead of n_slots rows
        if self._faults is not None:
            self._faults.check("lm_prefill",
                               sids=tuple(s.sid for s, _ in group))
        B = next(b for b in self._batch_buckets if b >= len(group))
        toks = np.zeros((B, bucket), np.int32)
        lens = np.ones((B,), np.int32)
        for i, (sess, _) in enumerate(group):
            prompt = sess._pending
            assert prompt is not None, f"session {sess.sid} pushed no prompt"
            toks[i, :prompt.shape[0]] = prompt
            lens[i] = prompt.shape[0]
        logits, pc = self._jit_prefill(self.params, jnp.asarray(toks),
                                       jnp.asarray(lens))
        if not commit:                # isolation probe: discard
            return
        # scatter the whole group at once: rows 0..G-1 of the prefill
        # cache land in the group's pool slots with ONE batched
        # advanced-index write per cache leaf (rows are ring-aligned
        # already), and one host sync reads every first token
        G = len(group)
        slots = jnp.asarray([slot for _, slot in group])

        def put(dst, src):
            return dst.at[:, slots].set(src[:, :G].astype(dst.dtype))

        self.cache["layers"] = jax.tree.map(put, self.cache["layers"],
                                            pc["layers"])
        self.cache["kpos"] = self.cache["kpos"].at[slots].set(
            pc["kpos"][:G])
        self.cache["offset"] = self.cache["offset"].at[slots].set(
            pc["offset"][:G])
        vocab = self.program.model_cfg.vocab_size
        firsts = np.asarray(jnp.argmax(logits[:G, :vocab], axis=-1),
                            np.int32)
        self._tokens = self._tokens.at[slots, 0].set(jnp.asarray(firsts))
        for i, (sess, slot) in enumerate(group):
            self._gen[slot] = [int(firsts[i])]
            self._rem[slot] = self.program.max_new - 1
            self.metrics.on_first_result(sess)
        # the padded prefill batch is one dispatch of B bucket rows
        self.metrics.on_step(len(group), B)
        entries = self.prefill_cache_entries()
        bound = len(self._buckets) * len(self._batch_buckets)
        assert entries is None or entries <= bound, (
            f"prefill jit entries {entries} exceed the "
            f"(length x batch)-bucket bound {bound}: a prefill input "
            "shape is varying outside the buckets")

    @worker_only
    def _step(self) -> bool:
        live = [s for s in range(self.n_slots)
                if self._owner[s] is not None and self._rem[s] > 0]
        if not live:
            return False
        with no_implicit_transfers():   # decode inputs live on device
            _, tok, self.cache = self._jit_decode(
                self.params, self.cache, {"tokens": self._tokens})
        self._tokens = tok[:, None]
        self.n_steps += 1
        self.metrics.on_step(len(live), self.n_slots)
        for s in live:
            self._gen[s].append(int(tok[s]))
            self._rem[s] -= 1
        return True

    def _ready_to_close(self, session: Session, slot: int) -> bool:
        return self._rem[slot] <= 0

    def _finalize_slot(self, slot: int) -> dict:
        out = {"tokens": list(self._gen[slot]), "done": True}
        self._gen[slot] = None
        return out

    def _release_slot(self, slot: int) -> None:
        # evicted mid-generation: drop the generation bookkeeping; the
        # cache rows are rewritten wholesale by the slot's next prefill
        self._gen[slot] = None
        self._rem[slot] = 0

    # ---- whole-batch convenience -------------------------------------
    def serve(self, prompts) -> List[list]:
        """Continuous batching over a list of prompts; returns the
        generated token lists in input order."""
        sessions = [self.open() for _ in prompts]
        for sess, prompt in zip(sessions, prompts):
            sess.push(prompt)      # admission/prefill only — steps batch
        results = [sess.poll() for sess in sessions]
        assert all(r["done"] for r in results), results
        return [r["tokens"] for r in results]
