"""Batched LM serving engine: a fixed (batch, cache) slot pool.

Admission prefills one request into its slot of the pooled decode cache;
every engine step is one fused `decode_step` over all slots (idle slots
decode garbage that is simply never read).  Cache position metadata is
PER SLOT — `kpos` is (B, Sc) and `offset` is (B,) — so staggered
admissions with unequal prompt lengths keep correct rotary positions and
cache-write slots per stream (the global-metadata version clobbered
every stream's offset on each admit; regression-tested in
tests/test_serving.py).

Session protocol: `push(prompt)` submits the request (prefill happens at
admission); `poll()` drives the engine — admitted requests generate
their full `program.max_new` tokens, batched across slots — and returns
this session's tokens (`done=False` only while no prompt has been
pushed).  `finish()` is optional for LM sessions; finishing a session
that never pushed a prompt closes it with an empty result.
"""
from __future__ import annotations

from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import LM
from repro.serving.config import EngineConfig, LmProgram
from repro.serving.engine import Engine, Session


class LmEngine(Engine):
    def __init__(self, config: EngineConfig, params):
        assert isinstance(config.program, LmProgram), config.program
        super().__init__(config)
        self.program: LmProgram = config.program
        self.lm = LM(self.program.model_cfg)
        self.params = params
        self._jit_decode = jax.jit(self.lm.decode_step)
        self._jit_prefill = jax.jit(self.lm.prefill)
        self._reset_pool()

    # ---- slot-pool state ---------------------------------------------
    def _reset_pool(self) -> None:
        B = self.n_slots
        self.cache = self.lm.init_cache(B, self.program.cache_len,
                                        per_slot=True)
        # sliding-window archs clamp the allocated ring to attn_window;
        # all admission-time position metadata must use the real width
        self._ring = int(self.cache["kpos"].shape[1])
        self._tokens = jnp.zeros((B, 1), jnp.int32)
        self._gen: List[Optional[list]] = [None] * B
        self._rem = np.zeros((B,), np.int64)

    # ---- session mechanics -------------------------------------------
    def _admittable(self, session: Session) -> bool:
        return session._pending is not None    # prompt pushed

    def _push(self, session: Session, prompt) -> None:
        if session._pending is not None or session.admitted or session.done:
            raise RuntimeError(
                f"session {session.sid}: LM sessions take one prompt")
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        self.program.validate_prompt(prompt.shape[0])
        session._pending = prompt
        self._admit()          # prefill now if a slot is free

    def _poll(self, session: Session) -> dict:
        self._advance()
        if session.done:
            return dict(session.result)
        # _advance runs admitted generation to completion and drains the
        # queue through freed slots, so the only session left un-done is
        # one whose prompt has not been pushed yet
        return {"tokens": [], "done": False}

    def _empty_result(self) -> dict:
        return {"tokens": [], "done": True}

    def _admit_to_slot(self, session: Session, slot: int) -> None:
        prompt = session._pending
        assert prompt is not None, f"session {session.sid} pushed no prompt"
        plen = int(prompt.shape[0])
        logits, pc = self._jit_prefill(
            self.params, {"tokens": jnp.asarray(prompt)[None]})

        # write the prompt KV / SSM state into the pooled cache slot
        def put(dst, src):
            src = src.astype(dst.dtype)
            if dst.ndim >= 3 and src.shape[2] != dst.shape[2]:
                return dst.at[:, slot:slot + 1, :src.shape[2]].set(src)
            return dst.at[:, slot:slot + 1].set(src)
        self.cache["layers"] = jax.tree.map(put, self.cache["layers"],
                                            pc["layers"])
        # per-slot position metadata: only THIS slot's row is touched.
        # A prompt longer than the SWA ring arrives trimmed from prefill
        # (last `ring` positions at indices 0..ring-1) — mirror that.
        Sc = self._ring
        eff = min(plen, Sc)
        row = jnp.full((Sc,), -1, jnp.int32).at[:eff].set(
            jnp.arange(plen - eff, plen, dtype=jnp.int32))
        self.cache["kpos"] = self.cache["kpos"].at[slot].set(row)
        self.cache["offset"] = self.cache["offset"].at[slot].set(plen)

        vocab = self.program.model_cfg.vocab_size
        first = int(jnp.argmax(logits[0, :vocab]))
        self._tokens = self._tokens.at[slot, 0].set(first)
        self._gen[slot] = [first]
        self._rem[slot] = self.program.max_new - 1

    def _step(self) -> bool:
        live = [s for s in range(self.n_slots)
                if self._owner[s] is not None and self._rem[s] > 0]
        if not live:
            return False
        _, tok, self.cache = self._jit_decode(self.params, self.cache,
                                              {"tokens": self._tokens})
        self._tokens = tok[:, None]
        self.n_steps += 1
        for s in live:
            self._gen[s].append(int(tok[s]))
            self._rem[s] -= 1
        return True

    def _ready_to_close(self, session: Session, slot: int) -> bool:
        return self._rem[slot] <= 0

    def _finalize_slot(self, slot: int) -> dict:
        out = {"tokens": list(self._gen[slot]), "done": True}
        self._gen[slot] = None
        return out

    # ---- whole-batch convenience -------------------------------------
    def serve(self, prompts) -> List[list]:
        """Continuous batching over a list of prompts; returns the
        generated token lists in input order."""
        sessions = [self.open() for _ in prompts]
        for sess, prompt in zip(sessions, prompts):
            sess.push(prompt)      # admission/prefill only — steps batch
        results = [sess.poll() for sess in sessions]
        assert all(r["done"] for r in results), results
        return [r["tokens"] for r in results]
