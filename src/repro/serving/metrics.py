"""Serving metrics: latency distributions + admission counters per engine.

One `EngineMetrics` object rides on every `Engine` (in-process and behind
the network front-end alike — the server's `GET /metrics` endpoint and a
plain `engine.metrics.snapshot()` read the same numbers).  The engine
records events at the points the SLO story cares about:

  * admission   — sessions opened / admitted / rejected (backpressure),
                  queue-wait latency, live + high-water queue depth
  * first result— time from `open()` to the first fused step that covers
                  the session's slot (ASR) or to prefill emitting the
                  first token (LM): the "first partial result exists"
                  moment a streaming client can observe
  * finalize    — time from `finish()` being signalled to the final
                  result being harvested off the slot
  * e2e         — open() -> final result, the whole-session latency
  * steps       — fused-step count and step-shape occupancy: the
                  fraction of dispatched sub-batch rows that carried a
                  real active slot (bucket padding and idle LM slots
                  burn compute without retiring work)

Latencies are held in bounded reservoirs (`LatencyStat`) so a long-lived
streaming engine does not grow without bound; percentiles are computed
over the retained window.  All hooks are O(1) appends — cheap enough for
the decode hot path.
"""
from __future__ import annotations

import time
from collections import deque
from typing import Callable, Dict, Optional

import numpy as np


class LatencyStat:
    """Bounded latency reservoir with percentile readout (seconds in,
    milliseconds out)."""

    def __init__(self, maxlen: int = 65536):
        self._v: deque = deque(maxlen=maxlen)
        self.count = 0            # total ever recorded (reservoir may drop)

    def add(self, seconds: float) -> None:
        self._v.append(float(seconds))
        self.count += 1

    def percentile_ms(self, q: float) -> Optional[float]:
        if not self._v:
            return None
        return float(np.percentile(np.fromiter(self._v, float), q)) * 1e3

    def snapshot(self) -> Dict[str, float]:
        out: Dict[str, float] = {"count": self.count}
        if self._v:
            arr = np.fromiter(self._v, float) * 1e3
            out["mean_ms"] = round(float(arr.mean()), 3)
            for q in (50, 95, 99):
                out[f"p{q}_ms"] = round(float(np.percentile(arr, q)), 3)
        return out


class EngineMetrics:
    """Event sink for one engine; see module docstring for the fields.

    `clock` is injectable for tests (defaults to `time.monotonic`).
    Session handles carry their own timestamps (`_t_open` etc.), so the
    hooks stay idempotent — recording "first result" twice for the same
    session is a no-op."""

    def __init__(self, clock: Callable[[], float] = time.monotonic):
        self._clock = clock
        self.opened = 0
        self.admitted = 0
        self.rejected = 0
        self.finalized = 0
        self.faulted_sessions = 0     # quarantined (poison input / pool)
        self.deadline_evictions = 0   # reaped past session_deadline
        self.worker_restarts = 0      # supervisor rebuilt the worker
        self.queue_depth = 0
        self.max_queue_depth = 0
        self.steps = 0
        self.stepped_slots = 0        # real active slots across all steps
        self.dispatched_rows = 0      # sub-batch rows incl. bucket padding
        self.queue_wait = LatencyStat()
        self.first_result = LatencyStat()
        self.finalize = LatencyStat()
        self.e2e = LatencyStat()

    # ---- admission ---------------------------------------------------
    def on_open(self, session) -> None:
        session._t_open = self._clock()
        self.opened += 1

    def on_reject(self) -> None:
        self.rejected += 1

    def on_admit(self, session) -> None:
        t = self._clock()
        session._t_admit = t
        self.admitted += 1
        if session._t_open is not None:
            self.queue_wait.add(t - session._t_open)

    def sample_queue_depth(self, depth: int) -> None:
        self.queue_depth = depth
        if depth > self.max_queue_depth:
            self.max_queue_depth = depth

    # ---- progress ----------------------------------------------------
    def on_step(self, n_active: int, n_rows: int) -> None:
        """One fused step advanced `n_active` real slots through a
        dispatch shaped for `n_rows` sub-batch rows."""
        self.steps += 1
        self.stepped_slots += n_active
        self.dispatched_rows += n_rows

    def on_first_result(self, session) -> None:
        if session._t_first is not None or session._t_open is None:
            return
        t = self._clock()
        session._t_first = t
        self.first_result.add(t - session._t_open)

    def on_finish(self, session) -> None:
        if session._t_finish is None:
            session._t_finish = self._clock()

    def on_done(self, session) -> None:
        t = self._clock()
        self.finalized += 1
        if session._t_open is not None:
            self.e2e.add(t - session._t_open)
        if session._t_finish is not None:
            self.finalize.add(t - session._t_finish)

    # ---- faults ------------------------------------------------------
    def on_fault(self, session) -> None:
        """Session evicted with a typed `SessionFaulted` (poison input,
        failed prefill, or whole-pool quarantine)."""
        self.faulted_sessions += 1

    def on_deadline(self, session) -> None:
        """Session reaped past `EngineConfig.session_deadline`."""
        self.deadline_evictions += 1

    def on_worker_restart(self) -> None:
        """The supervisor detected a dead/wedged `EngineWorker` and
        rebuilt it (called from the event loop: a dead worker cannot
        record its own death)."""
        self.worker_restarts += 1

    # ---- readout -----------------------------------------------------
    def occupancy(self) -> Optional[float]:
        """Fraction of dispatched sub-batch rows holding a real active
        slot (1.0 = every step ran exactly full)."""
        if not self.dispatched_rows:
            return None
        return self.stepped_slots / self.dispatched_rows

    def snapshot(self) -> dict:
        occ = self.occupancy()
        return {
            "sessions": {
                "opened": self.opened, "admitted": self.admitted,
                "rejected": self.rejected, "finalized": self.finalized,
                "faulted": self.faulted_sessions,
                "deadline_evicted": self.deadline_evictions,
            },
            "workers": {"restarts": self.worker_restarts},
            "queue": {
                "depth": self.queue_depth,
                "max_depth": self.max_queue_depth,
            },
            "steps": {
                "count": self.steps,
                "stepped_slots": self.stepped_slots,
                "dispatched_rows": self.dispatched_rows,
                "occupancy": None if occ is None else round(occ, 4),
            },
            "latency": {
                "queue_wait": self.queue_wait.snapshot(),
                "first_result": self.first_result.snapshot(),
                "finalize": self.finalize.snapshot(),
                "e2e": self.e2e.snapshot(),
            },
        }
