"""Deterministic fault injection for the serving stack.

A `FaultPolicy` is a list of `FaultSpec`s armed at named injection
*sites* the serving code consults at its hazard points
(`policy.check(site, **ctx)`):

  * ``"asr_step"``    — inside `AsrEngine._step_slots`, after batch
                        assembly and before the jit step commits; ctx
                        carries ``slots`` and ``sids`` of the gathered
                        sub-batch.
  * ``"lm_prefill"``  — inside `LmEngine._prefill_group`; ctx carries
                        the ``sids`` being prefilled.
  * ``"pump"``        — top of `EngineWorker._pump`, once per pump
                        iteration; the place to simulate a dying or
                        wedged worker thread.

Determinism contract: every decision is a pure function of the
per-site invocation counter (`nth`/`count`) and the injected context
(`match`) — never of wall-clock time or a global RNG — so a chaos test
replays identically and a bisected retry sees the same world minus the
spent injection.  Specs with ``count`` fire a bounded number of times
and then disarm, which is what lets quarantine tests observe recovery.

Actions:

  * ``"raise"`` — raise `InjectedFault` (an ordinary `Exception`): the
    quarantine machinery must contain it.
  * ``"die"``   — raise `WorkerKilled` (a `BaseException`): models the
    worker thread dying for reasons quarantine cannot contain (segfault
    stand-in); only the supervisor may recover from it.
  * ``"stall"`` — block on an event until `release()` (bounded by
    ``stall_timeout`` so a broken test cannot hang the suite): models a
    wedged worker the heartbeat watchdog must notice.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional


class InjectedFault(RuntimeError):
    """Raised by a ``"raise"`` fault spec: a synthetic per-step failure
    the quarantine machinery is expected to contain."""


class WorkerKilled(BaseException):
    """Raised by a ``"die"`` fault spec.  Deliberately NOT an
    `Exception` subclass: it escapes the engine's per-pump quarantine
    (`except Exception`) exactly like a real thread-killing failure
    would, so only the worker supervisor can observe and recover it."""


@dataclass
class FaultSpec:
    """One armed fault.

    site     injection-site name (see module docstring)
    action   "raise" | "die" | "stall"
    nth      fire starting at the nth *matching* check of this site
             (0-based over matching invocations)
    count    how many matching checks fire after `nth` (None = forever)
    match    optional predicate over the site's context kwargs; a check
             whose ctx does not match neither fires nor advances `nth`
    message  text carried by the raised InjectedFault/WorkerKilled
    """
    site: str
    action: str = "raise"
    nth: int = 0
    count: Optional[int] = 1
    match: Optional[Callable[[dict], bool]] = None
    message: str = "injected fault"
    _seen: int = field(default=0, repr=False)
    _fired: int = field(default=0, repr=False)

    def __post_init__(self):
        if self.action not in ("raise", "die", "stall"):
            raise ValueError(f"unknown fault action {self.action!r}")

    def should_fire(self, ctx: dict) -> bool:
        if self.match is not None and not self.match(ctx):
            return False
        seen = self._seen
        self._seen += 1
        if seen < self.nth:
            return False
        if self.count is not None and self._fired >= self.count:
            return False
        self._fired += 1
        return True


class FaultPolicy:
    """Armed fault specs + per-site counters + an injection log.

    Thread-safety: `check` is called from the engine-worker thread while
    tests `release()` stalls and read `log` from the main thread; a lock
    guards the counters and the log list (entries are appended once,
    never mutated)."""

    def __init__(self, specs: List[FaultSpec],
                 stall_timeout: float = 30.0):
        self.specs = list(specs)
        self.stall_timeout = stall_timeout
        self.log: List[dict] = []
        self._counters: Dict[str, int] = {}
        self._stall = threading.Event()
        self._lock = threading.Lock()

    def release(self) -> None:
        """Unblock every current and future ``"stall"`` injection."""
        self._stall.set()

    def check(self, site: str, **ctx) -> None:
        """Consult the policy at an injection site.  Raises / stalls if
        an armed spec fires; otherwise returns immediately (the no-op
        cost is one dict lookup, so production code may leave the hook
        wired unconditionally when no policy is configured)."""
        with self._lock:
            self._counters[site] = self._counters.get(site, 0) + 1
            spec = next((s for s in self.specs
                         if s.site == site and s.should_fire(ctx)), None)
            if spec is None:
                return
            self.log.append({
                "site": site, "action": spec.action,
                "invocation": self._counters[site] - 1,
                "ctx": {k: v for k, v in ctx.items()
                        if isinstance(v, (int, float, str, bool, tuple,
                                          list))},
            })
        if spec.action == "stall":
            # wait OUTSIDE the lock: release() and log readers must not
            # deadlock against a stalled worker
            self._stall.wait(self.stall_timeout)
            return
        if spec.action == "die":
            raise WorkerKilled(spec.message)
        raise InjectedFault(spec.message)
