"""Mamba-2 (SSD, state-space duality) block — chunked train/prefill + O(1) decode.

Implements the chunked SSD algorithm of arXiv:2405.21060 §6 in pure JAX
(einsums over (chunk x chunk) decay matrices + an inter-chunk state scan).
Decode is the exact linear recurrence:  h <- h*exp(dt*A) + dt * B x ;
y = C.h + D*x.  Correctness of the chunked path against the step
recurrence is property-tested in tests/test_mamba.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import SSMSpec
from repro.models import layers


def init_mamba(key, d_model: int, spec: SSMSpec, dtype=jnp.bfloat16) -> dict:
    di = spec.d_inner(d_model)
    nh = spec.n_heads(d_model)
    gn = spec.ngroups * spec.d_state
    ks = jax.random.split(key, 8)
    return {
        "w_z": layers.init_linear(ks[0], d_model, di, dtype=dtype),
        "w_x": layers.init_linear(ks[1], d_model, di, dtype=dtype),
        "w_B": layers.init_linear(ks[2], d_model, gn, dtype=dtype),
        "w_C": layers.init_linear(ks[3], d_model, gn, dtype=dtype),
        "w_dt": layers.init_linear(ks[4], d_model, nh, dtype=dtype),
        "conv_x": {"w": (jax.random.normal(ks[5], (spec.conv_kernel, di),
                                           jnp.float32) * 0.1).astype(dtype),
                   "b": jnp.zeros((di,), dtype)},
        "A_log": jnp.zeros((nh,), jnp.float32),          # A = -exp(A_log) = -1
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm_gate": {"scale": jnp.ones((di,), jnp.float32)},
        "out_proj": layers.init_linear(ks[6], di, d_model, dtype=dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: jax.Array | None = None,
                 lengths: jax.Array | None = None):
    """Depthwise causal conv over time. x: (B,S,C), w: (ck,C).

    Returns (y, new_state) with new_state = last ck-1 inputs.
    Implemented as ck shifted adds (ck is 4) — cheap and fusion-friendly.
    With `lengths` (B,), row b's trailing x[b, lengths[b]:] is right-
    padding: new_state becomes the last ck-1 inputs BEFORE the padding
    (causality already keeps pad inputs out of the real outputs).
    """
    ck = w.shape[0]
    B, S, C = x.shape
    if state is None:
        state = jnp.zeros((B, ck - 1, C), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)                 # (B, S+ck-1, C)
    y = sum(xp[:, i:i + S, :] * w[i][None, None, :] for i in range(ck))
    y = jax.nn.silu(y + b[None, None, :])
    if lengths is not None:
        # xp row j holds input position j - (ck-1); the state after
        # position len-1 is xp rows len .. len+ck-2
        rows = lengths[:, None] + jnp.arange(ck - 1)[None, :]    # (B, ck-1)
        new_state = jnp.take_along_axis(xp, rows[:, :, None], axis=1)
    elif S >= ck - 1:
        new_state = xp[:, S:, :]
    else:
        new_state = xp[:, -(ck - 1):, :]
    return y, new_state


def _segsum(a: jax.Array) -> jax.Array:
    """a: (..., L). Returns (..., L, L) with out[i,j] = sum_{j<k<=i} a_k (i>=j)."""
    c = jnp.cumsum(a, axis=-1)
    out = c[..., :, None] - c[..., None, :]
    L = a.shape[-1]
    mask = jnp.tril(jnp.ones((L, L), bool))
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(x, dt, A, Bm, Cm, chunk: int, h0=None):
    """Chunked SSD scan.

    x: (B,S,H,P)  dt: (B,S,H) (post-softplus)  A: (H,) (negative)
    Bm, Cm: (B,S,G,N) with G | H.  h0: optional (B,H,P,N) initial state.
    Returns y: (B,S,H,P), h_final: (B,H,P,N).
    """
    B, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    L = min(chunk, S)
    assert S % L == 0, (S, L)
    nc = S // L

    # One lax.scan over chunks: only ONE (B,H,L,L) decay matrix is live at a
    # time (materializing all nc of them is O(S*L) memory and blew HBM on
    # jamba/mamba2 trains).  The body is checkpointed: backward recomputes
    # the chunk-local tensors instead of saving them as scan residuals.
    xr = x.reshape(B, nc, L, H, P).transpose(1, 0, 2, 3, 4)
    dtr = dt.reshape(B, nc, L, H).transpose(1, 0, 2, 3).astype(jnp.float32)
    Br = Bm.reshape(B, nc, L, G, N).transpose(1, 0, 2, 3, 4)
    Cr = Cm.reshape(B, nc, L, G, N).transpose(1, 0, 2, 3, 4)

    @jax.checkpoint
    def chunk_step(h, xs):
        xc, dtc, bc, cc = xs            # (B,L,H,P),(B,L,H),(B,L,G,N),(B,L,G,N)
        xc = xc.astype(jnp.float32)
        bc = jnp.repeat(bc, rep, axis=2).astype(jnp.float32)   # (B,L,H,N)
        cc = jnp.repeat(cc, rep, axis=2).astype(jnp.float32)
        dA = dtc * A[None, None, :]                            # (B,L,H) <= 0
        dAc = jnp.cumsum(dA, axis=1)
        Lmat = jnp.exp(_segsum(dA.transpose(0, 2, 1)))         # (B,H,L,L)
        CB = jnp.einsum("blhn,bshn->bhls", cc, bc)             # (B,H,L,L)
        y = jnp.einsum("bhls,bsh,bshp->blhp", CB * Lmat, dtc, xc)
        # contribution of carried state + new carried state
        state_decay = jnp.exp(dAc)                             # (B,L,H)
        y = y + jnp.einsum("blhn,blh,bhpn->blhp", cc, state_decay, h)
        in_decay = jnp.exp(dAc[:, -1:, :] - dAc)               # (B,L,H)
        states = jnp.einsum("blhn,blh,blh,blhp->bhpn", bc, in_decay, dtc, xc)
        h_new = h * jnp.exp(dAc[:, -1, :])[:, :, None, None] + states
        return h_new, y

    if h0 is None:
        h0 = jnp.zeros((B, H, P, N), jnp.float32)
    hT, ys = lax.scan(chunk_step, h0.astype(jnp.float32), (xr, dtr, Br, Cr))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S, H, P)
    return y, hT


def init_cache(batch: int, d_model: int, spec: SSMSpec, dtype=jnp.bfloat16):
    di = spec.d_inner(d_model)
    nh = spec.n_heads(d_model)
    return {
        "conv": jnp.zeros((batch, spec.conv_kernel - 1, di), dtype),
        "ssm": jnp.zeros((batch, nh, spec.head_dim, spec.d_state), jnp.float32),
    }


def apply_mamba(p: dict, x: jax.Array, spec: SSMSpec, cache=None,
                sharder=None, lengths=None):
    """x: (B,S,D). cache: optional {'conv','ssm'} for decode/streaming.

    Returns (y, new_cache). S==1 with cache uses the exact step recurrence.
    `lengths` (B,) marks x[b, lengths[b]:] as right-padding (bucketed
    prefill): dt is zeroed there — the SSD recurrence then carries the
    state through pad positions untouched (decay exp(0)=1, update 0) —
    and the conv state is taken before the padding, so the returned
    cache equals an unpadded prefill's bit-for-bit in structure.
    Mamba is natural TP over d_inner: the depthwise conv and per-head SSD
    never mix heads until out_proj, so activations are constrained
    head-sharded over 'model' (one all-reduce per layer, at out_proj).
    """
    if sharder is None:
        from repro.parallel.sharding import Sharder
        sharder = Sharder(None)
    B, S, D = x.shape
    nh = spec.n_heads(D)
    P = spec.head_dim
    N = spec.d_state
    G = spec.ngroups
    A = -jnp.exp(p["A_log"])
    z = sharder.inner(layers.linear(p["w_z"], x))             # (B,S,di)
    xi = sharder.inner(layers.linear(p["w_x"], x))
    dt = jax.nn.softplus(layers.linear(p["w_dt"], x).astype(jnp.float32)
                         + p["dt_bias"])                      # (B,S,nh)
    if lengths is not None:
        pad = jnp.arange(S)[None, :] >= lengths[:, None]      # (B, S)
        dt = jnp.where(pad[:, :, None], 0.0, dt)

    conv_state = cache["conv"] if cache is not None else None
    xi, new_conv = _causal_conv(xi, p["conv_x"]["w"], p["conv_x"]["b"],
                                conv_state, lengths=lengths)
    xi = sharder.inner(xi)
    Bm = layers.linear(p["w_B"], x).reshape(B, S, G, N)
    Cm = layers.linear(p["w_C"], x).reshape(B, S, G, N)
    xh = sharder.heads(xi.reshape(B, S, nh, P))

    if S == 1 and cache is not None:
        # exact single-step recurrence
        h = cache["ssm"]                                      # (B,nh,P,N) fp32
        dt1 = dt[:, 0]                                        # (B,nh)
        dec = jnp.exp(dt1 * A[None, :])                       # (B,nh)
        Bf = jnp.repeat(Bm[:, 0], nh // G, axis=1).astype(jnp.float32)  # (B,nh,N)
        Cf = jnp.repeat(Cm[:, 0], nh // G, axis=1).astype(jnp.float32)
        xf = xh[:, 0].astype(jnp.float32)                     # (B,nh,P)
        h_new = (h * dec[:, :, None, None]
                 + jnp.einsum("bh,bhp,bhn->bhpn", dt1, xf, Bf))
        y = jnp.einsum("bhpn,bhn->bhp", h_new, Cf)
        y = y + p["D"][None, :, None] * xf
        y = y.reshape(B, 1, nh * P).astype(x.dtype)
        new_cache = {"conv": new_conv, "ssm": h_new}
    else:
        h0 = cache["ssm"] if cache is not None else None
        y, hT = ssd_chunked(xh, dt, A, Bm, Cm, spec.chunk_size, h0)
        y = sharder.heads(y) + p["D"][None, None, :, None] * xh.astype(jnp.float32)
        y = sharder.inner(y.reshape(B, S, nh * P).astype(x.dtype))
        new_cache = {"conv": new_conv, "ssm": hT}

    # gated RMSNorm then output projection (mamba2's RMSNormGated);
    # keep the gated product d_inner-sharded so GSPMD doesn't rebuild
    # full-(S, d_inner) f32 buffers around the norm
    y = sharder.inner(y * jax.nn.silu(z))
    y = sharder.inner(layers.apply_norm(p["norm_gate"], y, "rmsnorm"))
    return layers.linear(p["out_proj"], y), new_cache
