"""TDS acoustic model (paper §4.2) as an explicit ASRPU kernel sequence.

The paper implements the wav2letter TDS network as a sequence of 79
kernels: 18 CONV, 29 FC, 32 LayerNorm (each with its setup thread).  This
module builds exactly that kernel list — the list is both the executable
model (offline + streaming, causal convs with carried left context) and
the artifact the evaluation reproduces (Fig. 9 layer sizes, Fig. 11
per-kernel times via the instruction-count model).

Views follow TDS: activations are (T, w, c) "2-D" maps; convs are
time-only (kernel k x 1) with full c x c channel mixing; FC blocks operate
on the flattened (w*c) vector.  All convs are causal so streaming
decoding steps produce bit-identical outputs to offline decoding
(property-tested).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.tds_asr import TDSConfig
from repro.core import treeutil


@dataclass(frozen=True)
class KernelSpec:
    """One ASRPU kernel (paper §3.1): name, kind, and the setup-thread
    metadata needed by the controller and the performance model."""
    name: str
    kind: str              # conv | fc | layernorm | head
    n_in: int              # inputs per output neuron (MACs) — 0 for LN
    n_out: int             # neurons == kernel threads per output frame
    kernel: int = 1        # time-kernel width (convs)
    stride: int = 1
    weight_bytes: int = 0  # int8 weight footprint (model-memory residency)
    residual: bool = False
    activation: str = "none"   # relu | none

    @property
    def n_subkernels(self) -> int:
        """FC layers are partitioned into <=1MB sub-kernels (paper §5.2)."""
        limit = 1 << 20
        return max(1, -(-self.weight_bytes // limit))


def build_kernel_specs(cfg: TDSConfig) -> List[KernelSpec]:
    specs: List[KernelSpec] = []
    w = cfg.stages[0].feat
    c_prev = 1
    c0 = cfg.stages[0].channels
    # front conv (stride 1)
    specs.append(KernelSpec("front_conv", "conv", n_in=cfg.stages[0].kernel * c_prev,
                            n_out=w * c0, kernel=cfg.stages[0].kernel,
                            weight_bytes=cfg.stages[0].kernel * c_prev * c0,
                            activation="relu"))
    c_prev = c0
    for si, st in enumerate(cfg.stages):
        # stage-entry subsampling conv + LN
        specs.append(KernelSpec(
            f"s{si}_subsample", "conv", n_in=cfg.sub_kernel * c_prev,
            n_out=w * st.channels, kernel=cfg.sub_kernel, stride=st.subsample,
            weight_bytes=cfg.sub_kernel * c_prev * st.channels,
            activation="relu"))
        specs.append(KernelSpec(f"s{si}_sub_ln", "layernorm", 0,
                                w * st.channels))
        width = w * st.channels
        for b in range(st.n_blocks):
            specs.append(KernelSpec(
                f"s{si}b{b}_conv", "conv", n_in=st.kernel * st.channels,
                n_out=width, kernel=st.kernel,
                weight_bytes=st.kernel * st.channels * st.channels,
                residual=True, activation="relu"))
            specs.append(KernelSpec(f"s{si}b{b}_ln1", "layernorm", 0, width))
            specs.append(KernelSpec(
                f"s{si}b{b}_fc1", "fc", n_in=width, n_out=width,
                weight_bytes=width * width, activation="relu"))
            specs.append(KernelSpec(
                f"s{si}b{b}_fc2", "fc", n_in=width, n_out=width,
                weight_bytes=width * width, residual=True))
            specs.append(KernelSpec(f"s{si}b{b}_ln2", "layernorm", 0, width))
        c_prev = st.channels
    width = w * cfg.stages[-1].channels
    specs.append(KernelSpec("final_ln", "layernorm", 0, width))
    specs.append(KernelSpec("head", "fc", n_in=width, n_out=cfg.vocab_size,
                            weight_bytes=width * cfg.vocab_size))
    return specs


def kernel_census(cfg: TDSConfig) -> dict:
    specs = build_kernel_specs(cfg)
    return {
        "conv": sum(s.kind == "conv" for s in specs),
        "fc": sum(s.kind in ("fc", "head") for s in specs),
        "layernorm": sum(s.kind == "layernorm" for s in specs),
    }


# ---------------------------------------------------------------------------
# parameters + forward
# ---------------------------------------------------------------------------
def init_tds(key, cfg: TDSConfig, dtype=jnp.float32) -> dict:
    params = {}
    for spec in build_kernel_specs(cfg):
        key, k = jax.random.split(key)
        if spec.kind == "layernorm":
            params[spec.name] = {"scale": jnp.ones((spec.n_out,), jnp.float32),
                                 "bias": jnp.zeros((spec.n_out,), jnp.float32)}
        elif spec.kind == "conv":
            c_out = spec.n_out // cfg.stages[0].feat
            c_in = spec.n_in // spec.kernel
            std = 1.0 / math.sqrt(spec.n_in)
            params[spec.name] = {
                "w": (jax.random.normal(k, (spec.kernel, c_in, c_out),
                                        jnp.float32) * std).astype(dtype),
                "b": jnp.zeros((c_out,), dtype)}
        else:
            std = 1.0 / math.sqrt(spec.n_in)
            params[spec.name] = {
                "w": (jax.random.normal(k, (spec.n_in, spec.n_out),
                                        jnp.float32) * std).astype(dtype),
                "b": jnp.zeros((spec.n_out,), dtype)}
    return params


def init_stream_state(cfg: TDSConfig) -> dict:
    """Left-context ring buffers — the scratchpad the paper keeps in the
    512KB shared memory between decoding steps (~275KB; see DESIGN.md)."""
    state = {}
    w = cfg.stages[0].feat
    for spec in build_kernel_specs(cfg):
        if spec.kind == "conv":
            c_in = spec.n_in // spec.kernel
            state[spec.name] = jnp.zeros((spec.kernel - 1, w, c_in),
                                         jnp.float32)
    return state


def init_batched_stream_state(cfg: TDSConfig, batch: int) -> dict:
    """Stream state for `batch` concurrent utterances: (B, k-1, w, c_in)
    per conv — the per-slot left context of a multi-stream slot pool."""
    return treeutil.batch_tree(init_stream_state(cfg), batch)


def reset_stream_slot(state: dict, slot, cfg: TDSConfig) -> dict:
    """Zero one slot's left context (utterance boundary in that stream)."""
    return treeutil.set_slot(state, slot, init_stream_state(cfg))


def state_bytes(cfg: TDSConfig, bytes_per_el: int = 1) -> int:
    st = init_stream_state(cfg)
    return sum(int(np.prod(a.shape)) * bytes_per_el
               for a in jax.tree.leaves(st))


def quantize_params(params, cfg: TDSConfig) -> dict:
    """Pre-quantize every FC/head weight matrix ONCE (int8 + per-output
    scales): {kernel name: {"wq", "ws"}}.  The serving engine builds
    this at engine-construction time so the decode hot path only ever
    quantizes activations (`ops.int8_matmul_prepared`) instead of
    re-quantizing static weights on every decoding step."""
    from repro.kernels import ops
    prepared = {}
    for spec in build_kernel_specs(cfg):
        if spec.kind in ("fc", "head"):
            wq, ws = ops.prepare_int8_weights(params[spec.name]["w"])
            prepared[spec.name] = {"wq": wq, "ws": ws}
    return prepared


def forward_batched(params, cfg: TDSConfig, feats: jax.Array, state: dict,
                    use_int8: bool = False, kernels=None,
                    prepared: Optional[dict] = None,
                    axis: Optional[str] = None,
                    overlap: bool = False):
    """Slot-native TDS forward.  feats: (B, T, n_mfcc); state: the
    batched stream state ((B, k-1, w, c_in) per conv).  Returns
    (log_probs (B, T', V), new_state).

    The slot axis is folded into the row dimension of every matmul —
    (B*T, w*c) rows for FC/head/LayerNorm, (B*T*w, c_in) rows for each
    conv tap — so the MXU sees ONE large matmul per kernel instead of B
    independent small ones (the old path vmapped the whole forward per
    slot).  Convs, LayerNorms, and the int8 FC path dispatch through
    `kernels` (a KernelPolicy) as hot-path ops: pure-jnp ref on CPU,
    the Pallas kernels (conv epilogue fused: bias+ReLU+residual) under
    interpret/Mosaic.  `prepared` (from `quantize_params`) supplies
    pre-quantized int8 weights; without it the use_int8 path quantizes
    weights on the fly (offline/one-shot use).

    `axis` names the shard_map mesh axis this forward runs under (the
    serving engine's model-parallel step).  FC/head weights then arrive
    as feature-axis shards — (K/n_model, N) per device — and the
    contraction becomes a local partial matmul + psum over `axis`; the
    B*T row fold, convs, and LayerNorms are untouched (replicated).
    Activations stay replicated, so only the weight reads are split.
    Weights left whole (non-divisible feature dim) are detected by
    shape and contract locally, bit-identical to axis=None.

    `overlap` (sharded path only) routes each contraction through
    `ops.psum_overlap_matmul`'s output-column split so layer l's
    all-reduce chunks hide under the matmuls still being issued —
    numerically ~1e-6-equal to the synchronous reference, which stays
    the parity path (see `psum_overlap_matmul`).
    """
    from repro.kernels import ops

    specs = build_kernel_specs(cfg)
    new_state = dict(state)
    w = cfg.stages[0].feat
    B = feats.shape[0]
    x = feats[:, :, :, None]                         # (B, T, w, 1)

    def matmul(xm, name, p):
        if use_int8:
            if prepared is not None and name in prepared:
                pq = prepared[name]
                return ops.int8_matmul_prepared(xm, pq["wq"], pq["ws"],
                                                policy=kernels, hot=True,
                                                axis=axis,
                                                overlap=overlap) + p["b"]
            return ops.int8_matmul(xm, p["w"], policy=kernels,
                                   hot=True) + p["b"]
        wm = p["w"]
        if axis is not None and wm.shape[0] != xm.shape[1]:
            # model-parallel contraction: slice the activation columns
            # matching this device's weight shard, contract locally,
            # all-reduce the partial sums; bias added post-reduction
            xloc = ops.shard_local_cols(xm, wm.shape[0], axis)
            if overlap:
                return ops.psum_overlap_matmul(xloc, wm, axis) + p["b"]
            return jax.lax.psum(xloc @ wm, axis) + p["b"]
        return xm @ wm + p["b"]

    for spec in specs:
        p = params[spec.name]
        if spec.kind == "conv":
            k, s = spec.kernel, spec.stride
            m = x.shape[1]
            assert m % s == 0, (m, s)
            xp = jnp.concatenate([state[spec.name], x], axis=1)
            res = x if (spec.residual and s == 1
                        and x.shape[-1] == spec.n_out // w) else None
            x = ops.tds_conv(xp, p["w"], p["b"], stride=s,
                             relu=spec.activation == "relu", res=res,
                             policy=kernels, hot=True)
            new_state[spec.name] = xp[:, -(k - 1):] if k > 1 \
                else state[spec.name]
        elif spec.kind == "layernorm":
            t = x.shape[1]
            xm = ops.layernorm(x.reshape(B * t, -1), p["scale"], p["bias"],
                               policy=kernels, hot=True)
            x = xm.reshape(x.shape)
        else:  # fc / head
            t = x.shape[1]
            xm = x.reshape(B * t, -1)
            if spec.activation == "relu":      # fc1: start of the FC block
                fc_res = xm
            y = matmul(xm, spec.name, p)
            if spec.activation == "relu":
                y = jax.nn.relu(y)
            if spec.residual and y.shape == fc_res.shape:
                y = y + fc_res                 # TDS residual: whole FC block
            if spec.name == "head":
                logp = jax.nn.log_softmax(y, axis=-1)
                return logp.reshape(B, t, -1), new_state
            c = spec.n_out // w
            x = y.reshape(B, t, w, c)
    raise AssertionError("head kernel missing")


def forward(params, cfg: TDSConfig, feats: jax.Array,
            state: Optional[dict] = None, use_int8: bool = False,
            kernels=None, prepared: Optional[dict] = None,
            axis: Optional[str] = None):
    """feats: (T, n_mfcc). Returns (log_probs (T', V), new_state).

    state=None => offline (zero left context).  T must be divisible by the
    total subsample.  use_int8 routes FC/head matmuls through the int8
    quantized path — ASRPU's 8-bit MAC (`prepared` from
    `quantize_params` skips the per-call weight quantization); `kernels`
    is the KernelPolicy dispatching the Pallas-backed ops (None = auto).

    This is exactly the B=1 slice of `forward_batched` — single-stream
    and slot-pooled decoding share ONE code path, which is what keeps
    the streaming-vs-offline and multi-stream parity tests bit-honest.
    """
    st_in = state if state is not None else init_stream_state(cfg)
    bst = jax.tree.map(lambda a: a[None], st_in)
    logp, ns = forward_batched(params, cfg, feats[None], bst,
                               use_int8=use_int8, kernels=kernels,
                               prepared=prepared, axis=axis)
    return logp[0], jax.tree.map(lambda a: a[0], ns)
