"""TDS acoustic model (paper §4.2) as an explicit ASRPU kernel sequence.

The paper implements the wav2letter TDS network as a sequence of 79
kernels: 18 CONV, 29 FC, 32 LayerNorm (each with its setup thread).  This
module builds exactly that kernel list — the list is both the executable
model (offline + streaming, causal convs with carried left context) and
the artifact the evaluation reproduces (Fig. 9 layer sizes, Fig. 11
per-kernel times via the instruction-count model).

Views follow TDS: activations are (T, w, c) "2-D" maps; convs are
time-only (kernel k x 1) with full c x c channel mixing; FC blocks operate
on the flattened (w*c) vector.  All convs are causal so streaming
decoding steps produce bit-identical outputs to offline decoding
(property-tested).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.tds_asr import TDSConfig
from repro.core import treeutil


@dataclass(frozen=True)
class KernelSpec:
    """One ASRPU kernel (paper §3.1): name, kind, and the setup-thread
    metadata needed by the controller and the performance model."""
    name: str
    kind: str              # conv | fc | layernorm | head
    n_in: int              # inputs per output neuron (MACs) — 0 for LN
    n_out: int             # neurons == kernel threads per output frame
    kernel: int = 1        # time-kernel width (convs)
    stride: int = 1
    weight_bytes: int = 0  # int8 weight footprint (model-memory residency)
    residual: bool = False
    activation: str = "none"   # relu | none

    @property
    def n_subkernels(self) -> int:
        """FC layers are partitioned into <=1MB sub-kernels (paper §5.2)."""
        limit = 1 << 20
        return max(1, -(-self.weight_bytes // limit))


def build_kernel_specs(cfg: TDSConfig) -> List[KernelSpec]:
    specs: List[KernelSpec] = []
    w = cfg.stages[0].feat
    c_prev = 1
    c0 = cfg.stages[0].channels
    # front conv (stride 1)
    specs.append(KernelSpec("front_conv", "conv", n_in=cfg.stages[0].kernel * c_prev,
                            n_out=w * c0, kernel=cfg.stages[0].kernel,
                            weight_bytes=cfg.stages[0].kernel * c_prev * c0,
                            activation="relu"))
    c_prev = c0
    for si, st in enumerate(cfg.stages):
        # stage-entry subsampling conv + LN
        specs.append(KernelSpec(
            f"s{si}_subsample", "conv", n_in=cfg.sub_kernel * c_prev,
            n_out=w * st.channels, kernel=cfg.sub_kernel, stride=st.subsample,
            weight_bytes=cfg.sub_kernel * c_prev * st.channels,
            activation="relu"))
        specs.append(KernelSpec(f"s{si}_sub_ln", "layernorm", 0,
                                w * st.channels))
        width = w * st.channels
        for b in range(st.n_blocks):
            specs.append(KernelSpec(
                f"s{si}b{b}_conv", "conv", n_in=st.kernel * st.channels,
                n_out=width, kernel=st.kernel,
                weight_bytes=st.kernel * st.channels * st.channels,
                residual=True, activation="relu"))
            specs.append(KernelSpec(f"s{si}b{b}_ln1", "layernorm", 0, width))
            specs.append(KernelSpec(
                f"s{si}b{b}_fc1", "fc", n_in=width, n_out=width,
                weight_bytes=width * width, activation="relu"))
            specs.append(KernelSpec(
                f"s{si}b{b}_fc2", "fc", n_in=width, n_out=width,
                weight_bytes=width * width, residual=True))
            specs.append(KernelSpec(f"s{si}b{b}_ln2", "layernorm", 0, width))
        c_prev = st.channels
    width = w * cfg.stages[-1].channels
    specs.append(KernelSpec("final_ln", "layernorm", 0, width))
    specs.append(KernelSpec("head", "fc", n_in=width, n_out=cfg.vocab_size,
                            weight_bytes=width * cfg.vocab_size))
    return specs


def kernel_census(cfg: TDSConfig) -> dict:
    specs = build_kernel_specs(cfg)
    return {
        "conv": sum(s.kind == "conv" for s in specs),
        "fc": sum(s.kind in ("fc", "head") for s in specs),
        "layernorm": sum(s.kind == "layernorm" for s in specs),
    }


# ---------------------------------------------------------------------------
# parameters + forward
# ---------------------------------------------------------------------------
def init_tds(key, cfg: TDSConfig, dtype=jnp.float32) -> dict:
    params = {}
    for spec in build_kernel_specs(cfg):
        key, k = jax.random.split(key)
        if spec.kind == "layernorm":
            params[spec.name] = {"scale": jnp.ones((spec.n_out,), jnp.float32),
                                 "bias": jnp.zeros((spec.n_out,), jnp.float32)}
        elif spec.kind == "conv":
            c_out = spec.n_out // cfg.stages[0].feat
            c_in = spec.n_in // spec.kernel
            std = 1.0 / math.sqrt(spec.n_in)
            params[spec.name] = {
                "w": (jax.random.normal(k, (spec.kernel, c_in, c_out),
                                        jnp.float32) * std).astype(dtype),
                "b": jnp.zeros((c_out,), dtype)}
        else:
            std = 1.0 / math.sqrt(spec.n_in)
            params[spec.name] = {
                "w": (jax.random.normal(k, (spec.n_in, spec.n_out),
                                        jnp.float32) * std).astype(dtype),
                "b": jnp.zeros((spec.n_out,), dtype)}
    return params


def _ln(p, x):
    mu = x.mean(-1, keepdims=True)
    var = x.var(-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + 1e-5) * p["scale"] + p["bias"]


def init_stream_state(cfg: TDSConfig) -> dict:
    """Left-context ring buffers — the scratchpad the paper keeps in the
    512KB shared memory between decoding steps (~275KB; see DESIGN.md)."""
    state = {}
    w = cfg.stages[0].feat
    for spec in build_kernel_specs(cfg):
        if spec.kind == "conv":
            c_in = spec.n_in // spec.kernel
            state[spec.name] = jnp.zeros((spec.kernel - 1, w, c_in),
                                         jnp.float32)
    return state


def init_batched_stream_state(cfg: TDSConfig, batch: int) -> dict:
    """Stream state for `batch` concurrent utterances: (B, k-1, w, c_in)
    per conv — the per-slot left context of a multi-stream slot pool."""
    return treeutil.batch_tree(init_stream_state(cfg), batch)


def reset_stream_slot(state: dict, slot, cfg: TDSConfig) -> dict:
    """Zero one slot's left context (utterance boundary in that stream)."""
    return treeutil.set_slot(state, slot, init_stream_state(cfg))


def state_bytes(cfg: TDSConfig, bytes_per_el: int = 1) -> int:
    st = init_stream_state(cfg)
    return sum(int(np.prod(a.shape)) * bytes_per_el
               for a in jax.tree.leaves(st))


def _conv_step(p, spec: KernelSpec, state, x):
    """Causal strided time-conv. x: (m, w, c_in); state: (k-1, w, c_in)."""
    k, s = spec.kernel, spec.stride
    m = x.shape[0]
    assert m % s == 0, (m, s)
    xp = jnp.concatenate([state, x], axis=0)        # (k-1+m, w, c_in)
    t_out = m // s
    # output t consumes xp[s*t : s*t+k] (ends at input index s*t + s - 1)
    off = (jnp.arange(t_out) * s)[:, None] + jnp.arange(k)[None, :]
    win = xp[off]                                    # (t_out, k, w, c_in)
    y = jnp.einsum("tkwc,kcd->twd", win, p["w"]) + p["b"]
    new_state = xp[-(k - 1):] if k > 1 else state
    return y, new_state


def forward(params, cfg: TDSConfig, feats: jax.Array,
            state: Optional[dict] = None, use_int8: bool = False,
            kernels=None):
    """feats: (T, n_mfcc). Returns (log_probs (T', V), new_state).

    state=None => offline (zero left context).  T must be divisible by the
    total subsample.  use_int8 routes FC/head matmuls through the int8
    quantized path (core/quant) — ASRPU's 8-bit MAC; `kernels` is the
    KernelPolicy dispatching that Pallas-backed op (None = auto).
    """
    specs = build_kernel_specs(cfg)
    st_in = state if state is not None else init_stream_state(cfg)
    new_state = dict(st_in)
    w = cfg.stages[0].feat
    x = feats[:, :, None]                            # (T, w, 1)

    def matmul(xm, pw, pb):
        if use_int8:
            from repro.kernels import ops
            return ops.int8_matmul(xm, pw, policy=kernels) + pb
        return xm @ pw + pb

    for spec in specs:
        p = params[spec.name]
        if spec.kind == "conv":
            res = x
            y, ns = _conv_step(p, spec, st_in[spec.name], x)
            new_state[spec.name] = ns
            if spec.activation == "relu":
                y = jax.nn.relu(y)
            x = y + res if (spec.residual and res.shape == y.shape) else y
        elif spec.kind == "layernorm":
            t = x.shape[0]
            x = _ln(p, x.reshape(t, -1)).reshape(x.shape)
        else:  # fc / head
            t = x.shape[0]
            xm = x.reshape(t, -1)
            if spec.activation == "relu":      # fc1: start of the FC block
                fc_res = xm
            y = matmul(xm, p["w"], p["b"])
            if spec.activation == "relu":
                y = jax.nn.relu(y)
            if spec.residual and y.shape == fc_res.shape:
                y = y + fc_res                 # TDS residual: whole FC block
            if spec.name == "head":
                return jax.nn.log_softmax(y, axis=-1), new_state
            c = spec.n_out // w
            x = y.reshape(t, w, c)
    raise AssertionError("head kernel missing")
