"""Generic LM-family model: dense / GQA / MoE / SSM / hybrid, one code path.

Layers are lax.scan'ned over weights stacked along a leading "repeat" axis,
with an *effective period* P = lcm(len(layer_pattern), moe_every): layer
i = r*P + p, and the sub-layer kind (attn/mamba, dense-MLP/MoE) is static
per period position p.  This keeps the HLO O(1) in depth (80-layer models
compile as fast as 2-layer ones) and makes the per-layer KV/SSM caches
natural scan xs/ys.

Three entry points (all pure functions of (params, ...)):
  loss_fn(params, batch)              — training loss (remat'd scan body)
  prefill(params, batch)              — full-sequence forward, returns
                                        (last-token logits, decode cache)
  decode_step(params, cache, batch)   — one-token step against the cache
                                        (ring-buffer for SWA archs)
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import layers, mamba, moe

MOE_AUX_COEF = 0.01
Z_LOSS_COEF = 1e-4


def pad_vocab(v: int, multiple: int = 512) -> int:
    """Megatron-style vocab padding so embed/head shard evenly."""
    return -(-v // multiple) * multiple


def _lcm(a: int, b: int) -> int:
    return a * b // math.gcd(a, b)


class LM:
    def __init__(self, cfg: ModelConfig, sharder=None):
        self.cfg = cfg
        me = cfg.moe.moe_every if cfg.moe else 1
        self.P = _lcm(cfg.period, me)
        assert cfg.n_layers % self.P == 0, (cfg.name, cfg.n_layers, self.P)
        self.R = cfg.n_layers // self.P
        self.Vp = pad_vocab(cfg.vocab_size)
        self.dtype = jnp.dtype(cfg.dtype)
        if sharder is None:
            from repro.parallel.sharding import Sharder
            sharder = Sharder(None)
        self.sh = sharder

    # ------------------------------------------------------------------
    # params
    # ------------------------------------------------------------------
    def _kind(self, p: int) -> str:
        return self.cfg.layer_kind(p % self.cfg.period)

    def _is_moe(self, p: int) -> bool:
        return self.cfg.is_moe_layer(p)

    def _has_mlp(self, p: int) -> bool:
        return self._is_moe(p) or self.cfg.d_ff > 0

    def _init_sublayer(self, key, p: int) -> dict:
        cfg = self.cfg
        d = cfg.d_model
        ks = jax.random.split(key, 4)
        out = {"norm1": layers.init_norm(d, cfg.norm)}
        if self._kind(p) == "attn":
            qkv_out = (cfg.n_heads + 2 * cfg.n_kv_heads) * cfg.head_dim
            out["mixer"] = {
                "wqkv": layers.init_linear(ks[0], d, qkv_out, cfg.qkv_bias,
                                           self.dtype),
                "wo": layers.init_linear(ks[1], cfg.n_heads * cfg.head_dim, d,
                                         dtype=self.dtype),
            }
        else:
            out["mixer"] = mamba.init_mamba(ks[0], d, cfg.ssm, self.dtype)
        if self._has_mlp(p):
            out["norm2"] = layers.init_norm(d, cfg.norm)
            if self._is_moe(p):
                out["mlp"] = moe.init_moe(ks[2], d, cfg.moe, self.dtype)
            else:
                out["mlp"] = layers.init_mlp(ks[2], d, cfg.d_ff, self.dtype)
        return out

    def init(self, key) -> dict:
        cfg = self.cfg
        keys = jax.random.split(key, self.P + 2)
        params = {"final_norm": layers.init_norm(cfg.d_model, cfg.norm)}
        if cfg.embed_inputs or cfg.tie_embeddings:
            std = 1.0 / math.sqrt(cfg.d_model)
            params["embed"] = {"w": (jax.random.normal(
                keys[-1], (self.Vp, cfg.d_model), jnp.float32) * std
            ).astype(self.dtype)}
        if not cfg.tie_embeddings:
            params["lm_head"] = layers.init_linear(
                keys[-2], cfg.d_model, self.Vp, dtype=self.dtype)

        def stack_init(p):
            def one(key):
                return self._init_sublayer(key, p)
            return jax.vmap(one)(jax.random.split(keys[p], self.R))

        params["layers"] = {f"p{p}": stack_init(p) for p in range(self.P)}
        return params

    def param_shapes(self, key=None) -> dict:
        key = jax.random.PRNGKey(0) if key is None else key
        return jax.eval_shape(self.init, key)

    # ------------------------------------------------------------------
    # caches
    # ------------------------------------------------------------------
    def cache_len(self, seq_len: int) -> int:
        if self.cfg.attn_window is not None:
            return min(seq_len, self.cfg.attn_window)
        return seq_len

    def init_cache(self, batch: int, seq_len: int, *,
                   per_slot: bool = False) -> dict:
        """Decode cache.  per_slot=True gives every batch row its own
        position metadata — kpos (B, Sc) and offset (B,) — so a serving
        slot pool can hold streams at unequal positions (staggered
        admission with different prompt lengths); the default scalar
        offset / shared (Sc,) kpos assumes all rows aligned."""
        cfg = self.cfg
        Sc = self.cache_len(seq_len)
        lay = {}
        for p in range(self.P):
            if self._kind(p) == "attn":
                shp = (self.R, batch, Sc, cfg.n_kv_heads, cfg.head_dim)
                lay[f"p{p}"] = {"k": jnp.zeros(shp, self.dtype),
                                "v": jnp.zeros(shp, self.dtype)}
            else:
                one = mamba.init_cache(batch, cfg.d_model, cfg.ssm, self.dtype)
                lay[f"p{p}"] = jax.tree.map(
                    lambda a: jnp.broadcast_to(a[None], (self.R,) + a.shape), one)
        if per_slot:
            return {"layers": lay,
                    "kpos": jnp.full((batch, Sc), -1, jnp.int32),
                    "offset": jnp.zeros((batch,), jnp.int32)}
        return {"layers": lay,
                "kpos": jnp.full((Sc,), -1, jnp.int32),
                "offset": jnp.zeros((), jnp.int32)}

    # ------------------------------------------------------------------
    # forward pieces
    # ------------------------------------------------------------------
    def _positions(self, batch: dict, B: int, S: int, offset=0):
        if "positions" in batch:
            return batch["positions"]
        pos = jnp.arange(S, dtype=jnp.int32)[None, :] + offset   # (1,S)
        pos = jnp.broadcast_to(pos, (B, S))
        if self.cfg.rope == "mrope":
            pos = jnp.broadcast_to(pos[..., None], (B, S, 3))
        return pos

    def _embed(self, params, batch) -> jax.Array:
        if self.cfg.embed_inputs:
            x = jnp.take(params["embed"]["w"], batch["tokens"], axis=0)
        else:
            x = batch["embeds"].astype(self.dtype)
        return self.sh.act(x)

    def _logits(self, params, x) -> jax.Array:
        if self.cfg.tie_embeddings:
            y = jnp.einsum("...d,vd->...v", x, params["embed"]["w"])
        else:
            y = layers.linear(params["lm_head"], x)
        return self.sh.logits(y)

    def _attn_full(self, p_mix, x, positions):
        """Training/prefill attention. Returns (out, (k, v))."""
        cfg = self.cfg
        B, S, _ = x.shape
        qkv = layers.linear(p_mix["wqkv"], x)
        Hq = cfg.n_heads * cfg.head_dim
        Hk = cfg.n_kv_heads * cfg.head_dim
        q = qkv[..., :Hq].reshape(B, S, cfg.n_heads, cfg.head_dim)
        k = qkv[..., Hq:Hq + Hk].reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
        v = qkv[..., Hq + Hk:].reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
        q = layers.apply_rope(q, positions, cfg.rope, cfg.rope_theta)
        k = layers.apply_rope(k, positions, cfg.rope, cfg.rope_theta)
        ipos = positions[..., 0] if cfg.rope == "mrope" else positions
        out = layers.attention_chunked(
            q, k, v, ipos, ipos, causal=True, window=cfg.attn_window,
            chunk_q=cfg.attn_chunk_q, chunk_kv=cfg.attn_chunk_kv,
            sharder=self.sh)
        out = layers.linear(p_mix["wo"], out.reshape(B, S, Hq))
        # cache entries leave in sequence-parallel layout (S over 'model')
        return out, (self.sh.seq(k), self.sh.seq(v))

    def _attn_decode(self, p_mix, x, positions, kv_cache, kpos, slot):
        """Decode attention: the cache is READ-ONLY here; the new (k, v)
        is attended as a separate softmax column and returned, so the
        layer scan emits only (B, 1, K, Dh) slices — the caller writes
        them all into the donated cache with one batched in-place DUS
        (scanning full caches as carry made XLA copy them every layer)."""
        cfg = self.cfg
        B = x.shape[0]
        qkv = layers.linear(p_mix["wqkv"], x)                   # (B,1,·)
        Hq = cfg.n_heads * cfg.head_dim
        Hk = cfg.n_kv_heads * cfg.head_dim
        q = qkv[..., :Hq].reshape(B, 1, cfg.n_heads, cfg.head_dim)
        k = qkv[..., Hq:Hq + Hk].reshape(B, 1, cfg.n_kv_heads, cfg.head_dim)
        v = qkv[..., Hq + Hk:].reshape(B, 1, cfg.n_kv_heads, cfg.head_dim)
        q = layers.apply_rope(q, positions, cfg.rope, cfg.rope_theta)
        k = layers.apply_rope(k, positions, cfg.rope, cfg.rope_theta)
        ipos = positions[..., 0] if cfg.rope == "mrope" else positions
        # the slot being (re)written holds the evicted entry: mask it
        if kpos.ndim == 2:     # per-slot metadata: row b masks slot[b]
            kpos_m = kpos.at[jnp.arange(B), slot].set(-1)
        else:
            kpos_m = kpos.at[slot].set(-1)
        sh = self.sh
        Sc = kv_cache["k"].shape[1]
        if (sh.mesh is not None and not sh.baseline and kpos_m.ndim == 1
                and Sc % sh.mesh.shape["model"] == 0):
            # flash-decoding: partial softmax per model-shard of the
            # sequence-sharded cache; O(B*H*D) combine, no cache gather
            out = layers.attention_decode_sharded(
                q, kv_cache["k"], kv_cache["v"], ipos[:, 0], kpos_m,
                window=cfg.attn_window, k_new=k, v_new=v, sharder=sh)
        else:
            out = layers.attention_decode(q, kv_cache["k"], kv_cache["v"],
                                          ipos[:, 0], kpos_m,
                                          window=cfg.attn_window,
                                          k_new=k, v_new=v)
        out = layers.linear(p_mix["wo"], out.reshape(B, 1, Hq))
        return out, {"k": k, "v": v}

    def _sublayer(self, p, lp, x, positions, cache_p, kpos, slot, decode,
                  lengths=None):
        cfg = self.cfg
        aux = jnp.zeros((), jnp.float32)
        h = layers.apply_norm(lp["norm1"], x, cfg.norm)
        if self._kind(p) == "attn":
            if decode:
                out, new_cache = self._attn_decode(lp["mixer"], h, positions,
                                                   cache_p, kpos, slot)
            else:
                # causal: right-padding (bucketed prefill) cannot leak
                # into real positions, so no mask is needed here
                out, kv = self._attn_full(lp["mixer"], h, positions)
                new_cache = {"k": kv[0], "v": kv[1]}
        else:
            out, new_cache = mamba.apply_mamba(lp["mixer"], h, cfg.ssm,
                                               cache_p, sharder=self.sh,
                                               lengths=lengths)
        x = x + out
        if self._has_mlp(p):
            h = layers.apply_norm(lp["norm2"], x, cfg.norm)
            if self._is_moe(p):
                y, aux = moe.apply_moe(lp["mlp"], h, cfg.moe, cfg.act,
                                       sharder=self.sh)
            else:
                y = layers.apply_mlp(lp["mlp"], h, cfg.act)
            x = x + y
        return self.sh.act(x), new_cache, aux

    def _scan_layers(self, params, x, positions, cache=None, *, decode=False,
                     remat=False, collect_cache=False, lengths=None):
        kpos = cache["kpos"] if cache is not None else None
        # per-slot serving cache: offset (B,), kpos (B, Sc) — each batch
        # row keeps its own write slot / positions (see init_cache)
        per_slot = decode and cache["offset"].ndim == 1
        slot = (cache["offset"] % jnp.int32(max(1, kpos.shape[-1]))
                if decode else None)
        if decode:
            if per_slot:
                rows = jnp.arange(kpos.shape[0])
                kpos = kpos.at[rows, slot].set(cache["offset"])
            else:
                kpos = kpos.at[slot].set(cache["offset"])

        if decode:
            # The cache is read via per-layer dynamic-index from a
            # loop-INVARIANT operand (not scan xs: xs + post-scan DUS into
            # the same donated buffer is a WAR hazard that makes XLA copy
            # the whole cache).  Each layer emits only the new-token KV
            # (and the small SSM/conv states) as ys; the KV slices are
            # written with ONE batched dynamic-update-slice after the scan.
            cache_layers = cache["layers"]

            def body(h, xs):
                lp, r = xs
                ys = {}
                for p in range(self.P):
                    cp = jax.tree.map(
                        lambda a: lax.dynamic_index_in_dim(
                            a, r, 0, keepdims=False), cache_layers[f"p{p}"])
                    h, nc, _ = self._sublayer(p, lp[f"p{p}"], h, positions,
                                              cp, kpos, slot, decode)
                    ys[f"p{p}"] = nc
                return h, ys

            x, new_slices = lax.scan(
                body, x, (params["layers"], jnp.arange(self.R)))
            new_layers = {}
            for p in range(self.P):
                if self._kind(p) == "attn":
                    old = cache["layers"][f"p{p}"]
                    upd = new_slices[f"p{p}"]       # k/v: (R, B, 1, K, Dh)
                    if per_slot:
                        # scatter: row b writes its own cache slot[b]
                        rows = jnp.arange(old["k"].shape[1])
                        new_layers[f"p{p}"] = {
                            name: old[name].at[:, rows, slot].set(
                                upd[name][:, :, 0].astype(old[name].dtype))
                            for name in ("k", "v")}
                    else:
                        new_layers[f"p{p}"] = {
                            name: lax.dynamic_update_slice_in_dim(
                                old[name], upd[name].astype(old[name].dtype),
                                slot, axis=2)
                            for name in ("k", "v")}
                else:
                    new_layers[f"p{p}"] = new_slices[f"p{p}"]
            return x, jnp.zeros((), jnp.float32), {
                "layers": new_layers, "kpos": kpos,
                "offset": cache["offset"] + 1}

        def body(carry, xs):
            h, aux_sum = carry
            lp = xs[0]
            cr = xs[1] if cache is not None else {f"p{p}": None
                                                  for p in range(self.P)}
            new_c = {}
            for p in range(self.P):
                h, nc, aux = self._sublayer(p, lp[f"p{p}"], h, positions,
                                            cr[f"p{p}"], kpos, slot, decode,
                                            lengths=lengths)
                new_c[f"p{p}"] = nc
            ys = new_c if collect_cache else None
            return (h, aux_sum + aux), ys

        if remat:
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
        xs = (params["layers"], cache["layers"]) if cache is not None \
            else (params["layers"],)
        (x, aux), new_layers = lax.scan(body, (x, jnp.zeros((), jnp.float32)), xs)
        new_cache = None
        if collect_cache:
            new_cache = {"layers": new_layers, "kpos": kpos,
                         "offset": (cache["offset"] if cache is not None
                                    else jnp.zeros((), jnp.int32)) + 1}
        return x, aux, new_cache

    # ------------------------------------------------------------------
    # public entry points
    # ------------------------------------------------------------------
    def loss_fn(self, params, batch, *, remat=True, loss_chunks=0):
        """batch: tokens/embeds (B,S[,D]), labels (B,S) int32 (-1 = pad).

        Cross-entropy is computed in sequence chunks (lax.scan over S with a
        checkpointed body): the fp32 (B, S, V) logits tensor — the largest
        single training buffer for big-vocab archs — never materializes;
        each chunk's logits are recomputed in the backward pass.
        """
        cfg = self.cfg
        x = self._embed(params, batch)
        B, S = x.shape[:2]
        positions = self._positions(batch, B, S)
        x, aux, _ = self._scan_layers(params, x, positions, remat=remat)
        x = layers.apply_norm(params["final_norm"], x, cfg.norm)
        labels = batch["labels"]
        if loss_chunks == 0:
            loss_chunks = 16 if S % 16 == 0 and S >= 2048 else 1
        nc = loss_chunks
        xc = x.reshape(B, nc, S // nc, -1).transpose(1, 0, 2, 3)
        lc = labels.reshape(B, nc, S // nc).transpose(1, 0, 2)

        @jax.checkpoint
        def chunk(carry, xs):
            nll_s, z_s, n_s = carry
            xi, li = xs
            logits = self._logits(params, xi).astype(jnp.float32)
            valid = li >= 0
            lbl = jnp.where(valid, li, 0)
            lse = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, lbl[..., None], axis=-1)[..., 0]
            nll_s = nll_s + jnp.where(valid, lse - gold, 0.0).sum()
            z_s = z_s + jnp.where(valid, jnp.square(lse), 0.0).sum()
            n_s = n_s + valid.sum()
            return (nll_s, z_s, n_s), None

        (nll, zsum, ntok), _ = lax.scan(
            chunk, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32),
                    jnp.zeros((), jnp.int32)), (xc, lc))
        ntok = jnp.maximum(ntok, 1)
        loss = nll / ntok
        zloss = Z_LOSS_COEF * zsum / ntok
        return loss + zloss + MOE_AUX_COEF * aux, {
            "loss": loss, "aux": aux, "ntok": ntok}

    def prefill(self, params, batch, lengths=None, cache_len=None):
        """Full-seq forward. Returns (last-token logits (B,Vp), cache).

        `lengths` (B,) enables the masked (bucketed) path: each row's
        tokens beyond lengths[b] are right-padding — logits come from
        position lengths[b]-1, recurrent state stops before the padding
        (see mamba.apply_mamba), and the cache is assembled with
        PER-ROW position metadata (kpos (B, Sc), offset (B,)) so rows
        drop straight into a per-slot serving pool.  `cache_len`
        overrides the assembled ring width (the pool's ring may be
        narrower than the padded bucket)."""
        cfg = self.cfg
        x = self._embed(params, batch)
        B, S = x.shape[:2]
        positions = self._positions(batch, B, S)
        x, _, cache = self._scan_layers(params, x, positions,
                                        collect_cache=True, lengths=lengths)
        if lengths is None:
            x = layers.apply_norm(params["final_norm"], x[:, -1:], cfg.norm)
            logits = self._logits(params, x)[:, 0]
            # assemble decode cache (kpos/offset); SWA ring by decode path
            Sc = self.cache_len(S)
            if cache is not None and Sc != S:
                def trim(a):
                    return (a[:, :, -Sc:]
                            if a.ndim >= 3 and a.shape[2] == S else a)
                cache["layers"] = jax.tree.map(trim, cache["layers"])
                cache["kpos"] = jnp.arange(S - Sc, S, dtype=jnp.int32)
            else:
                cache["kpos"] = jnp.arange(S, dtype=jnp.int32)
            cache["offset"] = jnp.full((), S, jnp.int32)
            return logits, cache

        # ---- masked path: per-row last token + per-row ring assembly ----
        last = jnp.clip(lengths - 1, 0, S - 1)                    # (B,)
        xl = jnp.take_along_axis(x, last[:, None, None], axis=1)  # (B,1,D)
        xl = layers.apply_norm(params["final_norm"], xl, cfg.norm)
        logits = self._logits(params, xl)[:, 0]
        Sc = self.cache_len(S) if cache_len is None else int(cache_len)
        # cache row j of stream b holds position start_b + j, where
        # start_b = max(len_b - Sc, 0): the last min(len, Sc) real
        # positions land in rows 0.. (prompts longer than the ring
        # arrive trimmed, mirroring the SWA decode convention)
        start = jnp.maximum(lengths - Sc, 0)                      # (B,)
        pos_rows = start[:, None] + jnp.arange(Sc)[None, :]       # (B, Sc)
        rows = jnp.minimum(pos_rows, S - 1)
        new_layers = {}
        for p in range(self.P):
            lay = cache["layers"][f"p{p}"]
            if self._kind(p) == "attn":
                ix = rows[None, :, :, None, None]     # (1,B,Sc,1,1) -> bcast
                new_layers[f"p{p}"] = {
                    name: jnp.take_along_axis(lay[name], ix, axis=2)
                    for name in ("k", "v")}
            else:
                new_layers[f"p{p}"] = lay    # SSM/conv states: no seq axis
        cache["layers"] = new_layers
        cache["kpos"] = jnp.where(pos_rows < lengths[:, None],
                                  pos_rows, -1).astype(jnp.int32)
        cache["offset"] = lengths.astype(jnp.int32)
        return logits, cache

    def decode_step(self, params, cache, batch):
        """One-token step. batch: tokens (B,1) or embeds (B,1,D).

        Returns (logits (B,Vp), next_token (B,), new_cache).
        """
        cfg = self.cfg
        x = self._embed(params, batch)
        B = x.shape[0]
        pos = cache["offset"]
        if pos.ndim == 1:                   # per-slot offsets: (B,) -> (B, 1)
            pos = pos[:, None]
        positions = self._positions(batch, B, 1, offset=pos)
        x, _, new_cache = self._scan_layers(params, x, positions, cache,
                                            decode=True)
        x = layers.apply_norm(params["final_norm"], x, cfg.norm)
        logits = self._logits(params, x)[:, 0].astype(jnp.float32)
        # mask vocab padding before sampling
        vmask = jnp.arange(self.Vp) < cfg.vocab_size
        logits = jnp.where(vmask[None, :], logits, -jnp.inf)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return logits, next_tok, new_cache
