"""Mixture-of-Experts with capacity-based *scatter* dispatch.

Design notes (see DESIGN.md §5):
  * GShard-style one-hot dispatch einsums cost O(T·E·C·D) FLOPs — far more
    than the expert compute itself at our scales. We instead scatter tokens
    into an (E, C, D) buffer (O(T·D) data movement) and run grouped matmuls
    (O(T·k·cf·D·F) FLOPs == true active compute), so the roofline compute
    term reflects active parameters only.
  * Experts are sharded over the 'model' mesh axis when E % model == 0
    (expert parallelism); otherwise the expert f-dim is sharded
    (TP-within-expert).  The scatter/gather across the token<->expert
    resharding is what GSPMD lowers to the MoE all-to-all.
  * Router runs in fp32; auxiliary load-balancing loss is returned.
"""
from __future__ import annotations

import jax
from jax import lax
import jax.numpy as jnp

from repro import compat
from repro.configs.base import MoESpec
from repro.models import layers


def init_moe(key, d: int, spec: MoESpec, dtype=jnp.bfloat16) -> dict:
    k_r, k_g, k_u, k_d, k_s = jax.random.split(key, 5)
    E, F = spec.n_experts, spec.expert_d_ff
    std = 1.0 / jnp.sqrt(d)
    p = {
        "router": {"w": (jax.random.normal(k_r, (d, E), jnp.float32) * std
                         ).astype(jnp.float32)},
        "w_gate": (jax.random.normal(k_g, (E, d, F), jnp.float32) * std).astype(dtype),
        "w_up": (jax.random.normal(k_u, (E, d, F), jnp.float32) * std).astype(dtype),
        "w_down": (jax.random.normal(k_d, (E, F, d), jnp.float32)
                   / jnp.sqrt(F)).astype(dtype),
    }
    if spec.shared_d_ff:
        p["shared"] = layers.init_mlp(k_s, d, spec.shared_d_ff, dtype)
    return p


def capacity(n_tokens: int, spec: MoESpec) -> int:
    c = int(n_tokens * spec.top_k * spec.capacity_factor / spec.n_experts)
    # multiple of 256 so the capacity dim shards evenly over the DP axes
    return max(256, -(-c // 256) * 256) if n_tokens >= 256 else max(8, c)


def apply_moe(p: dict, x: jax.Array, spec: MoESpec, act: str, sharder=None):
    """x: (B, S, D) -> (y, aux_loss)."""
    if sharder is None:
        from repro.parallel.sharding import Sharder
        sharder = Sharder(None)
    ep = (sharder.mesh is not None
          and spec.n_experts % sharder.mesh.shape.get("model", 1) == 0)

    def _divisible():
        nb = 1
        for a in sharder.batch:
            nb *= sharder.mesh.shape[a]
        return (x.shape[0] % max(nb, 1) == 0
                and x.shape[1] % sharder.mesh.shape["model"] == 0)

    if (ep and not getattr(sharder, "baseline", False) and x.shape[1] > 1
            and _divisible()):
        # hillclimb: explicit expert-parallel dispatch via shard_map
        # (GSPMD's guessed layout for the gather dispatch replicates the
        # (T*K, D) combine tensors — see EXPERIMENTS.md §Perf)
        return apply_moe_ep(p, x, spec, act, sharder)
    B, S, D = x.shape
    T = B * S
    E, K = spec.n_experts, spec.top_k
    C = capacity(T, spec)
    xt = x.reshape(T, D)

    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                        p["router"]["w"])                       # (T, E) fp32
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, K)                      # (T, K)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # --- sort-based dispatch: 1-D argsort + row gathers only.  (A scatter
    # formulation makes GSPMD materialize (T*K, D)-shaped u32 index tensors;
    # gathers partition cleanly and lower to the MoE all-to-all.) ---------
    flat_e = top_e.reshape(-1)                                  # (T*K,)
    order = jnp.argsort(flat_e, stable=True)                    # (T*K,)
    rank = jnp.argsort(order)            # rank of candidate i in expert order
    counts = jnp.bincount(flat_e, length=E)                     # (E,)
    starts = jnp.cumsum(counts) - counts                        # (E,)
    pos = rank - starts[flat_e]          # position of candidate within expert
    keep = pos < C

    # expert buffer (E, C, D) filled by *gather*: slot (e, c) takes the
    # candidate ranked starts[e] + c, masked when c >= counts[e]
    slot_rank = starts[:, None] + jnp.arange(C)[None, :]        # (E, C)
    slot_valid = jnp.arange(C)[None, :] < counts[:, None]
    cand_of_slot = jnp.take(order, jnp.minimum(slot_rank, T * K - 1), axis=0)
    tok_of_slot = cand_of_slot // K                             # (E, C)
    buf = jnp.take(xt, tok_of_slot.reshape(-1), axis=0).reshape(E, C, D)
    buf = jnp.where(slot_valid[..., None], buf, 0)
    buf = sharder.expert(buf, ep)

    h = (layers.activation(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"]), act)
         * jnp.einsum("ecd,edf->ecf", buf, p["w_up"]))
    h = sharder.expert(h, ep)
    out = sharder.expert(jnp.einsum("ecf,efd->ecd", h, p["w_down"]), ep)
    out = out.reshape(E * C, D)

    # combine: candidate (t, k)'s slot is flat_e*C + pos (gather back)
    slot = jnp.minimum(flat_e * C + jnp.minimum(pos, C - 1), E * C - 1)
    gathered = jnp.take(out, slot, axis=0)
    gathered = jnp.where(keep[:, None], gathered, 0.0)
    y = (gathered.reshape(T, K, D)
         * top_p[..., None].astype(x.dtype)).sum(axis=1)

    if "shared" in p:
        y = y + layers.apply_mlp(p["shared"], xt, act)

    # load-balance aux loss (Switch-style)
    me = probs.mean(axis=0)                                     # (E,)
    ce = jnp.bincount(flat_e, length=E).astype(jnp.float32) / (T * K)
    aux = E * jnp.sum(me * ce)
    return y.reshape(B, S, D), aux


# ---------------------------------------------------------------------------
# explicit expert-parallel MoE (shard_map + all-to-all)
# ---------------------------------------------------------------------------
def _local_dispatch_combine(p, xl, spec: MoESpec, act: str, nm: int,
                            axis: str):
    """Per-shard MoE body: local routing + sort-gather dispatch, all-to-all
    over the expert axis, local-capacity (GShard local groups) semantics."""
    Tl, D = xl.shape
    E, K = spec.n_experts, spec.top_k
    E_loc = E // nm
    Cl = max(8, -(-int(Tl * K * spec.capacity_factor / E) // 8) * 8)

    logits = jnp.einsum("td,de->te", xl.astype(jnp.float32), p["router"]["w"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, K)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    flat_e = top_e.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    rank = jnp.argsort(order)
    counts = jnp.bincount(flat_e, length=E)
    starts = jnp.cumsum(counts) - counts
    pos = rank - starts[flat_e]
    keep = pos < Cl

    slot_rank = starts[:, None] + jnp.arange(Cl)[None, :]
    slot_valid = jnp.arange(Cl)[None, :] < counts[:, None]
    cand = jnp.take(order, jnp.minimum(slot_rank, Tl * K - 1), axis=0)
    buf = jnp.take(xl, (cand // K).reshape(-1), axis=0).reshape(E, Cl, D)
    buf = jnp.where(slot_valid[..., None], buf, 0)

    # dispatch all-to-all: (nm, E_loc, Cl, D) -> rows from every shard
    buf = lax.all_to_all(buf.reshape(nm, E_loc, Cl, D), axis, 0, 0,
                         tiled=False)                     # (nm, E_loc, Cl, D)
    buf = buf.transpose(1, 0, 2, 3).reshape(E_loc, nm * Cl, D)

    h = (layers.activation(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"]), act)
         * jnp.einsum("ecd,edf->ecf", buf, p["w_up"]))
    out = jnp.einsum("ecf,efd->ecd", h, p["w_down"])      # (E_loc, nm*Cl, D)

    # return trip
    out = out.reshape(E_loc, nm, Cl, D).transpose(1, 0, 2, 3)
    out = lax.all_to_all(out, axis, 0, 0, tiled=False)    # (nm, E_loc, Cl, D)
    out = out.reshape(E * Cl, D)

    slot = jnp.minimum(flat_e * Cl + jnp.minimum(pos, Cl - 1), E * Cl - 1)
    gathered = jnp.take(out, slot, axis=0)
    gathered = jnp.where(keep[:, None], gathered, 0.0)
    y = (gathered.reshape(Tl, K, D)
         * top_p[..., None].astype(xl.dtype)).sum(axis=1)

    me = probs.mean(axis=0)
    ce = jnp.bincount(flat_e, length=E).astype(jnp.float32) / (Tl * K)
    aux = E * jnp.sum(me * ce)
    return y, aux


def apply_moe_ep(p: dict, x: jax.Array, spec: MoESpec, act: str, sharder):
    """Expert parallelism with explicit all-to-all (shard_map over 'model',
    vmapped over the DP axes): tokens stay in their DP row, expert weights
    live on their 'model' column (replicated across DP inside the column —
    storage stays FSDP-sharded; jax reshards at the shard_map boundary).

    vs the GSPMD path: no (T*K, D) replication, two all-to-alls per layer
    (the textbook MoE schedule).  Local-capacity drop semantics (GShard
    local groups).
    """
    from jax.sharding import PartitionSpec as P
    mesh = sharder.mesh
    nm = mesh.shape["model"]
    B, S, D = x.shape
    b_axes = sharder.batch

    def body(router_w, wg, wu, wd, shared, xl):
        pl = {"router": {"w": router_w}, "w_gate": wg, "w_up": wu,
              "w_down": wd}
        Bl, Sl, _ = xl.shape
        y, aux = _local_dispatch_combine(pl, xl.reshape(Bl * Sl, D), spec,
                                         act, nm, "model")
        if shared is not None:
            y = y + layers.apply_mlp(shared, xl.reshape(Bl * Sl, D), act)
        aux = lax.pmean(aux, "model")
        for a in b_axes:
            aux = lax.pmean(aux, a)
        return y.reshape(Bl, Sl, D), aux

    shared = p.get("shared")
    in_specs = (P(), P("model", None, None), P("model", None, None),
                P("model", None, None),
                None if shared is None else P(),
                P(b_axes if b_axes else None, "model", None))
    if shared is None:
        def body2(rw, wg, wu, wd, xl):
            return body(rw, wg, wu, wd, None, xl)
        fn = compat.shard_map(body2, mesh=mesh,
                           in_specs=in_specs[:4] + (in_specs[5],),
                           out_specs=(P(b_axes if b_axes else None,
                                        "model", None), P()),
                           check_vma=False)
        y, aux = fn(p["router"]["w"], p["w_gate"], p["w_up"], p["w_down"], x)
    else:
        fn = compat.shard_map(body, mesh=mesh, in_specs=in_specs,
                           out_specs=(P(b_axes if b_axes else None,
                                        "model", None), P()),
                           check_vma=False)
        y, aux = fn(p["router"]["w"], p["w_gate"], p["w_up"], p["w_down"],
                    shared, x)
    return y, aux
