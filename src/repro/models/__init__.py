from repro.models.transformer import LM, pad_vocab
