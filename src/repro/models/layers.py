"""Core NN layers: norms, RoPE (standard / 2d / M-RoPE), GQA attention.

Attention has two execution paths:
  * `attention_chunked` — prefill/training: lax.scan over q-chunks with an
    inner online-softmax scan over kv-chunks (flash-attention structure in
    pure JAX; the Pallas kernel in `repro.kernels.flash_attention` is the
    TPU-optimized equivalent and is validated against this).
  * `attention_decode`  — single-query attention against a (ring-buffer)
    KV cache with absolute per-slot positions, supporting causal masking
    and sliding windows.

All softmax math is fp32 regardless of the activation dtype.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from repro import compat

MASK_VALUE = -1e30


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------
def init_norm(d: int, kind: str, dtype=jnp.float32) -> dict:
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def apply_norm(p: dict, x: jax.Array, kind: str, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * lax.rsqrt(var + eps)
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32)
    if "bias" in p:
        y = y + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# linear
# ---------------------------------------------------------------------------
def init_linear(key, d_in: int, d_out: int, bias: bool = False,
                dtype=jnp.bfloat16) -> dict:
    std = 1.0 / math.sqrt(d_in)
    p = {"w": (jax.random.normal(key, (d_in, d_out), jnp.float32) * std).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p: dict, x: jax.Array) -> jax.Array:
    if "wq" in p:
        # int8 serving weights (ASRPU's 8-bit MAC): stored/gathered as
        # int8 + per-output-channel scales, dequantized at use
        w = p["wq"].astype(x.dtype) * p["wscale"].astype(x.dtype)[None, :]
        y = jnp.einsum("...d,df->...f", x, w)
    else:
        y = jnp.einsum("...d,df->...f", x, p["w"])
    if "b" in p:
        y = y + p["b"]
    return y


def quantize_linear(p: dict) -> dict:
    """{'w': (din,dout), 'b'?} -> {'wq': int8, 'wscale': (dout,) f32, 'b'?}.

    Symmetric per-output-channel int8 (the serving-weight format: 4x less
    HBM residency and 4x less FSDP-gather wire than bf16-upcast-to-f32)."""
    w = p["w"].astype(jnp.float32)
    scale = jnp.max(jnp.abs(w), axis=0) / 127.0
    q = jnp.clip(jnp.round(w / jnp.maximum(scale[None, :], 1e-12)),
                 -127, 127).astype(jnp.int8)
    out = {"wq": q, "wscale": scale}
    if "b" in p:
        out["b"] = p["b"]
    return out


def quantize_params_for_serving(params: dict) -> dict:
    """Quantize every >=2D dense linear 'w' in an LM param tree to int8
    (embeddings, norms, MoE expert tensors and SSM params stay as-is)."""
    # skip: embeddings (lookup), router (fp32 by design), depthwise conv,
    # and the SSD dt/B/C projections — exp(cumsum(dt·A)) amplifies their
    # quantization error (jamba logits drifted 46% with them int8; they
    # are <1% of parameters)
    skip = ("embed", "router", "conv_x", "w_dt", "w_B", "w_C")

    def rec(tree, path=()):
        if isinstance(tree, dict):
            if any(s in path for s in skip):
                return {k: rec(v, path + (k,)) for k, v in tree.items()} \
                    if isinstance(tree, dict) else tree
            if "w" in tree and getattr(tree["w"], "ndim", 0) == 2:
                return quantize_linear(tree)
            if "w" in tree and getattr(tree["w"], "ndim", 0) == 3:
                # stacked-layer linear (leading repeat axis)
                w = tree["w"].astype(jnp.float32)
                scale = jnp.max(jnp.abs(w), axis=1) / 127.0   # (R, dout)
                q = jnp.clip(jnp.round(w / jnp.maximum(scale[:, None, :],
                                                       1e-12)),
                             -127, 127).astype(jnp.int8)
                out = {"wq": q, "wscale": scale}
                if "b" in tree:
                    out["b"] = tree["b"]
                return out
            return {k: rec(v, path + (k,)) for k, v in tree.items()}
        return tree
    return rec(params)


def activation(x: jax.Array, kind: str) -> jax.Array:
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# RoPE family
# ---------------------------------------------------------------------------
def _rope_rotate(x: jax.Array, pos: jax.Array, theta: float) -> jax.Array:
    """Rotate all of x's last dim. x: (..., S, H, D); pos: broadcastable (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(-jnp.arange(0, half, dtype=jnp.float32)
                    * (math.log(theta) / half))          # (half,)
    ang = pos.astype(jnp.float32)[..., None, None] * freqs  # (..., S, 1, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1).astype(x.dtype)


def apply_rope(x: jax.Array, positions: jax.Array, mode: str,
               theta: float) -> jax.Array:
    """x: (B, S, H, D). positions: (B, S) int, or (B, S, 3) for mrope."""
    if mode == "none":
        return x
    if mode == "rope":
        return _rope_rotate(x, positions, theta)
    if mode == "rope2d":
        # chatglm: rotary on the first half of the head dim only
        d = x.shape[-1]
        rot = _rope_rotate(x[..., : d // 2], positions, theta)
        return jnp.concatenate([rot, x[..., d // 2:]], axis=-1)
    if mode == "mrope":
        # positions: (B, S, 3) (temporal, h, w); split head dim in 3 sections
        d = x.shape[-1]
        s0 = (d // 3) & ~1   # even sections
        s1 = s0
        s2 = d - s0 - s1
        parts, off = [], 0
        for i, sec in enumerate((s0, s1, s2)):
            parts.append(_rope_rotate(x[..., off:off + sec],
                                      positions[..., i], theta))
            off += sec
        return jnp.concatenate(parts, axis=-1)
    raise ValueError(mode)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------
def _mask(qpos, kpos, causal: bool, window: Optional[int]):
    """qpos: (B, Sq), kpos: (B, Skv) -> bool (B, Sq, Skv). kpos<0 = invalid."""
    m = kpos[:, None, :] >= 0
    if causal:
        m &= kpos[:, None, :] <= qpos[:, :, None]
    if window is not None:
        m &= (qpos[:, :, None] - kpos[:, None, :]) < window
    return m


def attention_chunked(q, k, v, qpos, kpos, *, causal=True,
                      window: Optional[int] = None,
                      chunk_q: int = 512, chunk_kv: int = 1024,
                      sharder=None) -> jax.Array:
    """Flash-structured attention.

    q: (B, Sq, H, D); k, v: (B, Skv, K, D) with K | H (GQA).
    qpos: (B, Sq) int32 absolute positions; kpos: (B, Skv).
    Returns (B, Sq, H, D).
    """
    B, Sq, H, D = q.shape
    Skv, K = k.shape[1], k.shape[2]
    G = H // K
    scale = 1.0 / math.sqrt(D)
    cq = min(chunk_q, Sq)
    ckv = min(chunk_kv, Skv)
    assert Sq % cq == 0 and Skv % ckv == 0, (Sq, cq, Skv, ckv)
    nq, nkv = Sq // cq, Skv // ckv

    qg = q.reshape(B, nq, cq, K, G, D).transpose(1, 0, 3, 4, 2, 5)  # nq,B,K,G,cq,D
    qp = qpos.reshape(B, nq, cq).transpose(1, 0, 2)                 # nq,B,cq
    kc = k.reshape(B, nkv, ckv, K, D).transpose(1, 0, 3, 2, 4)      # nkv,B,K,ckv,D
    vc = v.reshape(B, nkv, ckv, K, D).transpose(1, 0, 3, 2, 4)
    kp = kpos.reshape(B, nkv, ckv).transpose(1, 0, 2)               # nkv,B,ckv
    if sharder is not None:
        # shard the intra-tile cq dim over 'model', replicate kv chunks:
        # every tensor inside the two scans is then local (see Sharder)
        qg = sharder.attn_q(qg)
        kc = sharder.attn_kv_chunks(kc)
        vc = sharder.attn_kv_chunks(vc)

    # flash-attention backward = recompute: checkpoint both loop levels so
    # the (cq x ckv) score/prob tiles are never saved as scan residuals
    # (without this, training residuals are O(S^2) and blow past HBM).
    @jax.checkpoint
    def q_block(args):
        qi, qpi = args  # (B,K,G,cq,D), (B,cq)

        @jax.checkpoint
        def kv_step(carry, xs):
            m_i, l_i, acc = carry
            ki, vi, kpi = xs
            # bf16 x bf16 -> f32 on the MXU (preferred_element_type);
            # upcasting ki/vi materialized f32 copies of every kv chunk
            # per (q-chunk, kv-chunk, layer) — 84 TB/device on 72b prefill
            s = jnp.einsum("bkgqd,bkcd->bkgqc", qi, ki,
                           preferred_element_type=jnp.float32) * scale
            msk = _mask(qpi, kpi, causal, window)[:, None, None]     # B,1,1,cq,ckv
            s = jnp.where(msk, s, MASK_VALUE)
            m_new = jnp.maximum(m_i, jnp.max(s, axis=-1))
            alpha = jnp.exp(m_i - m_new)
            p = jnp.exp(s - m_new[..., None])
            p = jnp.where(msk, p, 0.0)
            l_new = l_i * alpha + jnp.sum(p, axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bkgqc,bkcd->bkgqd", p.astype(vi.dtype), vi,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc), None

        init = (jnp.full((B, K, G, cq), -jnp.inf, jnp.float32),
                jnp.zeros((B, K, G, cq), jnp.float32),
                jnp.zeros((B, K, G, cq, D), jnp.float32))
        (m_f, l_f, acc), _ = lax.scan(kv_step, init, (kc, vc, kp))
        return acc / jnp.maximum(l_f, 1e-30)[..., None]  # (B,K,G,cq,D)

    outs = lax.map(q_block, (qg, qp))                      # nq,B,K,G,cq,D
    if sharder is not None:
        outs = sharder.attn_q(outs)
    outs = outs.transpose(1, 0, 4, 2, 3, 5).reshape(B, Sq, H, D)
    return outs.astype(q.dtype)


def attention_decode(q, k_cache, v_cache, qpos, kpos, *,
                     window: Optional[int] = None,
                     k_new=None, v_new=None) -> jax.Array:
    """Single-token attention against a cache.

    q: (B, 1, H, D); caches: (B, Sc, K, D); qpos: (B,) int32;
    kpos: (Sc,) int32 absolute positions of cache slots (-1 = empty), or
    (B, Sc) when each batch row tracks its own positions (per-slot
    serving cache with staggered admission).

    If k_new/v_new (B, 1, K, D) are given, the current token is attended
    as a separate logit column (two-part softmax) so the cache tensor is
    never concatenated/copied — the caller writes the new KV into the
    cache once, outside the layer loop.
    """
    B, _, H, D = q.shape
    Sc, K = k_cache.shape[1], k_cache.shape[2]
    G = H // K
    scale = 1.0 / math.sqrt(D)
    qg = q.reshape(B, K, G, D)
    # mixed-precision dots: never materialize an f32 copy of the KV cache
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    kp = kpos if kpos.ndim == 2 else kpos[None, :]         # (B|1, Sc)
    valid = (kp >= 0) & (kp <= qpos[:, None])
    if window is not None:
        valid &= (qpos[:, None] - kp) < window
    s = jnp.where(valid[:, None, None, :], s, MASK_VALUE)
    if k_new is not None:
        s_self = jnp.einsum("bkgd,bkd->bkg", qg, k_new[:, 0],
                            preferred_element_type=jnp.float32) * scale
        m = jnp.maximum(jnp.max(s, axis=-1), s_self)
        p = jnp.exp(s - m[..., None])
        p_self = jnp.exp(s_self - m)
        denom = p.sum(-1) + p_self
        out = (jnp.einsum("bkgs,bskd->bkgd", p.astype(v_cache.dtype), v_cache,
                          preferred_element_type=jnp.float32)
               + p_self[..., None] * v_new[:, 0].astype(jnp.float32)[:, :, None])
        out = out / denom[..., None]
    else:
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bkgs,bskd->bkgd", p.astype(v_cache.dtype), v_cache,
                         preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, D).astype(q.dtype)


def attention_decode_sharded(q, k_cache, v_cache, qpos, kpos, *,
                             window=None, k_new=None, v_new=None,
                             sharder=None) -> jax.Array:
    """Flash-decoding: the KV cache stays sequence-sharded over 'model';
    each shard computes a partial online softmax over its local slice and
    the shards combine (pmax/psum of (m, l, acc) — O(B·H·D) wire instead
    of all-gathering the cache).  The current token's KV joins afterwards
    as a separate logit column."""
    from jax.sharding import PartitionSpec as P
    mesh = sharder.mesh
    B, _, H, D = q.shape
    Sc, K = k_cache.shape[1], k_cache.shape[2]
    G = H // K
    scale = 1.0 / math.sqrt(D)
    b = sharder.batch if (sharder.batch and
                          B % _prod(mesh, sharder.batch) == 0) else ()

    def local(qg, kc, vc, qp, kp):
        s = jnp.einsum("bkgd,bskd->bkgs", qg, kc,
                       preferred_element_type=jnp.float32) * scale
        valid = (kp[None, :] >= 0) & (kp[None, :] <= qp[:, None])
        if window is not None:
            valid &= (qp[:, None] - kp[None, :]) < window
        s = jnp.where(valid[:, None, None, :], s, MASK_VALUE)
        m_loc = jnp.max(s, axis=-1)                        # (B,K,G)
        p = jnp.exp(s - m_loc[..., None])
        p = jnp.where(valid[:, None, None, :], p, 0.0)
        l_loc = p.sum(-1)
        acc_loc = jnp.einsum("bkgs,bskd->bkgd", p.astype(vc.dtype), vc,
                             preferred_element_type=jnp.float32)
        m_g = jax.lax.pmax(m_loc, "model")
        w = jnp.exp(m_loc - m_g)
        l_g = jax.lax.psum(l_loc * w, "model")
        acc_g = jax.lax.psum(acc_loc * w[..., None], "model")
        return m_g, l_g, acc_g

    fn = compat.shard_map(
        local, mesh=mesh,
        in_specs=(P(b, None, None, None), P(b, "model", None, None),
                  P(b, "model", None, None), P(b), P("model")),
        out_specs=(P(b, None, None), P(b, None, None),
                   P(b, None, None, None)),
        check_vma=False)
    qg = q.reshape(B, K, G, D)
    m, l, acc = fn(qg, k_cache, v_cache, qpos, kpos)
    if k_new is not None:
        s_self = jnp.einsum("bkgd,bkd->bkg", qg, k_new[:, 0],
                            preferred_element_type=jnp.float32) * scale
        m2 = jnp.maximum(m, s_self)
        w = jnp.exp(m - m2)
        p_self = jnp.exp(s_self - m2)
        l = l * w + p_self
        acc = acc * w[..., None] \
            + p_self[..., None] * v_new[:, 0].astype(jnp.float32)[:, :, None]
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, 1, H, D).astype(q.dtype)


def _prod(mesh, axes):
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


# ---------------------------------------------------------------------------
# gated MLP
# ---------------------------------------------------------------------------
def init_mlp(key, d: int, f: int, dtype=jnp.bfloat16) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {"w_gate": init_linear(k1, d, f, dtype=dtype),
            "w_up": init_linear(k2, d, f, dtype=dtype),
            "w_down": init_linear(k3, f, d, dtype=dtype)}


def apply_mlp(p: dict, x: jax.Array, act: str) -> jax.Array:
    return linear(p["w_down"],
                  activation(linear(p["w_gate"], x), act) * linear(p["w_up"], x))
