"""Checkpointing: atomic, async, sharding-agnostic, resumable.

Design (1000+ node posture, documented for the single-host container):
  * Layout-agnostic: leaves are saved as host numpy (fully addressable
    values); on restore they are re-placed with whatever shardings the
    *current* mesh prescribes — so a job can restart on a different
    topology (elastic re-mesh), because the checkpoint stores logical
    arrays, never device layouts.
  * Atomic: write to step_<n>.tmp/, fsync, rename — a crash mid-save
    never corrupts the latest good checkpoint.
  * Async: `save_async` snapshots to host memory synchronously (cheap)
    and writes in a background thread, overlapping I/O with the next
    training steps.
  * On real multi-host deployments each host writes its addressable
    shards (process-local files) — here jax.device_get covers the
    single-process case; the file format (one .npy per leaf + pytree
    manifest) is the same.
"""
from __future__ import annotations

import json
import os
import pathlib
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree) -> dict:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    # ---------------- save ----------------
    def save(self, step: int, state: Any):
        host = jax.tree.map(lambda a: np.asarray(jax.device_get(a)), state)
        self._write(step, host, state)

    def save_async(self, step: int, state: Any):
        self.wait()
        host = jax.tree.map(lambda a: np.asarray(jax.device_get(a)), state)
        self._thread = threading.Thread(
            target=self._write, args=(step, host, state), daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_tree: Any, template: Any):
        tmp = self.dir / f"step_{step:09d}.tmp"
        final = self.dir / f"step_{step:09d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        flat = _flatten(host_tree)
        manifest = {}
        for key, leaf in flat.items():
            fname = key.replace("/", "__") + ".npy"
            arr = np.asarray(leaf)
            dtype = str(arr.dtype)
            if arr.dtype.kind == "V" or "bfloat16" in dtype:
                # numpy can't round-trip ml_dtypes: store the raw bits
                np.save(tmp / fname, arr.view(np.uint16))
            else:
                np.save(tmp / fname, arr)
            manifest[key] = {"file": fname,
                             "shape": list(np.shape(leaf)),
                             "dtype": dtype}
        (tmp / "manifest.json").write_text(json.dumps(
            {"step": step, "leaves": manifest}))
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)          # atomic commit
        self._gc()

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s:09d}", ignore_errors=True)

    # ---------------- restore ----------------
    def all_steps(self):
        out = []
        for p in self.dir.glob("step_*"):
            if p.suffix == ".tmp" or not (p / "manifest.json").exists():
                continue
            out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, state_template: Any, step: Optional[int] = None,
                shardings: Any = None) -> Any:
        """Restore into the template's structure; if `shardings` is given,
        leaves are device_put with the current mesh's shardings."""
        if step is None:
            step = self.latest_step()
        assert step is not None, "no checkpoint found"
        d = self.dir / f"step_{step:09d}"
        manifest = json.loads((d / "manifest.json").read_text())["leaves"]
        flat_t, treedef = jax.tree_util.tree_flatten_with_path(state_template)
        sh_leaves = (jax.tree.leaves(shardings,
                                     is_leaf=lambda x: x is None or hasattr(x, "spec"))
                     if shardings is not None else [None] * len(flat_t))
        leaves = []
        for (path, tmpl), sh in zip(flat_t, sh_leaves):
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                           for p in path)
            arr = np.load(d / manifest[key]["file"])
            if "bfloat16" in manifest[key]["dtype"]:
                import ml_dtypes
                arr = arr.view(ml_dtypes.bfloat16)
            if hasattr(tmpl, "dtype") and arr.dtype != tmpl.dtype:
                arr = jax.numpy.asarray(arr).astype(tmpl.dtype)
            if sh is not None:
                leaves.append(jax.device_put(arr, sh))
            else:
                leaves.append(jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, leaves)
