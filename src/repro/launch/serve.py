"""Serving launcher — both modes run on the unified serving engine.

Two modes, one shape (repro.serving): a fixed slot pool owned by an
`Engine`, advanced by one fused (vmapped) step, with per-connection
`Session` handles streaming input in and output out:

  * --mode lm  : batched LM serving for any --arch (tiny configs on CPU):
                 an `LmEngine` slot pool with PER-SLOT cache positions,
                 so staggered admissions with unequal prompt lengths
                 decode correctly; each serve step is one fused
                 decode_step over all slots.
  * --mode asr : the paper's system as an `AsrEngine` — sessions stream
                 80 ms audio chunks via Session.push/poll/finish; with
                 --streams N > 1 the N-slot pool decodes N concurrent
                 utterances through one vmapped decoding step
                 (continuous batching, like --mode lm).

  PYTHONPATH=src python -m repro.launch.serve --mode asr --utterances 3
  PYTHONPATH=src python -m repro.launch.serve --mode asr --streams 4
  PYTHONPATH=src python -m repro.launch.serve --mode lm --arch mamba2-1.3b \
      --requests 8 --max-new 32
  XLA_FLAGS=--xla_force_host_platform_device_count=2 PYTHONPATH=src \
      python -m repro.launch.serve --mode asr --streams 4 --mesh 2
  XLA_FLAGS=--xla_force_host_platform_device_count=4 PYTHONPATH=src \
      python -m repro.launch.serve --mode asr --streams 4 --mesh 2x2

`--mesh N` runs the ASR fused step model-parallel: every TDS FC/head
weight is sharded over N devices on its feature axis and the step runs
under shard_map (partial-sum + all-reduce per matmul) — each device
reads 1/N of the FC weight bytes per window, the lever the flat B=1
`rtf_measured_step` is bound by (see ROADMAP).  `--mesh RxC` makes the
mesh 2D ('data', 'model'): the slot pool shards over the R-way 'data'
axis (each data shard decodes n_slots/R slots end-to-end — beam
expansion is slot-parallel, so no 'data' collectives) while weights
shard over the C-way 'model' axis, the layout that scales serve
throughput with device count.  `--overlap-psum` chunks the model-axis
all-reduces so they hide under the next chunk's matmul.  Transcripts
are parity-tested against the unsharded engine
(tests/test_sharded_serving).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.kernels.policy import KernelPolicy
from repro.launch.steps import build_lm
from repro.serving import (AsrEngine, AsrProgram, EngineConfig, LmEngine,
                           LmProgram)


def _policy(args) -> KernelPolicy:
    return KernelPolicy(args.kernels)


def serve_mesh(spec):
    """`--mesh` spec -> a serving Mesh, or None for the exact unsharded
    single-device step.

      * N (int or "N")  : 1-axis ('model',) mesh over N devices — PR 5's
                          feature-axis weight sharding; N <= 1 -> None.
      * "RxC"           : 2-axis ('data', 'model') mesh over R*C devices
                          — the slot pool shards over the R-way 'data'
                          axis, FC/head weights over the C-way 'model'
                          axis.  "1x1" -> a real 1x1 mesh (exercises the
                          2D step path on one device).

    On a CPU host the devices come from
    XLA_FLAGS=--xla_force_host_platform_device_count (set it BEFORE the
    process starts; jax locks the device count at first use)."""
    def _need(n, what):
        if jax.device_count() < n:
            raise SystemExit(
                f"--mesh {what} needs {n} devices but jax sees "
                f"{jax.device_count()}; on a CPU host prefix the command "
                f"with XLA_FLAGS=--xla_force_host_platform_device_count"
                f"={n}")

    if isinstance(spec, str) and "x" in spec:
        try:
            r, c = (int(v) for v in spec.split("x"))
        except ValueError:
            raise SystemExit(f"--mesh {spec!r}: expected N or RxC")
        if r < 1 or c < 1:
            raise SystemExit(f"--mesh {spec!r}: axes must be >= 1")
        _need(r * c, spec)
        return jax.make_mesh((r, c), ("data", "model"))
    n_model = int(spec)
    if n_model <= 1:
        return None
    _need(n_model, n_model)
    return jax.make_mesh((n_model,), ("model",))


def serve_lm(args):
    cfg = get_config(args.arch).tiny()
    lm = build_lm(cfg, None)
    params = lm.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    # vary prompt lengths so bucketed admission is exercised (one
    # masked multi-row prefill jit entry per bucket, not per length)
    plens = [max(1, args.prompt_len - (i % 4)) for i in range(args.requests)]
    prompts = [rng.integers(1, cfg.vocab_size, n) for n in plens]

    program = LmProgram(cfg, cache_len=args.prompt_len + args.max_new,
                        max_new=args.max_new)
    engine = LmEngine(EngineConfig(program, n_slots=args.slots,
                                   kernels=_policy(args)), params)

    t0 = time.time()
    outputs = engine.serve(prompts)
    dt = time.time() - t0
    total_tokens = sum(len(v) for v in outputs)
    print(f"served {len(outputs)} requests, {total_tokens} tokens, "
          f"{engine.n_steps} decode steps in {dt:.2f}s "
          f"({total_tokens/dt:.1f} tok/s); "
          f"{engine.prefill_cache_entries()} prefill jit entries over "
          f"buckets {program.buckets()}")
    return dict(enumerate(outputs))


def asr_demo_system():
    """Small-TDS ASR system shared by the asr serving paths and
    benchmarks/run.py (public: external harnesses build on it)."""
    from repro.configs.tds_asr import DECODER_CONFIG, TDSConfig, TDSStage
    from repro.core import lexicon as lx
    from repro.models import tds

    # small TDS so it runs fast on CPU; same kernel structure
    tds_cfg = TDSConfig(
        stages=(TDSStage(1, 4, 80, 9, 2), TDSStage(1, 4, 80, 9, 2),
                TDSStage(1, 6, 80, 9, 2)),
        vocab_size=32)
    words = {f"w{i}": [1 + (i * 3 + j) % 30 for j in range(2 + i % 3)]
             for i in range(12)}
    lex = lx.build_lexicon(words, max_children=16)
    lm = lx.uniform_bigram(len(words))
    params = tds.init_tds(jax.random.PRNGKey(0), tds_cfg)
    return tds_cfg, words, lex, lm, params, DECODER_CONFIG


def asr_demo_engine(n_slots: int, kernels: KernelPolicy = None,
                    mesh=None, max_queue=None,
                    overlap_psum: bool = False,
                    session_deadline=None, worker_watchdog=None,
                    faults=None) -> tuple:
    """(engine, words): an AsrEngine over the demo system's program.
    `mesh` (see `serve_mesh`) shards the TDS FC/head weights over its
    'model' axis — and, with a 'data' axis, the slot pool — running the
    fused step under shard_map; `overlap_psum` enables the
    latency-hiding psum split on the sharded contractions; `max_queue`
    is the admission backpressure bound (`EngineConfig.max_queue`);
    `session_deadline`/`worker_watchdog`/`faults` are the
    fault-tolerance knobs (see README "Fault tolerance")."""
    tds_cfg, words, lex, lm, params, dec_cfg = asr_demo_system()
    program = AsrProgram(tds_cfg, lex, lm, dec_cfg=dec_cfg,
                        ).with_beam_width(25.0)
    engine = AsrEngine(EngineConfig(program, n_slots=n_slots,
                                    kernels=kernels or KernelPolicy(),
                                    mesh=mesh, max_queue=max_queue,
                                    overlap_psum=overlap_psum,
                                    session_deadline=session_deadline,
                                    worker_watchdog=worker_watchdog,
                                    faults=faults),
                       params)
    return engine, words


def serve_asr(args):
    """Single-stream streaming ASR: one Session per utterance, pushing
    80 ms chunks; poll() tracks the live best hypothesis."""
    from repro.data.pipeline import SyntheticASR

    engine, words = asr_demo_engine(1, _policy(args), serve_mesh(args.mesh),
                                    overlap_psum=args.overlap_psum)
    data = SyntheticASR(words)
    spp = engine.plan.samples_per_step
    n_utts = 2 if args.utterances is None else args.utterances
    for u in range(n_utts):
        utt = data.utterance(u)
        t0 = time.time()
        audio = utt["audio"]
        session = engine.open()
        # stream in 80ms chunks — one push per chunk, poll for live best
        for off in range(0, len(audio), spp):
            session.push(audio[off:off + spp])
            session.poll()
        best = session.finish()
        dt = time.time() - t0
        rtf = dt / (len(audio) / 16000)
        print(f"utt {u}: {len(audio)/16000:.2f}s audio, decoded in {dt:.2f}s "
              f"(RTF {rtf:.2f}), steps={best['steps']}, "
              f"best words={best['words'].tolist()} score={best['score']:.2f} "
              f"(ref={utt['words'].tolist()})")


def serve_asr_multistream(args):
    """Multi-stream ASR serving: a B-slot pool of concurrent utterance
    streams, one vmapped/jitted decoding step advancing all active slots
    (continuous batching, mirroring serve_lm's slot pool)."""
    from repro.data.pipeline import SyntheticASR

    engine, words = asr_demo_engine(args.streams, _policy(args),
                                    serve_mesh(args.mesh),
                                    overlap_psum=args.overlap_psum)
    data = SyntheticASR(words)
    # default: one utterance per slot; an explicit --utterances wins
    # (fewer than --streams just leaves the extra slots masked idle)
    n_utts = args.utterances if args.utterances is not None \
        else max(args.streams, 2)
    utts = [data.utterance(u) for u in range(n_utts)]
    audio_s = sum(len(u["audio"]) for u in utts) / 16000
    t0 = time.time()
    results = engine.serve([u["audio"] for u in utts])
    dt = time.time() - t0
    for u, (utt, best) in enumerate(zip(utts, results)):
        print(f"utt {u}: {len(utt['audio'])/16000:.2f}s audio, "
              f"steps={best['steps']}, best words={best['words'].tolist()} "
              f"score={best['score']:.2f} (ref={utt['words'].tolist()})")
    print(f"served {n_utts} utterances ({audio_s:.2f}s audio) over "
          f"{args.streams} streams in {dt:.2f}s: "
          f"{engine.n_steps} vmapped decoding steps, "
          f"RTF {dt/audio_s:.2f}, throughput {audio_s/dt:.2f}x realtime")
    return results


def serve_network(args):
    """`--serve`: bind the asyncio network front-end over the demo
    engines (ASR always; plus a tiny LM engine) and serve until
    interrupted.  Each engine's step loop runs on its own EngineWorker
    thread, so sessions stream over HTTP chunked transfer while the
    fused steps batch across them (see repro.serving.server).

    SIGTERM/SIGINT trigger a graceful drain: the listener stops
    accepting, in-flight sessions run to their final result
    (bounded by --drain-timeout), then the workers stop — the contract
    a rolling restart behind a load balancer needs."""
    import asyncio
    import signal

    from repro.serving.server import EngineServer

    asr_engine, _ = asr_demo_engine(args.streams, _policy(args),
                                    serve_mesh(args.mesh),
                                    max_queue=args.max_queue,
                                    overlap_psum=args.overlap_psum,
                                    session_deadline=args.session_deadline,
                                    worker_watchdog=args.watchdog)
    lm_cfg = get_config(args.arch).tiny()
    lm = build_lm(lm_cfg, None)
    lm_program = LmProgram(lm_cfg, cache_len=args.prompt_len + args.max_new,
                           max_new=args.max_new)
    lm_engine = LmEngine(
        EngineConfig(lm_program, n_slots=args.slots, kernels=_policy(args),
                     max_queue=args.max_queue,
                     session_deadline=args.session_deadline,
                     worker_watchdog=args.watchdog),
        lm.init(jax.random.PRNGKey(0)))

    async def run():
        server = EngineServer(asr_engine=asr_engine, lm_engine=lm_engine,
                              host=args.host, port=args.port,
                              asr_idle_timeout=args.idle_timeout)
        await server.start()
        stop = asyncio.Event()
        loop = asyncio.get_running_loop()
        for sig in (signal.SIGTERM, signal.SIGINT):
            try:
                loop.add_signal_handler(sig, stop.set)
            except (NotImplementedError, RuntimeError):
                pass             # platform without loop signal handlers
        print(f"serving ASR ({args.streams} slots) + LM ({args.slots} "
              f"slots) on http://{server.host}:{server.port} "
              f"(max_queue={args.max_queue}, watchdog={args.watchdog}, "
              f"session_deadline={args.session_deadline}); POST /asr, "
              f"POST /lm, GET /metrics, GET /healthz")
        try:
            serve = asyncio.ensure_future(server.serve_forever())
            stopper = asyncio.ensure_future(stop.wait())
            await asyncio.wait({serve, stopper},
                               return_when=asyncio.FIRST_COMPLETED)
            serve.cancel()
            stopper.cancel()
            if stop.is_set():
                print("signal received: draining in-flight sessions ...")
        finally:
            await server.aclose(drain=True, timeout=args.drain_timeout)
            print("drained; server stopped")

    try:
        asyncio.run(run())
    except KeyboardInterrupt:
        pass


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="asr", choices=["lm", "asr"])
    ap.add_argument("--arch", default="mamba2-1.3b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--utterances", type=int, default=None,
                    help="ASR utterance count (default: 2, or one per "
                         "slot when --streams > 1)")
    ap.add_argument("--streams", type=int, default=1,
                    help="ASR slot-pool size; >1 uses the batched "
                         "multi-stream scheduler")
    ap.add_argument("--kernels", default="auto",
                    choices=["auto", "ref", "interpret", "mosaic"],
                    help="KernelPolicy mode for Pallas-backed decode ops "
                         "(auto: Mosaic on TPU, ref for the hot path on "
                         "CPU)")
    ap.add_argument("--mesh", type=str, default="1", metavar="N|RxC",
                    help="ASR parallel spec: N shards every TDS FC/head "
                         "weight over N devices ('model' mesh axis) and "
                         "runs the fused step under shard_map; RxC "
                         "additionally shards the slot pool over an "
                         "R-way 'data' axis (C-way 'model'), so "
                         "throughput scales with R; 1 = the unsharded "
                         "single-device step (on CPU hosts set "
                         "XLA_FLAGS="
                         "--xla_force_host_platform_device_count=R*C "
                         "first)")
    ap.add_argument("--overlap-psum", action="store_true",
                    help="sharded ASR step: chunk each model-axis "
                         "all-reduce so it overlaps the next chunk's "
                         "local matmul (async-collective backends; "
                         "numerical ~1e-6 parity with the default "
                         "synchronous psum)")
    ap.add_argument("--serve", action="store_true",
                    help="run the asyncio network front-end (HTTP "
                         "chunked streaming over the demo ASR + LM "
                         "engines) instead of the in-process demos")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8300,
                    help="--serve listen port (0 picks a free port)")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="admission backpressure bound: with every slot "
                         "busy and this many sessions queued, new "
                         "sessions get HTTP 503 (default: unbounded)")
    ap.add_argument("--watchdog", type=float, default=None,
                    help="--serve: seconds an engine worker's heartbeat "
                         "may age before the supervisor declares it "
                         "wedged and restarts it (default: only DEAD "
                         "threads restart)")
    ap.add_argument("--session-deadline", type=float, default=None,
                    help="--serve: seconds a session may live from "
                         "open() before the pump reaps it "
                         "(DeadlineExceeded; default: no deadline)")
    ap.add_argument("--idle-timeout", type=float, default=None,
                    help="--serve: seconds /asr waits for the next "
                         "command chunk before freeing a silent "
                         "client's slot (default: wait forever)")
    ap.add_argument("--drain-timeout", type=float, default=30.0,
                    help="--serve: bound on the SIGTERM graceful drain "
                         "(seconds; in-flight sessions finishing)")
    args = ap.parse_args(argv)
    if args.serve:
        return serve_network(args)
    if args.mode == "lm":
        if args.mesh not in ("1", "0"):
            ap.error("--mesh is ASR-only (LmEngine rejects a mesh; "
                     "sharded LM serving goes through launch/steps.py "
                     "build_cell)")
        return serve_lm(args)
    if args.streams > 1:
        return serve_asr_multistream(args)
    return serve_asr(args)


if __name__ == "__main__":
    main()
