"""Serving launcher — batched request decoding, ASRPU-style decoding steps.

Two modes:
  * --mode lm  : batched LM serving for any --arch (tiny configs on CPU):
                 slot-based continuous batching — a fixed (batch, cache)
                 pool; finished sequences free their slot for queued
                 requests; every serve step is one fused decode_step.
  * --mode asr : the paper's system — streaming ASR through the ASRPU
                 command API (configure -> DecodingStep* -> CleanDecoding).
                 With --streams N > 1, a MultiStreamASRPU slot pool
                 decodes N concurrent utterances through one vmapped
                 decoding step (continuous batching, like --mode lm).

  PYTHONPATH=src python -m repro.launch.serve --mode asr --utterances 3
  PYTHONPATH=src python -m repro.launch.serve --mode asr --streams 4
  PYTHONPATH=src python -m repro.launch.serve --mode lm --arch mamba2-1.3b \
      --requests 8 --max-new 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.steps import build_lm


def serve_lm(args):
    cfg = get_config(args.arch).tiny()
    lm = build_lm(cfg, None)
    params = lm.init(jax.random.PRNGKey(0))
    B = args.slots
    cache_len = args.prompt_len + args.max_new
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size, args.prompt_len)
               for _ in range(args.requests)]

    # slot pool
    queue = list(enumerate(prompts))
    active = {}           # slot -> (request_id, generated list, remaining)
    outputs = {}
    cache = lm.init_cache(B, cache_len)
    tokens = jnp.zeros((B, 1), jnp.int32)

    jit_decode = jax.jit(lm.decode_step)
    jit_prefill = jax.jit(lm.prefill)

    # simple admission: prefill each request individually into its slot
    # (a production server batches prefills; slot writes are exact here)
    def admit(slot, rid, prompt):
        nonlocal cache, tokens
        logits, pc = jit_prefill(params, {"tokens": jnp.asarray(prompt)[None]})
        # write prompt KV into the pooled cache at this slot
        def put(dst, src):
            if dst.ndim >= 3 and src.shape[2] <= dst.shape[2]:
                return dst.at[:, slot:slot+1, :src.shape[2]].set(
                    src.astype(dst.dtype))
            return dst.at[:, slot:slot+1].set(src.astype(dst.dtype))
        cache["layers"] = jax.tree.map(put, cache["layers"], pc["layers"])
        cache["kpos"] = jnp.maximum(cache["kpos"],
                                    jnp.arange(cache_len) *
                                    (jnp.arange(cache_len) < args.prompt_len))
        cache["kpos"] = cache["kpos"].at[:args.prompt_len].set(
            jnp.arange(args.prompt_len))
        cache["offset"] = jnp.full((), args.prompt_len, jnp.int32)
        first = int(jnp.argmax(logits[0, :cfg.vocab_size]))
        tokens = tokens.at[slot, 0].set(first)
        active[slot] = (rid, [first], args.max_new - 1)

    t0 = time.time()
    n_steps = 0
    while queue or active:
        for slot in range(B):
            if slot not in active and queue:
                rid, prompt = queue.pop(0)
                admit(slot, rid, prompt)
        _, tok, cache = jit_decode(params, cache, {"tokens": tokens})
        n_steps += 1
        tokens = tok[:, None]
        done = []
        for slot, (rid, gen, rem) in active.items():
            gen.append(int(tok[slot]))
            rem -= 1
            active[slot] = (rid, gen, rem)
            if rem <= 0:
                outputs[rid] = gen
                done.append(slot)
        for slot in done:
            del active[slot]
    dt = time.time() - t0
    total_tokens = sum(len(v) for v in outputs.values())
    print(f"served {len(outputs)} requests, {total_tokens} tokens, "
          f"{n_steps} decode steps in {dt:.2f}s "
          f"({total_tokens/dt:.1f} tok/s)")
    return outputs


def asr_demo_system():
    """Small-TDS ASR system shared by the asr serving paths and
    benchmarks/run.py (public: external harnesses build on it)."""
    from repro.configs.tds_asr import DECODER_CONFIG, TDSConfig, TDSStage
    from repro.core import lexicon as lx
    from repro.models import tds

    # small TDS so it runs fast on CPU; same kernel structure
    tds_cfg = TDSConfig(
        stages=(TDSStage(1, 4, 80, 9, 2), TDSStage(1, 4, 80, 9, 2),
                TDSStage(1, 6, 80, 9, 2)),
        vocab_size=32)
    words = {f"w{i}": [1 + (i * 3 + j) % 30 for j in range(2 + i % 3)]
             for i in range(12)}
    lex = lx.build_lexicon(words, max_children=16)
    lm = lx.uniform_bigram(len(words))
    params = tds.init_tds(jax.random.PRNGKey(0), tds_cfg)
    return tds_cfg, words, lex, lm, params, DECODER_CONFIG


def configure_asrpu(asrpu, tds_cfg, lex, lm, dec_cfg, params):
    asrpu.configure_acoustic_scoring(tds_cfg, params)
    asrpu.configure_hyp_expansion(lex, lm, dec_cfg)
    asrpu.configure_beam_width(25.0)


def serve_asr(args):
    from repro.core.scheduler import ASRPU
    from repro.data.pipeline import SyntheticASR

    tds_cfg, words, lex, lm, params, dec_cfg = asr_demo_system()
    asrpu = ASRPU()
    configure_asrpu(asrpu, tds_cfg, lex, lm, dec_cfg, params)

    data = SyntheticASR(words)
    spp = asrpu.plan.samples_per_step
    n_utts = 2 if args.utterances is None else args.utterances
    for u in range(n_utts):
        utt = data.utterance(u)
        asrpu.clean_decoding()
        t0 = time.time()
        audio = utt["audio"]
        # stream in 80ms chunks — one DecodingStep command per chunk
        for off in range(0, len(audio), spp):
            best = asrpu.decoding_step(audio[off:off + spp])
        dt = time.time() - t0
        rtf = dt / (len(audio) / 16000)
        print(f"utt {u}: {len(audio)/16000:.2f}s audio, decoded in {dt:.2f}s "
              f"(RTF {rtf:.2f}), steps={asrpu._n_steps}, "
              f"best words={best['words'].tolist()} score={best['score']:.2f} "
              f"(ref={utt['words'].tolist()})")


def serve_asr_multistream(args):
    """Multi-stream ASR serving: a B-slot pool of concurrent utterance
    streams, one vmapped/jitted decoding step advancing all active slots
    (continuous batching, mirroring serve_lm's slot pool)."""
    from repro.core.scheduler import MultiStreamASRPU
    from repro.data.pipeline import SyntheticASR

    tds_cfg, words, lex, lm, params, dec_cfg = asr_demo_system()
    asrpu = MultiStreamASRPU(args.streams)
    configure_asrpu(asrpu, tds_cfg, lex, lm, dec_cfg, params)

    data = SyntheticASR(words)
    # default: one utterance per slot; an explicit --utterances wins
    # (fewer than --streams just leaves the extra slots masked idle)
    n_utts = args.utterances if args.utterances is not None \
        else max(args.streams, 2)
    utts = [data.utterance(u) for u in range(n_utts)]
    audio_s = sum(len(u["audio"]) for u in utts) / 16000
    t0 = time.time()
    results = asrpu.serve([u["audio"] for u in utts])
    dt = time.time() - t0
    for u, (utt, best) in enumerate(zip(utts, results)):
        print(f"utt {u}: {len(utt['audio'])/16000:.2f}s audio, "
              f"steps={best['steps']}, best words={best['words'].tolist()} "
              f"score={best['score']:.2f} (ref={utt['words'].tolist()})")
    print(f"served {n_utts} utterances ({audio_s:.2f}s audio) over "
          f"{args.streams} streams in {dt:.2f}s: "
          f"{asrpu._n_steps} vmapped decoding steps, "
          f"RTF {dt/audio_s:.2f}, throughput {audio_s/dt:.2f}x realtime")
    return results


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="asr", choices=["lm", "asr"])
    ap.add_argument("--arch", default="mamba2-1.3b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--utterances", type=int, default=None,
                    help="ASR utterance count (default: 2, or one per "
                         "slot when --streams > 1)")
    ap.add_argument("--streams", type=int, default=1,
                    help="ASR slot-pool size; >1 uses the vmapped "
                         "multi-stream scheduler")
    args = ap.parse_args(argv)
    if args.mode == "lm":
        return serve_lm(args)
    if args.streams > 1:
        return serve_asr_multistream(args)
    return serve_asr(args)


if __name__ == "__main__":
    main()
