"""Step builders: jitted train_step / prefill / decode with full shardings.

These are what both the real launchers (train.py / serve.py) and the
multi-pod dry-run (dryrun.py) lower — one source of truth for the
production computation + sharding.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeSpec
from repro.models.transformer import LM
from repro.optim import adamw
from repro.parallel import sharding as shlib


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; no allocation)
# ---------------------------------------------------------------------------
def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """Model inputs for one workload cell, as ShapeDtypeStructs.

    train/prefill: full (B, S); decode: one new token (B, 1) —
    the KV/SSM cache is a separate argument (see cache_specs).
    [audio]/[vlm] archs get precomputed frame/patch embeddings (stub
    frontend per brief) instead of token ids.
    """
    B = shape.global_batch
    S = 1 if shape.is_decode else shape.seq_len
    sds = jax.ShapeDtypeStruct
    batch = {}
    if cfg.embed_inputs:
        batch["tokens"] = sds((B, S), jnp.int32)
    else:
        batch["embeds"] = sds((B, S, cfg.d_model), jnp.dtype(cfg.dtype))
    if shape.kind == "train":
        batch["labels"] = sds((B, S), jnp.int32)
    return batch


def cache_specs(lm: LM, shape: ShapeSpec):
    return jax.eval_shape(
        functools.partial(lm.init_cache, shape.global_batch, shape.seq_len))


def opt_shardings(param_sharding_tree):
    """Moment trees share the parameter sharding; int8 scale blocks too
    (same spec with the last dim replicated is handled by the safety net
    in the rules — here moments are same-shape so specs transfer 1:1)."""
    def f(ps):
        return ps
    return {"m": jax.tree.map(f, param_sharding_tree),
            "v": jax.tree.map(f, param_sharding_tree),
            "count": None}


# ---------------------------------------------------------------------------
# builders
# ---------------------------------------------------------------------------
def build_lm(cfg: ModelConfig, mesh: Optional[Mesh]) -> LM:
    return LM(cfg, shlib.Sharder(mesh))


def make_train_step(lm: LM, opt_cfg: adamw.AdamWConfig, *, remat=True,
                    accum: int = 1, accum_dtype=jnp.float32):
    """accum > 1: microbatched gradient accumulation (scan over accum
    microbatches; grad buffer in parameter sharding).  Divides the
    per-step activation-residual footprint by `accum` at equal FLOPs.
    accum_dtype=bf16 halves the buffer for >100B-param models (the fp32
    buffer alone is 12 GB/dev for llama4-400b on 256 chips)."""
    def train_step(state, batch):
        if accum == 1:
            def lf(p):
                return lm.loss_fn(p, batch, remat=remat)
            (_, metrics), grads = jax.value_and_grad(lf, has_aux=True)(
                state["params"])
        else:
            mb = jax.tree.map(
                lambda a: a.reshape((accum, a.shape[0] // accum)
                                    + a.shape[1:]), batch)

            def mstep(gsum, b):
                def lf(p):
                    return lm.loss_fn(p, b, remat=remat)
                (_, met), g = jax.value_and_grad(lf, has_aux=True)(
                    state["params"])
                gsum = jax.tree.map(
                    lambda s, x: s + x.astype(accum_dtype), gsum, g)
                return gsum, met

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, accum_dtype),
                              state["params"])
            grads, mets = jax.lax.scan(mstep, g0, mb)
            grads = jax.tree.map(lambda g: g / accum, grads)
            metrics = jax.tree.map(lambda m: m[-1], mets)
        new_p, new_opt = adamw.update(grads, state["opt"], state["params"],
                                      opt_cfg)
        metrics = dict(metrics, step=state["step"] + 1)
        return {"params": new_p, "opt": new_opt,
                "step": state["step"] + 1}, metrics
    return train_step


def make_prefill(lm: LM):
    def prefill(params, batch):
        return lm.prefill(params, batch)
    return prefill


def make_decode_step(lm: LM):
    def decode_step(params, cache, batch):
        logits, tok, new_cache = lm.decode_step(params, cache, batch)
        return tok, new_cache
    return decode_step


# ---------------------------------------------------------------------------
# jitted + sharded assembly for one (cfg, shape, mesh) cell
# ---------------------------------------------------------------------------
def default_accum(cfg: ModelConfig, shape: ShapeSpec) -> int:
    """Microbatching policy for the production cells: accumulate just
    enough that the residual stack fits v5e HBM (16 GB/chip).  Every
    extra microbatch re-pays the ZeRO-3/FSDP weight all-gathers (the
    dominant collective term for big dense trains), so this is minimized,
    not maximized."""
    if shape.kind != "train":
        return 1
    n = cfg.param_counts()["total"]
    if n > 100e9:
        return 8          # llama4-class: capacity-floor cells (see §Perf)
    if cfg.moe is not None or n > 60e9:
        return 4          # accum=2 overruns HBM for 72b (18.3 GiB, §Perf)
    if n > 20e9:
        return 2
    return 1


def build_cell(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh, *,
               opt_cfg: Optional[adamw.AdamWConfig] = None, remat=True,
               accum: Optional[int] = None):
    """Returns (jitted_fn, abstract_args) ready to .lower(*abstract_args)."""
    lm = build_lm(cfg, mesh)
    p_shapes = lm.param_shapes()
    p_sh = shlib.param_shardings(cfg, p_shapes, mesh)
    batch_shapes = input_specs(cfg, shape)
    b_sh = shlib.batch_shardings(batch_shapes, mesh)
    repl = NamedSharding(mesh, P())

    if shape.kind == "train":
        if opt_cfg is None:
            # int8 moments for very large models (fits HBM), fp32 otherwise
            big = cfg.param_counts()["total"] > 100e9
            opt_cfg = adamw.AdamWConfig(
                moment_dtype="int8" if big else "float32")
        opt_shapes = jax.eval_shape(
            functools.partial(adamw.init, cfg=opt_cfg), p_shapes)
        o_sh = _opt_shardings_like(cfg, opt_shapes, mesh)
        state_shapes = {"params": p_shapes, "opt": opt_shapes,
                        "step": jax.ShapeDtypeStruct((), jnp.int32)}
        state_sh = {"params": p_sh, "opt": o_sh, "step": repl}
        if accum is None:
            accum = default_accum(cfg, shape)
        accum_dtype = (jnp.bfloat16 if cfg.param_counts()["total"] > 100e9
                       else jnp.float32)
        fn = make_train_step(build_lm(cfg, mesh), opt_cfg, remat=remat,
                             accum=accum, accum_dtype=accum_dtype)
        jfn = jax.jit(fn, in_shardings=(state_sh, b_sh),
                      out_shardings=(state_sh, None), donate_argnums=(0,))
        return jfn, (state_shapes, batch_shapes)

    # serving cells run on int8 weights (the paper's 8-bit MAC serving
    # story): 4x less HBM residency and 4x less FSDP-gather wire
    import os
    from repro.models import layers as L
    int8_serving = os.environ.get("REPRO_BASELINE", "0") != "1"
    if int8_serving:
        p_shapes = jax.eval_shape(L.quantize_params_for_serving, p_shapes)
        p_sh = shlib.param_shardings(cfg, p_shapes, mesh)

    if shape.kind == "prefill":
        c_shapes = jax.eval_shape(
            functools.partial(lm.init_cache, shape.global_batch,
                              shape.seq_len))
        c_sh = shlib.cache_shardings(cfg, c_shapes, mesh, shape.global_batch)
        fn = make_prefill(lm)
        jfn = jax.jit(fn, in_shardings=(p_sh, b_sh),
                      out_shardings=(repl, c_sh))
        return jfn, (p_shapes, batch_shapes)

    # decode
    c_shapes = cache_specs(lm, shape)
    c_sh = shlib.cache_shardings(cfg, c_shapes, mesh, shape.global_batch)
    fn = make_decode_step(lm)
    jfn = jax.jit(fn, in_shardings=(p_sh, c_sh, b_sh),
                  out_shardings=(None, c_sh), donate_argnums=(1,))
    return jfn, (p_shapes, c_shapes, batch_shapes)


def _opt_shardings_like(cfg, opt_shapes, mesh):
    """Sharding tree for adamw state: moments inherit parameter rules by
    path (the 'm'/'v' prefix and any trailing 'q'/'scale' are stripped)."""
    from jax.tree_util import tree_map_with_path

    def f(path, leaf):
        names = [getattr(k, "key", str(k)) for k in path]
        if names and names[0] in ("m", "v"):
            names = names[1:]
        if names and names[-1] in ("q", "scale") and leaf.ndim >= 1:
            # int8 moment payload/scale: payload shares param spec; scale
            # shares it with the last dim replicated (handled by safety net)
            core = names[:-1]
        else:
            core = names
        spec = shlib._param_rule(_FakePath(core), leaf.shape, cfg, mesh) \
            if core else P()
        return NamedSharding(mesh, spec)
    return tree_map_with_path(f, opt_shapes)


class _FakePath(tuple):
    """List of objects exposing .key so _param_rule can consume plain names."""
    def __new__(cls, names):
        return super().__new__(cls, [_K(n) for n in names])


class _K:
    def __init__(self, key):
        self.key = key
