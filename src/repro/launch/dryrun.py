import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any other import (jax locks the device
count at first init); 512 placeholder host devices back the production
meshes.  Nothing here allocates device memory: all inputs are
ShapeDtypeStructs and we stop at .lower().compile().

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                  # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-72b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --mesh multi_pod
Artifacts land in artifacts/dryrun/<arch>__<shape>__<mesh>.json and feed
EXPERIMENTS.md §Dry-run / §Roofline.
"""

import argparse
import json
import pathlib
import time
import traceback


from repro.configs import ASSIGNED_ARCHS, LM_SHAPES, get_config
from repro.launch import roofline as rl
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_cell

ART = pathlib.Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def run_cell(arch: str, shape_name: str, mesh_name: str, *,
             save: bool = True, extra: dict | None = None,
             baseline: bool = False) -> dict:
    if baseline:
        os.environ["REPRO_BASELINE"] = "1"
        mesh_name_out = mesh_name + "_baseline"
    else:
        os.environ.pop("REPRO_BASELINE", None)
        mesh_name_out = mesh_name
    cfg = get_config(arch)
    shape = {s.name: s for s in LM_SHAPES}[shape_name]
    if shape in cfg.skipped_shapes():
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name_out,
               "status": "skipped",
               "reason": "full-attention arch; long_500k requires "
                         "sub-quadratic attention (see DESIGN.md)"}
        if save:
            _save(rec)
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_name == "multi_pod"))
    n_dev = mesh.size
    t0 = time.time()
    try:
        jfn, args = build_cell(cfg, shape, mesh)
        with mesh:
            lowered = jfn.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
            hlo = compiled.as_text()
            roof = rl.analyze(compiled, n_dev, rl.model_flops(cfg, shape),
                              hlo_text=hlo)
            from repro.launch import hlo_cost as _hc
            coll = dict(_hc.analyze_hlo(hlo, n_dev).coll)
            coll["total"] = sum(coll.values())
            coll["counts"] = {}
        rec = {
            "arch": arch, "shape": shape_name, "mesh": mesh_name_out,
            "status": "ok", "n_devices": n_dev,
            "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
            "memory_analysis": _mem_dict(mem),
            "roofline": roof.asdict(),
            "collectives": {k: v for k, v in coll.items() if k != "counts"},
            "collective_counts": coll["counts"],
        }
    except Exception as e:  # a failure here is a bug in our sharding
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name_out,
               "status": "FAIL", "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:]}
    if extra:
        rec.update(extra)
    if save:
        _save(rec)
    return rec


def _mem_dict(mem) -> dict:
    if mem is None:
        return {}
    out = {}
    for f in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        v = getattr(mem, f, None)
        if v is not None:
            out[f] = int(v)
    if out:
        out["total_hbm_bytes_per_device"] = (
            out.get("argument_size_in_bytes", 0)
            + out.get("output_size_in_bytes", 0)
            + out.get("temp_size_in_bytes", 0)
            - out.get("alias_size_in_bytes", 0))
    return out


def _save(rec: dict):
    ART.mkdir(parents=True, exist_ok=True)
    name = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}.json"
    (ART / name).write_text(json.dumps(rec, indent=1))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="arch id (default: all)")
    ap.add_argument("--shape", default=None, help="shape name (default: all)")
    ap.add_argument("--mesh", default=None,
                    choices=[None, "single_pod", "multi_pod"])
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--baseline", action="store_true",
                    help="disable hillclimb layout optimizations; saves "
                         "to *_<mesh>_baseline.json")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ASSIGNED_ARCHS)
    shapes = [args.shape] if args.shape else [s.name for s in LM_SHAPES]
    meshes = [args.mesh] if args.mesh else ["single_pod", "multi_pod"]

    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mesh in meshes:
                out = ART / f"{arch}__{shape}__{mesh}.json"
                if args.skip_existing and out.exists():
                    old = json.loads(out.read_text())
                    if old.get("status") in ("ok", "skipped"):
                        print(f"[skip-existing] {arch} {shape} {mesh}")
                        continue
                t0 = time.time()
                rec = run_cell(arch, shape, mesh, baseline=args.baseline)
                dt = time.time() - t0
                status = rec["status"]
                n_fail += status == "FAIL"
                msg = f"[{status}] {arch} {shape} {mesh} ({dt:.0f}s)"
                if status == "ok":
                    r = rec["roofline"]
                    hbm = rec["memory_analysis"].get(
                        "total_hbm_bytes_per_device", 0) / 2**30
                    msg += (f" bottleneck={r['bottleneck']}"
                            f" t=({r['t_compute']:.3f},{r['t_memory']:.3f},"
                            f"{r['t_collective']:.3f})s hbm={hbm:.2f}GiB")
                elif status == "FAIL":
                    msg += " " + rec["error"][:300]
                print(msg, flush=True)
    print(f"done. failures={n_fail}")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
