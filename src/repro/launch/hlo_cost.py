"""Loop-corrected cost extraction from post-SPMD HLO text.

XLA's `compiled.cost_analysis()` counts a while-loop body ONCE, regardless
of trip count — with layers lax.scan'ned and gradient accumulation, that
undercounts FLOPs by 1-3 orders of magnitude.  This walker parses
`compiled.as_text()` (the per-device program) and computes:

  flops       — 2 * prod(out_shape) * contraction for every `dot`
                (batch dims included via out_shape); `while` bodies are
                multiplied by their `known_trip_count` backend_config.
  bytes       — HBM traffic model: for every top-level instruction that
                reads/writes buffers (fusion, dot, copy, collectives,
                dynamic-(update-)slice, sort, ...), operand bytes +
                output bytes, times enclosing trip counts.  Fusion
                internals are NOT double counted (a fusion is one HBM
                round trip — that is the point of fusion).
  collectives — per-kind wire bytes with ring factors ((n-1)/n for
                AG/RS, 2(n-1)/n for AR, 1 for A2A/permute), n from
                replica_groups, times enclosing trip counts.

Used by launch/dryrun.py for the §Roofline terms; validated against
cost_analysis on loop-free programs (tests/test_hlo_cost.py).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COMP_START = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{")
# tuple types carry /*index=N*/ comments (with '=') but never nested parens
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(\([^()]*\)|[a-z0-9]+\[[0-9,]*\]\S*)\s*"
    r"([\w\-]+)\((.*)$")
_SHAPE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OPERAND = re.compile(r"%([\w\.\-]+)")
_TRIP = re.compile(r'known_trip_count[^0-9]*(\d+)')
_CALLS = re.compile(r"(?:calls|body|condition|to_apply)=%?([\w\.\-]+)")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS = re.compile(r"replica_groups=\{\{([^}]*)\}")
_LHS_C = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
_SKIP_BYTES = {"parameter", "constant", "get-tuple-element", "tuple",
               "bitcast", "iota", "after-all", "partition-id", "replica-id",
               "reshape", "broadcast", "convert", "transpose"}


def _shape_bytes(sig: str) -> int:
    total = 0
    for dt, dims in _SHAPE.findall(sig):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(sig: str):
    m = _SHAPE.search(sig)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: dict = field(default_factory=lambda: {k: 0.0 for k in COLLECTIVES})

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k in COLLECTIVES:
            self.coll[k] += other.coll[k] * mult

    @property
    def coll_total(self) -> float:
        return sum(self.coll.values())


def parse_computations(hlo: str) -> tuple:
    """Returns (name -> list of instruction dicts, entry_name | None)."""
    comps = {}
    entry = None
    cur = None
    for line in hlo.splitlines():
        m = _COMP_START.match(line.strip())
        if m and line.rstrip().endswith("{"):
            cur = m.group(2)
            if m.group(1):
                entry = cur
            comps[cur] = []
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        mi = _INSTR.match(line)
        if not mi:
            continue
        name, sig, op, rest = mi.groups()
        comps[cur].append({
            "name": name, "sig": sig, "op": op, "rest": rest,
        })
    return comps, entry


def _group_size(rest: str, default: int) -> int:
    m = _GROUPS_IOTA.search(rest)
    if m:
        return int(m.group(2))
    m = _GROUPS.search(rest)
    if m:
        toks = [t for t in m.group(1).split(",") if t.strip()]
        return max(1, len(toks))
    return default


def _dot_flops(instr: dict, symtab: dict) -> float:
    out_elems = 1
    for d in _shape_dims(instr["sig"]):
        out_elems *= d
    ops = _OPERAND.findall(instr["rest"].split("),")[0] + ")")
    lhs_sig = symtab.get(ops[0], "") if ops else ""
    lhs_dims = _shape_dims(lhs_sig)
    m = _LHS_C.search(instr["rest"])
    contract = 1
    if m and lhs_dims:
        for idx in m.group(1).split(","):
            if idx and int(idx) < len(lhs_dims):
                contract *= lhs_dims[int(idx)]
    return 2.0 * out_elems * contract


def analyze_hlo(hlo: str, default_group: int) -> Cost:
    comps, entry = parse_computations(hlo)
    symtabs = {c: {i["name"]: i["sig"] for i in instrs}
               for c, instrs in comps.items()}
    # add parameters to symtab (they match _INSTR as op == 'parameter')
    memo: dict = {}
    called = set()
    for instrs in comps.values():
        for i in instrs:
            for c in _CALLS.findall(i["rest"]):
                called.add(c)
    entries = [entry] if entry else [c for c in comps if c not in called]

    sliced_memo: dict = {}

    def _sliced_params(cname: str) -> dict:
        """param index -> slice bytes, for fused-computation parameters
        whose only consumers are dynamic-slice/gather ops."""
        if cname in sliced_memo:
            return sliced_memo[cname]
        out = {}
        if cname in comps:
            instrs = comps[cname]
            pidx = {}
            for i in instrs:
                if i["op"] == "parameter":
                    m = re.match(r"(\d+)", i["rest"])
                    if m:
                        pidx[i["name"]] = int(m.group(1))
            consumers: dict = {n: [] for n in pidx}
            for i in instrs:
                if i["op"] == "parameter":
                    continue
                for oname in _OPERAND.findall(i["rest"]):
                    if oname in consumers:
                        consumers[oname].append(i)
            for pname, idx in pidx.items():
                cons = consumers.get(pname, [])
                if cons and all(c["op"] in ("dynamic-slice", "gather")
                                and _OPERAND.findall(c["rest"])[:1] == [pname]
                                for c in cons):
                    out[idx] = sum(_shape_bytes(c["sig"]) for c in cons)
        sliced_memo[cname] = out
        return out

    def cost_of(cname: str, stack=()) -> Cost:
        if cname in memo:
            return memo[cname]
        if cname in stack or cname not in comps:
            return Cost()
        total = Cost()
        symtab = symtabs[cname]
        for instr in comps[cname]:
            op = instr["op"]
            callees = _CALLS.findall(instr["rest"])
            if op == "while":
                trip = 1
                m = _TRIP.search(instr["rest"])
                if m:
                    trip = int(m.group(1))
                sub = Cost()
                for c in callees:
                    sub.add(cost_of(c, stack + (cname,)))
                total.add(sub, trip)
                continue
            if op in ("call", "conditional", "async-start"):
                for c in callees:
                    total.add(cost_of(c, stack + (cname,)))
                continue
            if op == "fusion":
                # one HBM round trip + any dots inside (rare on TPU path).
                # Operands that the fused computation only *slices* count
                # as the slice, not the whole buffer (XLA fuses the
                # per-layer dynamic-slice of stacked weights/caches into
                # consumers — counting full operands overcounted decode
                # cells ~50x).
                total.bytes += _shape_bytes(instr["sig"])
                sliced = {}
                for c in callees:
                    sliced.update(_sliced_params(c))
                for idx, oname in enumerate(_OPERAND.findall(instr["rest"])):
                    if idx in sliced:
                        total.bytes += 2 * sliced[idx]
                    else:
                        total.bytes += _shape_bytes(symtab.get(oname, ""))
                for c in callees:
                    inner = cost_of(c, stack + (cname,))
                    total.flops += inner.flops
                    for k in COLLECTIVES:
                        total.coll[k] += inner.coll[k]
                continue
            if op == "dot":
                total.flops += _dot_flops(instr, symtab)
                total.bytes += _shape_bytes(instr["sig"])
                for oname in _OPERAND.findall(instr["rest"]):
                    total.bytes += _shape_bytes(symtab.get(oname, ""))
                continue
            base = op.replace("-start", "")
            if base in COLLECTIVES:
                nbytes = _shape_bytes(instr["sig"])
                n = _group_size(instr["rest"], default_group)
                if n > 1:
                    ring = (n - 1) / n
                    factor = {"all-gather": ring, "reduce-scatter": ring,
                              "all-reduce": 2 * ring, "all-to-all": ring,
                              "collective-permute": 1.0}[base]
                    total.coll[base] += nbytes * factor
                total.bytes += nbytes
                continue
            if op in _SKIP_BYTES or op.endswith("-done"):
                continue
            if op in ("dynamic-slice", "slice", "gather"):
                # reads only the sliced region, not the whole operand
                total.bytes += 2 * _shape_bytes(instr["sig"])
                continue
            if op in ("dynamic-update-slice", "scatter"):
                # reads + writes the update region (in-place on the buffer)
                ops_ = _OPERAND.findall(instr["rest"])
                upd = symtab.get(ops_[1], "") if len(ops_) > 1 else ""
                total.bytes += 2 * _shape_bytes(upd)
                continue
            # generic data-moving op (copy, sort, reduce, pad, ...)
            total.bytes += _shape_bytes(instr["sig"])
            for oname in _OPERAND.findall(instr["rest"])[:4]:
                total.bytes += _shape_bytes(symtab.get(oname, ""))
        memo[cname] = total
        return total

    out = Cost()
    if entries:
        # heuristically, the real entry is the largest root computation
        best = max(entries, key=lambda e: len(comps[e]))
        out = cost_of(best)
    return out
