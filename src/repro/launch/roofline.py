"""Roofline-term extraction from compiled dry-run artifacts.

TPU v5e constants (per chip):
  peak bf16 compute : 197 TFLOP/s
  HBM bandwidth     : 819 GB/s
  ICI               : ~50 GB/s per link

Conventions (documented in EXPERIMENTS.md):
  * `compiled.cost_analysis()` on an SPMD-partitioned executable reports
    the *per-device* program; we record per-device FLOPs/bytes and derive
    terms as per-device quantity / per-chip peak (equivalent to the
    global/(chips*peak) formulation).
  * collective bytes: the post-SPMD HLO is parsed; for each all-gather /
    all-reduce / reduce-scatter / all-to-all / collective-permute we take
    the per-device output tensor bytes times a ring-algorithm wire factor
    ((n-1)/n for AG/RS, 2(n-1)/n for AR with n = devices in the replica
    group when parseable, else the mesh size; 1.0 for A2A/permute).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, asdict

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?:\()?\s*((?:[a-z0-9]+\[[^\]]*\][^ )]*)(?:,\s*[a-z0-9]+\[[^\]]*\][^ )]*)*)\s*(?:\))?\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.M)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(sig: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(sig):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str, default_group: int) -> dict:
    """Per-device wire bytes by collective kind."""
    out = {"all-gather": 0.0, "all-reduce": 0.0, "reduce-scatter": 0.0,
           "all-to-all": 0.0, "collective-permute": 0.0}
    counts = dict.fromkeys(out, 0)
    for m in _COLL_RE.finditer(hlo_text):
        sig, kind = m.group(1), m.group(2)
        nbytes = _shape_bytes(sig)
        # replica group size from the full op line
        line = hlo_text[m.start():hlo_text.find("\n", m.start())]
        n = default_group
        gm = _GROUPS_IOTA_RE.search(line)
        if gm:
            n = int(gm.group(2))
        else:
            gm = _GROUPS_RE.search(line)
            if gm and gm.group(1).strip():
                first = gm.group(1).split("}")[0].strip("{} ")
                n = max(1, len([t for t in first.split(",") if t.strip()]))
        if n <= 1:
            continue
        ring = (n - 1) / n
        factor = {"all-gather": ring, "reduce-scatter": ring,
                  "all-reduce": 2 * ring, "all-to-all": ring,
                  "collective-permute": 1.0}[kind]
        out[kind] += nbytes * factor
        counts[kind] += 1
    out["total"] = sum(out.values())
    out["counts"] = counts
    return out


@dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    model_flops: float          # global useful FLOPs (6ND / 2ND)
    hlo_flops_global: float
    useful_ratio: float
    peak_bytes_per_device: float = 0.0

    def asdict(self):
        return asdict(self)


def analyze(compiled, n_devices: int, model_flops: float,
            hlo_text: str | None = None) -> Roofline:
    """Roofline terms from the compiled artifact.

    Primary source is the loop-corrected HLO walker (launch/hlo_cost.py):
    XLA's cost_analysis counts while bodies once, which undercounts
    scanned-layer programs by the trip count.  The raw cost_analysis
    numbers are kept in the record for reference.
    """
    from repro.launch import hlo_cost
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    text = hlo_text if hlo_text is not None else compiled.as_text()
    cost = hlo_cost.analyze_hlo(text, n_devices)
    flops = cost.flops
    byts = cost.bytes
    coll = dict(cost.coll)
    coll["total"] = cost.coll_total
    coll["counts"] = {}
    coll["raw_cost_analysis_flops"] = float(ca.get("flops", 0.0))
    coll["raw_cost_analysis_bytes"] = float(ca.get("bytes accessed", 0.0))
    t_comp = flops / PEAK_FLOPS
    t_mem = byts / HBM_BW
    t_coll = coll["total"] / ICI_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    bottleneck = max(terms, key=terms.get)
    peak_bytes = 0.0
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            peak_bytes = float(
                getattr(ma, "temp_size_in_bytes", 0)
                + getattr(ma, "argument_size_in_bytes", 0)
                + getattr(ma, "output_size_in_bytes", 0)
                - getattr(ma, "alias_size_in_bytes", 0))
    except Exception:
        pass
    hlo_global = flops * n_devices
    return Roofline(
        flops_per_device=flops,
        bytes_per_device=byts,
        collective_bytes_per_device=coll["total"],
        t_compute=t_comp, t_memory=t_mem, t_collective=t_coll,
        bottleneck=bottleneck,
        model_flops=model_flops,
        hlo_flops_global=hlo_global,
        useful_ratio=model_flops / hlo_global if hlo_global else 0.0,
        peak_bytes_per_device=peak_bytes,
    )


def model_flops(cfg, shape) -> float:
    """Useful FLOPs per step: 6·N_active·tokens (train), 2·N_active·tokens
    (prefill), 2·N_active·batch (decode; one token per sequence)."""
    n_active = cfg.param_counts()["active"]
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch
