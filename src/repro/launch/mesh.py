"""Production mesh builders.

Functions, not module-level constants, so importing this module never
touches jax device state (device count is locked at first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 (2 pods, 512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU smoke)."""
    n = jax.device_count()
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))
