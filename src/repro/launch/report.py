"""Render EXPERIMENTS.md tables from artifacts/dryrun/*.json.

  PYTHONPATH=src python -m repro.launch.report            # markdown tables
"""
from __future__ import annotations

import json
import pathlib

ART = pathlib.Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def load(mesh: str):
    out = []
    for f in sorted(ART.glob(f"*__{mesh}.json")):
        out.append(json.loads(f.read_text()))
    return out


def fmt_table(mesh: str) -> str:
    rows = [
        "| arch | shape | HBM/dev | t_comp (s) | t_mem (s) | t_coll (s) | "
        "bound | roofline frac | useful ratio |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for rec in load(mesh):
        name = f"| {rec['arch']} | {rec['shape']} "
        if rec["status"] == "skipped":
            rows.append(name + "| — | — | — | — | skipped (full attention; "
                        "long_500k needs sub-quadratic) | — | — |")
            continue
        if rec["status"] != "ok":
            rows.append(name + f"| FAIL: {rec.get('error','')[:60]} |")
            continue
        r = rec["roofline"]
        hbm = rec["memory_analysis"].get("total_hbm_bytes_per_device", 0) / 2**30
        tmax = max(r["t_compute"], r["t_memory"], r["t_collective"])
        frac = r["t_compute"] / tmax if tmax else 0.0
        rows.append(
            name + f"| {hbm:.2f} GiB | {r['t_compute']:.4f} | "
            f"{r['t_memory']:.4f} | {r['t_collective']:.4f} | "
            f"{r['bottleneck']} | {frac:.3f} | {r['useful_ratio']:.3f} |")
    return "\n".join(rows)


def perf_comparison() -> str:
    """Baseline vs optimized for the hillclimb cells."""
    rows = ["| cell | variant | t_comp | t_mem | t_coll | HBM/dev |",
            "|---|---|---|---|---|---|"]
    for f in sorted(ART.glob("*__single_pod_baseline.json")):
        base = json.loads(f.read_text())
        opt_f = ART / f.name.replace("_baseline", "")
        if not opt_f.exists():
            continue
        opt = json.loads(opt_f.read_text())
        for tag, rec in (("baseline", base), ("optimized", opt)):
            if rec["status"] != "ok":
                continue
            r = rec["roofline"]
            hbm = rec["memory_analysis"].get(
                "total_hbm_bytes_per_device", 0) / 2**30
            rows.append(
                f"| {rec['arch']} × {rec['shape']} | {tag} | "
                f"{r['t_compute']:.2f} | {r['t_memory']:.2f} | "
                f"{r['t_collective']:.2f} | {hbm:.1f} GiB |")
    return "\n".join(rows)


def summary():
    for mesh in ("single_pod", "multi_pod"):
        recs = load(mesh)
        ok = [r for r in recs if r["status"] == "ok"]
        print(f"\n## {mesh}: {len(ok)} ok / "
              f"{sum(r['status']=='skipped' for r in recs)} skipped / "
              f"{sum(r['status']=='FAIL' for r in recs)} fail\n")
        print(fmt_table(mesh))
    print("\n## §Perf baseline vs optimized (hillclimb cells)\n")
    print(perf_comparison())


if __name__ == "__main__":
    summary()
