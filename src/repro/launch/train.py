"""Training launcher: config -> mesh -> sharded train_step -> resilient loop.

Examples (CPU container):
  PYTHONPATH=src python -m repro.launch.train --arch h2o-danube-1.8b --tiny \
      --steps 50 --batch 8 --seq 128 --ckpt /tmp/ckpt
  PYTHONPATH=src python -m repro.launch.train --arch mamba2-1.3b --tiny --steps 20

On a real pod, drop --tiny and point --mesh at production; everything else
(sharding rules, checkpointing, fault handling, data determinism) is the
same code path the dry-run lowers.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt.checkpoint import Checkpointer
from repro.configs import get_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.launch.steps import build_lm, make_train_step
from repro.optim import adamw
from repro.parallel import sharding as shlib
from repro.runtime import fault
from repro.runtime.elastic import mesh_invariant_rng, replace_state


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--tiny", action="store_true",
                    help="reduced config (CPU smoke / examples)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--mesh", default="local", choices=["local", "production",
                                                        "multi_pod"])
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--moment-dtype", default="float32")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    # before ANY rng use: init must be a pure function of the key, not
    # of the mesh it is jitted onto, or elastic restarts on a different
    # topology silently fork the trajectory (see runtime/elastic.py)
    mesh_invariant_rng()

    cfg = get_config(args.arch)
    if args.tiny:
        cfg = cfg.tiny()
    if args.mesh == "local":
        mesh = make_local_mesh(model=args.model_parallel)
    else:
        mesh = make_production_mesh(multi_pod=(args.mesh == "multi_pod"))

    lm = build_lm(cfg, mesh)
    opt_cfg = adamw.AdamWConfig(lr=args.lr, moment_dtype=args.moment_dtype)
    train_step = make_train_step(lm, opt_cfg, remat=True)

    p_shapes = lm.param_shapes()
    p_sh = shlib.param_shardings(cfg, p_shapes, mesh)
    with mesh:
        params = jax.jit(lm.init, out_shardings=p_sh)(jax.random.PRNGKey(0))
        opt = adamw.init(params, opt_cfg)
        state = {"params": params, "opt": opt,
                 "step": jnp.zeros((), jnp.int32)}
        jstep = jax.jit(train_step, donate_argnums=(0,))

        data = SyntheticLM(DataConfig(cfg.vocab_size, args.seq, args.batch))
        ckpt = Checkpointer(args.ckpt) if args.ckpt else None
        start = 0
        if ckpt and args.resume and ckpt.latest_step() is not None:
            start = ckpt.latest_step()
            # elastic-safe restore: re-place params AND optimizer
            # moments with THIS mesh's shardings (the checkpoint may
            # come from a different topology)
            state = replace_state(cfg, ckpt, state, mesh, step=start)
            print(f"resumed from step {start}")

        losses = []

        def one_step(state, step):
            batch = jax.tree.map(jnp.asarray, data.batch(step))
            if not cfg.embed_inputs:   # frontend stub: embed synthetically
                rng = np.random.default_rng(step)
                emb = rng.normal(0, 1, (args.batch, args.seq,
                                        cfg.d_model)).astype(np.float32)
                batch = {"embeds": jnp.asarray(emb, jnp.bfloat16),
                         "labels": batch["labels"]}
            return jstep(state, batch)

        def log(step, metrics, dt):
            # keep the device array: float() here would block on the
            # async dispatch EVERY step, serializing host and device —
            # coerce only at the log boundary (and once at the end)
            losses.append(metrics["loss"])
            if (step + 1) % args.log_every == 0:
                print(f"step {step+1} loss {float(losses[-1]):.4f} "
                      f"({dt*1e3:.0f} ms)", flush=True)

        t0 = time.time()
        state, stats = fault.run_resilient(
            one_step, state, start, args.steps, checkpointer=ckpt,
            ckpt_every=args.ckpt_every, watchdog=fault.StepWatchdog(),
            heartbeat=None, on_metrics=log)
        losses[:] = [float(v) for v in losses]
        dt = time.time() - t0
        print(f"done: {args.steps} steps in {dt:.1f}s; "
              f"loss {losses[0]:.4f} -> {losses[-1]:.4f}; stats={stats}")
        return losses


if __name__ == "__main__":
    main()
