"""Fused log-mel + DCT Pallas kernel: power @ fb -> log -> @ dct.

The post-FFT tail of MFCC extraction (paper Fig. 3) fused into one VMEM
round-trip; the filterbank and DCT matrices are small enough to reside in
VMEM whole (80 x 257 and 80 x 80 — they are the "model memory" residents
of the feature kernel).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(p_ref, fb_ref, dct_ref, o_ref):
    mel = jax.lax.dot(p_ref[...], fb_ref[...])
    lg = jnp.log(jnp.maximum(mel, 1e-10))
    o_ref[...] = jax.lax.dot(lg, dct_ref[...])


@functools.partial(jax.jit, static_argnames=("bt", "interpret"))
def logmel_pallas(power, fb, dct, *, bt=128, interpret=False):
    """power: (T, F) f32; fb: (F, M); dct: (M, C) -> (T, C) f32."""
    T, F = power.shape
    M = fb.shape[1]
    C = dct.shape[1]
    bt = min(bt, T)
    # pad T to a multiple of bt (frames are independent rows)
    pad = (-T) % bt
    if pad:
        power = jnp.pad(power, ((0, pad), (0, 0)), constant_values=1.0)
    Tp = T + pad
    out = pl.pallas_call(
        _kernel,
        grid=(Tp // bt,),
        in_specs=[pl.BlockSpec((bt, F), lambda i: (i, 0)),
                  pl.BlockSpec((F, M), lambda i: (0, 0)),
                  pl.BlockSpec((M, C), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((bt, C), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Tp, C), jnp.float32),
        interpret=interpret,
    )(power, fb, dct)
    return out[:T]
