"""jit'd public wrappers for the Pallas kernels, dispatched by KernelPolicy.

Each wrapper takes an optional `policy: KernelPolicy` (threaded from
`EngineConfig.kernels` by the serving engines) and resolves it to one of
three modes (see kernels/policy.py):

  ref        pure-jnp oracle from ref.py, XLA-compiled
  interpret  the Pallas kernel under the interpreter (kernel body runs
             in Python per grid step — how correctness is validated
             against ref.py on CPU)
  mosaic     the same pallas_call compiled to Mosaic on TPU

`auto` resolves per backend, with the backend probe hoisted into the
policy module (one `jax.default_backend()` read per process instead of
one per call).  On CPU it keeps today's behavior for the standalone
validation kernels (interpret) but routes the decode hot path — the
fused `hypothesis_unit` — through `ref`.

`int8_matmul(x, w)` takes float tensors and performs the full ASRPU int8
path: blockless per-row/col symmetric quantization + int8 MXU matmul +
fp32 rescale (core/quant holds the block-wise variant used by the
optimizer).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import (beam_prune as _bp, flash_attention as _fa,
                           hypothesis_unit as _hu, int8_matmul as _im,
                           layernorm as _ln, logmel as _lm, ref as _ref,
                           tds_conv as _tc)
from repro.kernels.policy import (DEFAULT_POLICY, KernelPolicy,  # noqa: F401
                                  resolve)


def quantize_rows(x):
    """Symmetric per-row int8: x (M, K) -> (q i8, scale f32 (M,))."""
    xf = x.astype(jnp.float32)
    s = jnp.max(jnp.abs(xf), axis=1) / 127.0
    q = jnp.clip(jnp.round(xf / jnp.maximum(s[:, None], 1e-12)),
                 -127, 127).astype(jnp.int8)
    return q, s


def shard_local_cols(x, kloc, axis):
    """Model-parallel contraction helper: slice the activation columns
    matching this device's feature-axis weight shard — rows
    [i*kloc, (i+1)*kloc) of the full weight, where i is the device's
    index along the shard_map mesh `axis`.  Shared by the fp32
    (`tds.forward_batched`) and int8 (`int8_matmul_prepared`) paths so
    the slicing rule cannot diverge between them; callers detect a
    sharded weight by shape (w.shape[0] != x.shape[1]) and psum the
    local partial products."""
    i = jax.lax.axis_index(axis)
    return jax.lax.dynamic_slice_in_dim(x, i * kloc, kloc, axis=1)


def overlap_splits(n: int, n_chunks: int = 2):
    """Static [lo, hi) output-column chunk boundaries for the
    latency-hiding psum split (`psum_overlap_matmul`).  Python ints so
    every slice is static under jit; degenerates to one full-width
    chunk when n < n_chunks."""
    n_chunks = max(1, min(int(n_chunks), int(n)))
    return [(i * n // n_chunks, (i + 1) * n // n_chunks)
            for i in range(n_chunks)]


def psum_overlap_matmul(xloc, wm, axis, n_chunks: int = 2):
    """Latency-hiding model-parallel contraction: xloc (M, K/n) local
    activation columns, wm (K/n, N) this device's feature-axis weight
    shard -> the full (M, N) all-reduced product.

    The output columns are split into static chunks and emitted as
    matmul(c0), psum(c0), matmul(c1), psum(c1), ...: chunk c+1's local
    matmul has no data dependence on chunk c's all-reduce, so a backend
    with async collectives starts c's psum and computes c+1's partial
    products under it (the coprocessor-scaling overlap trick; see
    ROADMAP item 4).  Each output element is still ONE local dot +
    ONE psum — the reduction structure per element is identical to the
    synchronous `psum(xloc @ wm, axis)` — but XLA may tile the narrower
    per-chunk matmuls differently, so parity with the synchronous path
    is numerical (~1e-6), not bitwise; the sync path stays the parity
    reference and this variant sits behind `EngineConfig.overlap_psum`.
    On CPU host devices there is no async-collective win: correctness
    coverage only."""
    parts = []
    for lo, hi in overlap_splits(wm.shape[1], n_chunks):
        wc = jax.lax.slice_in_dim(wm, lo, hi, axis=1)
        parts.append(jax.lax.psum(xloc @ wc, axis))
    return jnp.concatenate(parts, axis=1)


def prepare_int8_weights(w):
    """Quantize a static weight matrix ONCE: w (K, N) float ->
    (wq (K, N) i8, ws (N,) f32 per-output-channel scales).

    The serving engines call this at build time (`tds.quantize_params`)
    so the decode hot path only quantizes activations — re-quantizing a
    static weight every `int8_matmul` call is pure waste."""
    wq_t, ws = quantize_rows(w.T)
    return wq_t.T, ws


def int8_matmul_prepared(x, wq, ws, *, bm=128, bn=128, bk=128, policy=None,
                         hot=False, axis=None, overlap=False):
    """x: (M, K) float; wq/ws from `prepare_int8_weights` -> (M, N) f32.

    The hot-path half of the int8 pipeline: per-row activation
    quantization + int8 MXU matmul + fp32 rescale, with the weight-side
    quantization already done.

    `axis` names the shard_map mesh axis of a model-parallel caller
    (the sharded serving step): when `wq` arrives as a feature-axis
    shard — (K/n_model, N), detected by shape against `x` — the
    activations are quantized on their FULL rows first (so the per-row
    scales match the unsharded path exactly), the matching xq columns
    are sliced locally, and the rescaled partial products are psummed
    over `axis`.  `overlap` applies the `psum_overlap_matmul`
    output-column split to the sharded path (per-chunk dispatch + psum
    so the all-reduces hide under the next chunk's matmul); the per-row
    activation scales are computed once on the full rows either way."""
    mode = resolve(policy, hot=hot)
    xq, xs = quantize_rows(x)
    if axis is not None and wq.shape[0] != xq.shape[1]:
        xloc = shard_local_cols(xq, wq.shape[0], axis)
        if overlap:
            parts = []
            for lo, hi in overlap_splits(wq.shape[1]):
                parts.append(jax.lax.psum(
                    _int8_dispatch(xloc, wq[:, lo:hi], xs, ws[lo:hi],
                                   mode, bm=bm, bn=bn, bk=bk), axis))
            return jnp.concatenate(parts, axis=1)
        return jax.lax.psum(
            _int8_dispatch(xloc, wq, xs, ws, mode, bm=bm, bn=bn, bk=bk),
            axis)
    return _int8_dispatch(xq, wq, xs, ws, mode, bm=bm, bn=bn, bk=bk)


def _int8_dispatch(xq, wq, xs, ws, mode, *, bm, bn, bk):
    """Mode-resolved int8 matmul core on (possibly shard-local) operands."""
    if mode == "ref":
        return _ref.int8_matmul(xq, wq, xs, ws)
    M, K = xq.shape
    N = wq.shape[1]
    pad_m, pad_n, pad_k = (-M) % 8, (-N) % 128, (-K) % 128
    if pad_m or pad_k:
        xq = jnp.pad(xq, ((0, pad_m), (0, pad_k)))
        xs = jnp.pad(xs, (0, pad_m))
    if pad_n or pad_k:
        wq = jnp.pad(wq, ((0, pad_k), (0, pad_n)))
        ws = jnp.pad(ws, (0, pad_n))
    bm_ = min(bm, xq.shape[0])
    while xq.shape[0] % bm_:
        bm_ //= 2
    out = _im.int8_matmul_pallas(xq, wq, xs, ws, bm=bm_, bn=bn, bk=bk,
                                 interpret=mode != "mosaic")
    return out[:M, :N]


def int8_matmul(x, w, *, bm=128, bn=128, bk=128, policy=None, hot=False):
    """x: (M, K) float; w: (K, N) float -> (M, N) f32 (int8 MXU path).

    Quantizes BOTH operands on every call — correct for one-shot use,
    but callers with static weights should `prepare_int8_weights` once
    and use `int8_matmul_prepared` on the hot path."""
    wq, ws = prepare_int8_weights(w)
    return int8_matmul_prepared(x, wq, ws, bm=bm, bn=bn, bk=bk,
                                policy=policy, hot=hot)


def flash_attention(q, k, v, *, causal=True, window=None,
                    block_q=128, block_kv=128, policy=None):
    mode = resolve(policy)
    if mode == "ref":
        return _ref.flash_attention(q, k, v, causal=causal, window=window)
    return _fa.flash_attention_pallas(q, k, v, causal=causal, window=window,
                                      block_q=block_q, block_kv=block_kv,
                                      interpret=mode != "mosaic")


def layernorm(x, scale, bias, *, eps=1e-5, policy=None, hot=False):
    mode = resolve(policy, hot=hot)
    if mode == "ref":
        return _ref.layernorm(x, scale, bias, eps=eps)
    return _ln.norm_pallas(x, scale, bias, kind="layernorm", eps=eps,
                           interpret=mode != "mosaic")


def rmsnorm(x, scale, *, eps=1e-6, policy=None, hot=False):
    mode = resolve(policy, hot=hot)
    if mode == "ref":
        return _ref.rmsnorm(x, scale, eps=eps)
    return _ln.norm_pallas(x, scale, None, kind="rmsnorm", eps=eps,
                           interpret=mode != "mosaic")


def logmel(power, fb, dct, policy=None, *, hot=False):
    mode = resolve(policy, hot=hot)
    if mode == "ref":
        return _ref.logmel(power, fb, dct)
    return _lm.logmel_pallas(power, fb, dct, interpret=mode != "mosaic")


def beam_prune(scores, beam, policy=None):
    mode = resolve(policy)
    if mode == "ref":
        return _ref.beam_prune(scores, beam)
    return _bp.beam_prune_pallas(scores, beam, interpret=mode != "mosaic")


def tds_conv(x, w, b, *, stride=1, relu=False, res=None, policy=None,
             hot=False):
    """Causal strided TDS conv with the fused bias+ReLU+residual
    epilogue.  x: (B, k-1+T, W, Cin) slot-batched (3-D = B=1)."""
    mode = resolve(policy, hot=hot)
    squeeze = x.ndim == 3
    if squeeze:
        x = x[None]
        res = None if res is None else res[None]
    if mode == "ref":
        out = _ref.tds_conv_fused(x, w, b, stride=stride, relu=relu,
                                  res=res)
    else:
        out = _tc.tds_conv_pallas(x, w, b, res, stride=stride, relu=relu,
                                  interpret=mode != "mosaic")
    return out[0] if squeeze else out


# ---------------------------------------------------------------------------
# fused hypothesis unit (decode hot path)
# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("k", "beam", "mode"))
def _hypothesis_unit(hashes, pb, pnb, *, k, beam, mode):
    B, N = hashes.shape
    assert N >= k, (N, k)
    if mode == "ref":
        return _ref.hypothesis_unit(hashes, pb, pnb, k=k, beam=beam)
    valid = jnp.logaddexp(pb, pnb) > _ref.NEG_INF / 2
    key = jnp.where(valid, hashes.astype(jnp.uint32), _ref.HASH_SENTINEL)
    pad = (-N) % 128                       # lane-align the row for Mosaic
    if pad:
        key = jnp.pad(key, ((0, 0), (0, pad)),
                      constant_values=_ref.HASH_SENTINEL)
        pb = jnp.pad(pb, ((0, 0), (0, pad)), constant_values=_ref.NEG_INF)
        pnb = jnp.pad(pnb, ((0, 0), (0, pad)), constant_values=_ref.NEG_INF)
    # the hardware sort unit's ordering half: ONE batched XLA argsort;
    # dead candidates carry an out-of-range uint32 sentinel, so a live
    # hash equal to 2**31 - 1 can never be mistaken for one (the lane
    # padding is merge-neutral: pads sort into the sentinel tail and
    # contribute exact-zero mass, pinned bitwise by the parity tests
    # against the unpadded ref pipeline)
    order = jnp.argsort(key, axis=-1, stable=True)
    key_s = jnp.take_along_axis(key, order, axis=-1)
    pb_s = jnp.take_along_axis(pb, order, axis=-1)
    pnb_s = jnp.take_along_axis(pnb, order, axis=-1)
    pos, opb, opnb, oval = _hu.hypothesis_unit_pallas(
        key_s, pb_s, pnb_s, k=k, beam=beam, interpret=mode != "mosaic")
    valid = oval.astype(bool)
    # order[pos] is the sorted segment head = the selected hash's FIRST
    # occurrence in the original row (stable sort), matching the
    # sort-free ref path; pruned slots pin to 0 in both paths
    idx = jnp.where(valid, jnp.take_along_axis(order, pos, axis=-1), 0)
    return {"idx": idx, "pb": opb, "pnb": opnb, "valid": valid}


def hypothesis_unit(hashes, pb, pnb, k, beam, policy=None):
    """Fused hypothesis unit over a batch of candidate rows.

    hashes: (B, N) int32 31-bit prefix hashes; pb/pnb: (B, N) f32 CTC
    channels.  Merges duplicate hashes (channel-wise logsumexp), applies
    the beam threshold, and selects the top-`k` per row.  Returns a dict
    of (B, k) arrays: `idx` (index of each selected representative into
    the original row — callers gather their payload fields with it),
    merged `pb`/`pnb` (NEG_INF where pruned), and boolean `valid`.
    """
    mode = resolve(policy, hot=True)
    return _hypothesis_unit(hashes, pb, pnb, k=k, beam=float(beam),
                            mode=mode)
