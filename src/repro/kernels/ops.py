"""jit'd public wrappers for the Pallas kernels.

On CPU (this container) kernels execute in interpret mode — the kernel
body runs in Python per grid step, which is how correctness is validated
against ref.py.  On TPU the same pallas_call compiles to Mosaic.

`int8_matmul(x, w)` takes float tensors and performs the full ASRPU int8
path: blockless per-row/col symmetric quantization + int8 MXU matmul +
fp32 rescale (core/quant holds the block-wise variant used by the
optimizer).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import (beam_prune as _bp, flash_attention as _fa,
                           int8_matmul as _im, layernorm as _ln,
                           logmel as _lm, tds_conv as _tc)


def _interpret() -> bool:
    return jax.default_backend() == "cpu"


def quantize_rows(x):
    """Symmetric per-row int8: x (M, K) -> (q i8, scale f32 (M,))."""
    xf = x.astype(jnp.float32)
    s = jnp.max(jnp.abs(xf), axis=1) / 127.0
    q = jnp.clip(jnp.round(xf / jnp.maximum(s[:, None], 1e-12)),
                 -127, 127).astype(jnp.int8)
    return q, s


def int8_matmul(x, w, *, bm=128, bn=128, bk=128):
    """x: (M, K) float; w: (K, N) float -> (M, N) f32 (int8 MXU path)."""
    xq, xs = quantize_rows(x)
    wq_t, ws = quantize_rows(w.T)          # per-output-channel scales
    wq = wq_t.T
    M, K = xq.shape
    N = wq.shape[1]
    pad_m, pad_n, pad_k = (-M) % 8, (-N) % 128, (-K) % 128
    if pad_m or pad_k:
        xq = jnp.pad(xq, ((0, pad_m), (0, pad_k)))
        xs = jnp.pad(xs, (0, pad_m))
    if pad_n or pad_k:
        wq = jnp.pad(wq, ((0, pad_k), (0, pad_n)))
        ws = jnp.pad(ws, (0, pad_n))
    bm_ = min(bm, xq.shape[0])
    while xq.shape[0] % bm_:
        bm_ //= 2
    out = _im.int8_matmul_pallas(xq, wq, xs, ws, bm=bm_, bn=bn, bk=bk,
                                 interpret=_interpret())
    return out[:M, :N]


def flash_attention(q, k, v, *, causal=True, window=None,
                    block_q=128, block_kv=128):
    return _fa.flash_attention_pallas(q, k, v, causal=causal, window=window,
                                      block_q=block_q, block_kv=block_kv,
                                      interpret=_interpret())


def layernorm(x, scale, bias, *, eps=1e-5):
    return _ln.norm_pallas(x, scale, bias, kind="layernorm", eps=eps,
                           interpret=_interpret())


def rmsnorm(x, scale, *, eps=1e-6):
    return _ln.norm_pallas(x, scale, None, kind="rmsnorm", eps=eps,
                           interpret=_interpret())


def logmel(power, fb, dct):
    return _lm.logmel_pallas(power, fb, dct, interpret=_interpret())


def beam_prune(scores, beam):
    return _bp.beam_prune_pallas(scores, beam, interpret=_interpret())


def tds_conv(x, w, b, *, stride=1):
    return _tc.tds_conv_pallas(x, w, b, stride=stride,
                               interpret=_interpret())
