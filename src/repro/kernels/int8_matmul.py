"""int8 x int8 -> int32 matmul Pallas kernel with per-row/col scales.

TPU mapping of ASRPU's 8-wide int8 MAC with fp32 accumulation (paper §3.4):
the MXU is the 128x128 systolic generalization.  The paper's "partition FC
layers into <=1MB model-memory kernels" (§5.2) is exactly the BlockSpec
HBM->VMEM tiling here: each (bk x bn) weight tile is staged into VMEM and
double-buffered by the Pallas pipeline — same insight, TPU memory sizes.

Grid (M/bm, N/bn, K/bk), K innermost; int32 accumulator in VMEM scratch;
scales applied at the final K step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat


def _kernel(xq_ref, wq_ref, xs_ref, ws_ref, o_ref, acc_ref, *, nk):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        xq_ref[...], wq_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(ik == nk - 1)
    def _done():
        o_ref[...] = (acc_ref[...].astype(jnp.float32)
                      * xs_ref[...][:, None] * ws_ref[...][None, :])


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def int8_matmul_pallas(xq, wq, xs, ws, *, bm=128, bn=128, bk=128,
                       interpret=False):
    """xq: (M,K) i8; wq: (K,N) i8; xs: (M,) f32; ws: (N,) f32 -> (M,N) f32."""
    M, K = xq.shape
    N = wq.shape[1]
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    nk = K // bk
    return pl.pallas_call(
        functools.partial(_kernel, nk=nk),
        grid=(M // bm, N // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bm,), lambda i, j, k: (i,)),
            pl.BlockSpec((bn,), lambda i, j, k: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(xq, wq, xs, ws)
