"""Pure-jnp oracles for every Pallas kernel (the `ref.py` contract).

Each function is the semantic ground truth its kernel is allclose-tested
against (tests/test_kernels.py sweeps shapes + dtypes).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

MASK = -1e30


def int8_matmul(xq, wq, xs, ws):
    """xq: (M,K) i8, wq: (K,N) i8, xs: (M,) f32, ws: (N,) f32 -> (M,N) f32.

    int8 x int8 -> int32 accumulate (the MXU path), then per-row/col scales
    — ASRPU's 8-wide int8 MAC with fp32 accumulation, MXU-sized.
    """
    acc = jax.lax.dot_general(
        xq, wq, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)
    return acc.astype(jnp.float32) * xs[:, None] * ws[None, :]


def flash_attention(q, k, v, *, causal=True, window=None, scale=None):
    """q: (B,H,Sq,D); k,v: (B,H,Skv,D) (GQA pre-expanded). f32 softmax."""
    B, H, Sq, D = q.shape
    Skv = k.shape[2]
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    qpos = jnp.arange(Sq)[:, None] + (Skv - Sq)
    kpos = jnp.arange(Skv)[None, :]
    m = jnp.ones((Sq, Skv), bool)
    if causal:
        m &= kpos <= qpos
    if window is not None:
        m &= (qpos - kpos) < window
    s = jnp.where(m[None, None], s, MASK)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def layernorm(x, scale, bias, eps=1e-5):
    """x: (T, D) any float dtype; f32 statistics."""
    xf = x.astype(jnp.float32)
    mu = xf.mean(-1, keepdims=True)
    var = xf.var(-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps) * scale + bias).astype(x.dtype)


def rmsnorm(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), -1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale).astype(x.dtype)


def logmel(power, fb, dct):
    """power: (T,F) f32, fb: (F,M), dct: (M,C) -> (T,C) MFCC tail."""
    return jnp.log(jnp.maximum(power @ fb, 1e-10)) @ dct


def beam_prune(scores, beam, mask_value=MASK):
    """scores: (N,) f32 -> scores with entries < max - beam set to MASK."""
    best = jnp.max(scores)
    return jnp.where(scores >= best - beam, scores, mask_value)


def tds_conv(x, w, b, stride=1):
    """Causal strided time conv. x: (T_pad, W, Cin) already left-padded by
    k-1; w: (k, Cin, Cout); returns (T_out, W, Cout) with
    T_out = (T_pad - k + 1 + stride - 1) // stride ... callers pass
    T_pad = k - 1 + T_in with T_in % stride == 0, giving T_in // stride."""
    k = w.shape[0]
    T_in = x.shape[0] - (k - 1)
    t_out = T_in // stride
    off = (jnp.arange(t_out) * stride)[:, None] + jnp.arange(k)[None, :]
    win = x[off]                                    # (t_out, k, W, Cin)
    return jnp.einsum("tkwc,kcd->twd", win, w) + b
