"""Pure-jnp oracles for every Pallas kernel (the `ref.py` contract).

Each function is the semantic ground truth its kernel is allclose-tested
against (tests/test_kernels.py sweeps shapes + dtypes).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

MASK = -1e30


def int8_matmul(xq, wq, xs, ws):
    """xq: (M,K) i8, wq: (K,N) i8, xs: (M,) f32, ws: (N,) f32 -> (M,N) f32.

    int8 x int8 -> int32 accumulate (the MXU path), then per-row/col scales
    — ASRPU's 8-wide int8 MAC with fp32 accumulation, MXU-sized.
    """
    acc = jax.lax.dot_general(
        xq, wq, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)
    return acc.astype(jnp.float32) * xs[:, None] * ws[None, :]


def flash_attention(q, k, v, *, causal=True, window=None, scale=None):
    """q: (B,H,Sq,D); k,v: (B,H,Skv,D) (GQA pre-expanded). f32 softmax."""
    B, H, Sq, D = q.shape
    Skv = k.shape[2]
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    qpos = jnp.arange(Sq)[:, None] + (Skv - Sq)
    kpos = jnp.arange(Skv)[None, :]
    m = jnp.ones((Sq, Skv), bool)
    if causal:
        m &= kpos <= qpos
    if window is not None:
        m &= (qpos - kpos) < window
    s = jnp.where(m[None, None], s, MASK)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def layernorm(x, scale, bias, eps=1e-5):
    """x: (T, D) any float dtype; f32 statistics."""
    xf = x.astype(jnp.float32)
    mu = xf.mean(-1, keepdims=True)
    var = xf.var(-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps) * scale + bias).astype(x.dtype)


def rmsnorm(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), -1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale).astype(x.dtype)


def logmel(power, fb, dct):
    """power: (T,F) f32, fb: (F,M), dct: (M,C) -> (T,C) MFCC tail."""
    return jnp.log(jnp.maximum(power @ fb, 1e-10)) @ dct


def beam_prune(scores, beam, mask_value=MASK):
    """scores: (N,) f32 -> scores with entries < max - beam set to MASK."""
    best = jnp.max(scores)
    return jnp.where(scores >= best - beam, scores, mask_value)


# ---------------------------------------------------------------------------
# fused hypothesis unit (paper §3.5): hash-merge + beam threshold + top-k
# ---------------------------------------------------------------------------
NEG_INF = -1e30                      # matches core/hypothesis.py
HASH_SENTINEL = np.uint32(0xFFFFFFFF)    # > any 31-bit prefix hash


def _seg_lse(v, ids, num_segments, *, indices_are_sorted=False):
    """Per-segment logsumexp of flat `v`, broadcast back per position:
    out[j] = logsumexp(v over j's whole segment).

    max + exp + segment-sum (scatter) instead of a log2(n)-pass
    logaddexp scan — the scan's transcendentals dominated the
    decode-hot-path merge.  Both hypothesis-unit paths (sorted-row
    kernel, sort-free ref) call this one helper, accumulating segment
    terms in original index order, which is what keeps them
    bit-identical.  An all-dead channel stays exactly NEG_INF (the
    exp(0)=1 terms of -1e30 entries would drift it by +log(count) ulps
    otherwise)."""
    m = jax.ops.segment_max(v, ids, num_segments=num_segments,
                            indices_are_sorted=indices_are_sorted)
    s = jax.ops.segment_sum(jnp.exp(v - m[ids]), ids,
                            num_segments=num_segments,
                            indices_are_sorted=indices_are_sorted)
    out = (m + jnp.log(s))[ids]
    return jnp.where(out > NEG_INF / 2, out, NEG_INF)


def merge_select_sorted(key_s, pb_s, pnb_s, *, k: int, beam: float,
                        iterative_topk: bool = False):
    """One hypothesis-unit row over a candidate set PRE-SORTED by key.

    key_s: (N,) uint32 — prefix hash for valid candidates, HASH_SENTINEL
    for dead ones (so dead candidates sort to the tail and can never
    merge with a live hash, even a live hash equal to 2**31 - 1).
    pb_s / pnb_s: (N,) f32 CTC channels in the same sorted order.

    Returns (pos, pb, pnb, valid), each (k,): `pos` indexes the SORTED
    row (the caller maps it back through its argsort permutation),
    pb/pnb are the merged channels of the selected representative, and
    `valid` (int32 0/1) applies the beam threshold.

    The sorted-row half of the hypothesis unit: the Pallas kernel
    (kernels/hypothesis_unit.py) calls this per grid step.  The pure-jnp
    ref path (`hypothesis_unit` below) is sort-free but shares
    `_seg_lse`, summing each segment's terms in the same (original
    index) order, which is what keeps interpret-mode parity
    bit-for-bit.  `iterative_topk` picks the Mosaic-friendly k-pass
    argmax selection (the kernel path; no sort primitive on TPU) over
    one `lax.top_k` — both have the same semantics exactly (descending,
    ties to the lowest index; the score domain is bounded below by
    NEG_INF, never -inf, and k <= N, so the argmax loop can never
    re-pick an exhausted slot).
    """
    n = key_s.shape[0]
    head = jnp.concatenate(
        [jnp.ones((1,), bool), key_s[1:] != key_s[:-1]])     # segment starts
    ids = jnp.cumsum(head) - 1                               # segment ids
    live = key_s != HASH_SENTINEL

    pb_m = _seg_lse(pb_s, ids, n, indices_are_sorted=True)
    pnb_m = _seg_lse(pnb_s, ids, n, indices_are_sorted=True)

    rep = head & live                       # one representative per live hash
    tot = jnp.where(rep, jnp.logaddexp(pb_m, pnb_m), NEG_INF)
    best = jnp.max(tot)

    if iterative_topk:
        def pick(i, carry):
            t, pos = carry
            j = jnp.argmax(t).astype(jnp.int32)   # ties -> lowest index
            return t.at[j].set(-jnp.inf), pos.at[i].set(j)

        _, pos = jax.lax.fori_loop(
            0, k, pick, (tot, jnp.zeros((k,), jnp.int32)))
        top = tot[pos]
    else:
        top, pos = jax.lax.top_k(tot, k)
        pos = pos.astype(jnp.int32)
    valid = (top > NEG_INF / 2) & (top >= best - beam)
    pb = jnp.where(valid, pb_m[pos], NEG_INF)
    pnb = jnp.where(valid, pnb_m[pos], NEG_INF)
    return pos, pb, pnb, valid.astype(jnp.int32)


def hypothesis_unit(hashes, pb, pnb, *, k: int, beam: float):
    """Batched fused hypothesis unit, pure jnp (the kernel's oracle).

    hashes: (B, N) int32 31-bit prefix hashes; pb/pnb: (B, N) f32.
    Returns dict of (B, k) arrays: `idx` (selected candidate index into
    the ORIGINAL row — the first occurrence of the selected hash; 0 for
    pruned slots), merged `pb`/`pnb`, and boolean `valid`.

    Sort-free formulation of the same merge: candidates never move.
    A single-operand key sort (XLA's fast path — the (key, iota) pair
    sort behind `argsort` is ~8x slower on CPU) + `searchsorted` assign
    every ORIGINAL position its segment id, the per-segment logsumexp is
    a max + exp + segment-sum over unmoved positions (accumulating in
    original index order, exactly the order the sorted-row kernel path
    sums — the two stay bit-identical), and top-k reads original
    positions directly, so the argsort permutation, its three payload
    gathers, and the order re-mapping all disappear from the decode hot
    path.
    """
    B, n = hashes.shape
    valid_in = jnp.logaddexp(pb, pnb) > NEG_INF / 2
    key = jnp.where(valid_in, hashes.astype(jnp.uint32), HASH_SENTINEL)
    key_sorted = jnp.sort(key, axis=-1)
    ids = jax.vmap(
        lambda ks, kk: jnp.searchsorted(ks, kk, side="left"))(key_sorted, key)
    gids = (ids + jnp.arange(B, dtype=ids.dtype)[:, None] * n).reshape(-1)
    iota = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[None], (B, n))

    pb_m = _seg_lse(pb.reshape(-1), gids, B * n).reshape(B, n)
    pnb_m = _seg_lse(pnb.reshape(-1), gids, B * n).reshape(B, n)
    first = jax.ops.segment_min(iota.reshape(-1), gids, num_segments=B * n)
    rep = (iota == first[gids].reshape(B, n)) & (key != HASH_SENTINEL)
    tot = jnp.where(rep, jnp.logaddexp(pb_m, pnb_m), NEG_INF)
    best = jnp.max(tot, axis=-1, keepdims=True)
    top, pos = jax.lax.top_k(tot, k)
    valid = (top > NEG_INF / 2) & (top >= best - beam)
    idx = jnp.where(valid, pos.astype(jnp.int32), 0)
    opb = jnp.where(valid, jnp.take_along_axis(pb_m, pos, axis=-1), NEG_INF)
    opnb = jnp.where(valid, jnp.take_along_axis(pnb_m, pos, axis=-1),
                     NEG_INF)
    return {"idx": idx, "pb": opb, "pnb": opnb, "valid": valid}


def tds_conv(x, w, b, stride=1):
    """Causal strided time conv. x: (T_pad, W, Cin) already left-padded by
    k-1; w: (k, Cin, Cout); returns (T_out, W, Cout) with
    T_out = (T_pad - k + 1 + stride - 1) // stride ... callers pass
    T_pad = k - 1 + T_in with T_in % stride == 0, giving T_in // stride."""
    k = w.shape[0]
    T_in = x.shape[0] - (k - 1)
    t_out = T_in // stride
    off = (jnp.arange(t_out) * stride)[:, None] + jnp.arange(k)[None, :]
    win = x[off]                                    # (t_out, k, W, Cin)
    return jnp.einsum("tkwc,kcd->twd", win, w) + b


def tds_conv_fused(x, w, b, *, stride=1, relu=False, res=None):
    """Slot-batched causal conv with the ASRPU conv epilogue fused in.

    x: (B, k-1+T, W, Cin); w: (k, Cin, Cout); b: (Cout,); optional
    res: (B, T//stride, W, Cout) residual added AFTER the ReLU (the TDS
    block order).  Returns (B, T//stride, W, Cout).

    One k-tap loop of (B*t_out*W, Cin) x (Cin, Cout) matmuls — the MXU
    sees the slot axis folded into the row dimension — instead of the
    gather-window einsum, which materializes a (t_out, k, W, Cin) window
    tensor per conv per slot.
    """
    B, Tp, W, Cin = x.shape
    k, _, Cout = w.shape
    t_out = (Tp - (k - 1)) // stride
    acc = jnp.zeros((B * t_out * W, Cout), jnp.float32)
    for j in range(k):
        # tap j of output t reads x[:, stride*t + j]
        xj = jax.lax.slice_in_dim(x, j, j + stride * (t_out - 1) + 1,
                                  stride=stride, axis=1)
        acc = acc + xj.reshape(B * t_out * W, Cin).astype(jnp.float32) @ \
            w[j].astype(jnp.float32)
    y = acc.reshape(B, t_out, W, Cout) + b
    if relu:
        y = jnp.maximum(y, 0.0)
    if res is not None:
        y = y + res
    return y
