"""Pure-jnp oracles for every Pallas kernel (the `ref.py` contract).

Each function is the semantic ground truth its kernel is allclose-tested
against (tests/test_kernels.py sweeps shapes + dtypes).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

MASK = -1e30


def int8_matmul(xq, wq, xs, ws):
    """xq: (M,K) i8, wq: (K,N) i8, xs: (M,) f32, ws: (N,) f32 -> (M,N) f32.

    int8 x int8 -> int32 accumulate (the MXU path), then per-row/col scales
    — ASRPU's 8-wide int8 MAC with fp32 accumulation, MXU-sized.
    """
    acc = jax.lax.dot_general(
        xq, wq, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)
    return acc.astype(jnp.float32) * xs[:, None] * ws[None, :]


def flash_attention(q, k, v, *, causal=True, window=None, scale=None):
    """q: (B,H,Sq,D); k,v: (B,H,Skv,D) (GQA pre-expanded). f32 softmax."""
    B, H, Sq, D = q.shape
    Skv = k.shape[2]
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    qpos = jnp.arange(Sq)[:, None] + (Skv - Sq)
    kpos = jnp.arange(Skv)[None, :]
    m = jnp.ones((Sq, Skv), bool)
    if causal:
        m &= kpos <= qpos
    if window is not None:
        m &= (qpos - kpos) < window
    s = jnp.where(m[None, None], s, MASK)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def layernorm(x, scale, bias, eps=1e-5):
    """x: (T, D) any float dtype; f32 statistics."""
    xf = x.astype(jnp.float32)
    mu = xf.mean(-1, keepdims=True)
    var = xf.var(-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps) * scale + bias).astype(x.dtype)


def rmsnorm(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), -1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale).astype(x.dtype)


def logmel(power, fb, dct):
    """power: (T,F) f32, fb: (F,M), dct: (M,C) -> (T,C) MFCC tail."""
    return jnp.log(jnp.maximum(power @ fb, 1e-10)) @ dct


def beam_prune(scores, beam, mask_value=MASK):
    """scores: (N,) f32 -> scores with entries < max - beam set to MASK."""
    best = jnp.max(scores)
    return jnp.where(scores >= best - beam, scores, mask_value)


# ---------------------------------------------------------------------------
# fused hypothesis unit (paper §3.5): hash-merge + beam threshold + top-k
# ---------------------------------------------------------------------------
NEG_INF = -1e30                      # matches core/hypothesis.py
HASH_SENTINEL = np.uint32(0xFFFFFFFF)    # > any 31-bit prefix hash


def merge_select_sorted(key_s, pb_s, pnb_s, *, k: int, beam: float,
                        iterative_topk: bool = False):
    """One hypothesis-unit row over a candidate set PRE-SORTED by key.

    key_s: (N,) uint32 — prefix hash for valid candidates, HASH_SENTINEL
    for dead ones (so dead candidates sort to the tail and can never
    merge with a live hash, even a live hash equal to 2**31 - 1).
    pb_s / pnb_s: (N,) f32 CTC channels in the same sorted order.

    Returns (pos, pb, pnb, valid), each (k,): `pos` indexes the SORTED
    row (the caller maps it back through its argsort permutation),
    pb/pnb are the merged channels of the selected representative, and
    `valid` (int32 0/1) applies the beam threshold.

    This function is the single source of truth for the merge/select
    math: the pure-jnp ref path vmaps it per batch row and the Pallas
    kernel (kernels/hypothesis_unit.py) calls it per grid step, which is
    what makes interpret-mode parity bit-for-bit.  `iterative_topk`
    picks the Mosaic-friendly k-pass argmax selection (the kernel path;
    no sort primitive on TPU) over one `lax.top_k` — both have the same
    semantics exactly (descending, ties to the lowest index; the score
    domain is bounded below by NEG_INF, never -inf, and k <= N, so the
    argmax loop can never re-pick an exhausted slot).
    """
    n = key_s.shape[0]
    head = jnp.concatenate(
        [jnp.ones((1,), bool), key_s[1:] != key_s[:-1]])     # segment starts
    tail = jnp.concatenate([head[1:], jnp.ones((1,), bool)])  # segment ends
    live = key_s != HASH_SENTINEL

    def seg_lse(v):
        """Backward segmented inclusive logsumexp scan (Hillis-Steele):
        out[j] = logsumexp(v[j : end of j's segment])."""
        val, done = v, tail
        d = 1
        while d < n:
            nxt_val = jnp.concatenate(
                [val[d:], jnp.full((d,), NEG_INF, val.dtype)])
            nxt_done = jnp.concatenate([done[d:], jnp.zeros((d,), bool)])
            val = jnp.where(done, val, jnp.logaddexp(val, nxt_val))
            done = done | nxt_done
            d *= 2
        return val

    pb_m = seg_lse(pb_s)
    pnb_m = seg_lse(pnb_s)
    # an all-dead channel stays exactly NEG_INF (streaming logaddexp of
    # -1e30 terms drifts by +log(count) ulps otherwise)
    pb_m = jnp.where(pb_m > NEG_INF / 2, pb_m, NEG_INF)
    pnb_m = jnp.where(pnb_m > NEG_INF / 2, pnb_m, NEG_INF)

    rep = head & live                       # one representative per live hash
    tot = jnp.where(rep, jnp.logaddexp(pb_m, pnb_m), NEG_INF)
    best = jnp.max(tot)

    if iterative_topk:
        def pick(i, carry):
            t, pos = carry
            j = jnp.argmax(t).astype(jnp.int32)   # ties -> lowest index
            return t.at[j].set(-jnp.inf), pos.at[i].set(j)

        _, pos = jax.lax.fori_loop(
            0, k, pick, (tot, jnp.zeros((k,), jnp.int32)))
        top = tot[pos]
    else:
        top, pos = jax.lax.top_k(tot, k)
        pos = pos.astype(jnp.int32)
    valid = (top > NEG_INF / 2) & (top >= best - beam)
    pb = jnp.where(valid, pb_m[pos], NEG_INF)
    pnb = jnp.where(valid, pnb_m[pos], NEG_INF)
    return pos, pb, pnb, valid.astype(jnp.int32)


def hypothesis_unit(hashes, pb, pnb, *, k: int, beam: float):
    """Batched fused hypothesis unit, pure jnp (the kernel's oracle).

    hashes: (B, N) int32 31-bit prefix hashes; pb/pnb: (B, N) f32.
    Returns dict of (B, k) arrays: `idx` (selected candidate index into
    the ORIGINAL row), merged `pb`/`pnb`, and boolean `valid`.
    """
    n = hashes.shape[-1]
    valid_in = jnp.logaddexp(pb, pnb) > NEG_INF / 2
    key = jnp.where(valid_in, hashes.astype(jnp.uint32), HASH_SENTINEL)
    order = jnp.argsort(key, axis=-1, stable=True)
    key_s = jnp.take_along_axis(key, order, axis=-1)
    pb_s = jnp.take_along_axis(pb, order, axis=-1)
    pnb_s = jnp.take_along_axis(pnb, order, axis=-1)
    row = jax.vmap(
        lambda ks, ps, qs: merge_select_sorted(ks, ps, qs, k=k, beam=beam))
    pos, opb, opnb, oval = row(key_s, pb_s, pnb_s)
    idx = jnp.minimum(jnp.take_along_axis(order, pos, axis=-1), n - 1)
    return {"idx": idx, "pb": opb, "pnb": opnb, "valid": oval.astype(bool)}


def tds_conv(x, w, b, stride=1):
    """Causal strided time conv. x: (T_pad, W, Cin) already left-padded by
    k-1; w: (k, Cin, Cout); returns (T_out, W, Cout) with
    T_out = (T_pad - k + 1 + stride - 1) // stride ... callers pass
    T_pad = k - 1 + T_in with T_in % stride == 0, giving T_in // stride."""
    k = w.shape[0]
    T_in = x.shape[0] - (k - 1)
    t_out = T_in // stride
    off = (jnp.arange(t_out) * stride)[:, None] + jnp.arange(k)[None, :]
    win = x[off]                                    # (t_out, k, W, Cin)
    return jnp.einsum("tkwc,kcd->twd", win, w) + b
