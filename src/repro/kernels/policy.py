"""Kernel dispatch policy: ref / interpret / Mosaic, resolved per backend.

Every public wrapper in `kernels/ops.py` takes an optional `KernelPolicy`
(threaded from `EngineConfig.kernels` by the serving engines) and picks
one of three execution modes:

  * ``ref``       — the pure-jnp oracle in `kernels/ref.py`, compiled by
                    XLA.  The fast path on CPU for ops in the decode hot
                    loop (interpret mode runs the kernel body in Python
                    per grid step, which is debug-speed only).
  * ``interpret`` — the Pallas kernel under the interpreter.  How kernel
                    correctness is validated against ref.py on CPU.
  * ``mosaic``    — the same pallas_call compiled by Mosaic (TPU).

``auto`` (the default) resolves per backend: ``mosaic`` on an
accelerator; on CPU, ``interpret`` for the standalone validation kernels
but ``ref`` for hot-path ops (the fused hypothesis unit runs inside the
per-frame decode scan).  The backend probe is hoisted out of the call
path — `jax.default_backend()` is read once per process, not per call
(it used to be re-queried by every op via `ops._interpret`).

Dispatch composes with `shard_map` (the mesh-sharded serving step runs
every hot-path op inside a per-device program): resolution happens at
Python trace time, outside any mesh axis, so ``ref``/``interpret``
lower to ordinary per-device XLA/Pallas calls on the shard-local
shapes, and ``mosaic`` keeps one pallas_call per device.  Only the
model-parallel matmul wrappers themselves (ops.int8_matmul_prepared's
``axis=``, tds.forward_batched's contraction) ever touch the mesh axis
— kernels never psum internally (Mosaic-under-shard_map shares the
real-TPU caveat tracked in ROADMAP.md).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax

MODES = ("auto", "ref", "interpret", "mosaic")


@functools.lru_cache(maxsize=1)
def _default_backend() -> str:
    return jax.default_backend()


@dataclass(frozen=True)
class KernelPolicy:
    """Frozen kernel-dispatch spec carried by `EngineConfig`."""
    mode: str = "auto"

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(f"mode must be one of {MODES}, got {self.mode!r}")

    def resolve(self, *, hot: bool = False) -> str:
        """Concrete mode for one op.  `hot=True` marks ops on the decode
        hot path, which `auto` never sends through the interpreter."""
        if self.mode != "auto":
            return self.mode
        if _default_backend() == "cpu":
            return "ref" if hot else "interpret"
        return "mosaic"


DEFAULT_POLICY = KernelPolicy()


def resolve(policy: KernelPolicy | None, *, hot: bool = False) -> str:
    return (policy if policy is not None else DEFAULT_POLICY).resolve(hot=hot)


# Kernel contract registry, consumed by `python -m repro.analysis`
# (rules RPL002 + RPL007): every module under kernels/ with a
# `pl.pallas_call` site declares its ref.py twin, the interpret-parity
# test that pins kernel==ref, the public "entry" wrapper whose
# signature must stay call-compatible with a ref twin (RPL007 checks
# parity and that the divisibility guard dominates each pallas_call in
# the entry's reach), and how its grid/BlockSpec divisibility
# assumption is handled — "checked" means the module itself guards it
# with a divisibility check (assert / pad / tile-halving),
# "fallback: ..." documents why no in-module check is needed.  Must
# stay a pure dict literal: the analyzer reads it with
# ast.literal_eval, never imports.
KERNEL_REGISTRY = {
    "tds_conv": {
        "ref": ["tds_conv", "tds_conv_fused"],
        "entry": "tds_conv_pallas",
        "test": "tests/test_kernels.py",
        "shape_guard": "checked",   # stride assert + bt halved to divide
    },
    "layernorm": {
        "ref": ["layernorm", "rmsnorm"],
        "entry": "norm_pallas",
        "test": "tests/test_kernels.py",
        "shape_guard": "checked",   # rows padded to the bt tile
    },
    "logmel": {
        "ref": "logmel",
        "entry": "logmel_pallas",
        "test": "tests/test_kernels.py",
        "shape_guard": "checked",   # frames padded to the bt tile
    },
    "flash_attention": {
        "ref": "flash_attention",
        "entry": "flash_attention_pallas",
        "test": "tests/test_kernels.py",
        "shape_guard": "checked",   # asserts Sq/Sk divisible by blocks
    },
    "beam_prune": {
        "ref": "beam_prune",
        "entry": "beam_prune_pallas",
        "test": "tests/test_kernels.py",
        "shape_guard": "checked",   # candidates padded to the bn tile
    },
    "int8_matmul": {
        "ref": "int8_matmul",
        "entry": "int8_matmul_pallas",
        "test": "tests/test_kernels.py",
        "shape_guard": "checked",   # bm/bn/bk asserted or halved to fit
    },
    "hypothesis_unit": {
        "ref": ["hypothesis_unit", "merge_select_sorted"],
        "entry": "hypothesis_unit_pallas",
        "test": "tests/test_hypothesis_unit.py",
        "shape_guard": "fallback: callers route through "
                       "ops._hypothesis_unit, which pads candidate rows "
                       "to a multiple of 128 before the pallas_call",
    },
}
