"""Flash-attention Pallas kernel (fwd): online softmax in VMEM, causal +
sliding-window masking, block-skipping for fully-masked KV tiles.

This is the TPU execution path for `models.layers.attention_chunked`
(which is also its oracle, via ref.flash_attention).  Unlike the pure-JAX
scan (which must visit every (q, kv) chunk and mask), the kernel skips
out-of-causal-range and out-of-window KV blocks entirely via pl.when —
the "useful ratio" the §Roofline analysis attributes to the Pallas path.

Grid: (B*H, nq, nkv), kv innermost (sequential); scratch: m, l, acc.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

MASK = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale, causal, window, block_q, block_kv, nkv, q_offset):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = iq * block_q + q_offset           # absolute q positions
    kv_start = ik * block_kv
    # block-level skip: causal (kv entirely after q) / window (entirely before)
    run = jnp.bool_(True)
    if causal:
        run &= kv_start <= q_start + block_q - 1
    if window is not None:
        run &= kv_start + block_kv - 1 >= q_start - window + 1

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32)        # (bq, D)
        k = k_ref[0].astype(jnp.float32)        # (bkv, D)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_kv), 0)
        kpos = kv_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_kv), 1)
        mask = jnp.ones((block_q, block_kv), jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= (qpos - kpos) < window
        s = jnp.where(mask, s, MASK)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_ref[...] = m_new

    @pl.when(ik == nkv - 1)
    def _out():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "block_kv", "interpret"))
def flash_attention_pallas(q, k, v, *, causal=True, window=None,
                           block_q=128, block_kv=128, interpret=False):
    """q: (B,H,Sq,D); k,v: (B,H,Skv,D) (GQA pre-expanded) -> (B,H,Sq,D).

    When Sq < Skv (decode tail), q positions are right-aligned to the end
    of kv (q_offset = Skv - Sq), matching ref.flash_attention.
    """
    B, H, Sq, D = q.shape
    Skv = k.shape[2]
    block_q = min(block_q, Sq)
    block_kv = min(block_kv, Skv)
    assert Sq % block_q == 0 and Skv % block_kv == 0
    nq, nkv = Sq // block_q, Skv // block_kv
    scale = 1.0 / (D ** 0.5)
    qr = q.reshape(B * H, Sq, D)
    kr = k.reshape(B * H, Skv, D)
    vr = v.reshape(B * H, Skv, D)
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal, window=window,
                          block_q=block_q, block_kv=block_kv, nkv=nkv,
                          q_offset=Skv - Sq),
        grid=(B * H, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_kv, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_kv, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(qr, kr, vr)
    return out.reshape(B, H, Sq, D)
