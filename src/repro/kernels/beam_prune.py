"""Hypothesis-unit beam-threshold prune as a Pallas kernel (paper §3.5).

Two grid passes over the candidate score vector: pass 0 reduces the
global max into SMEM scratch; pass 1 masks scores below (max - beam).
This standalone threshold stage predates the fused hypothesis unit
(kernels/hypothesis_unit.py merges + thresholds + top-k selects in one
pallas_call — the decode hot path uses that); it survives as the
minimal two-pass reduction example and is still parity-tested.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

MASK = -1e30


def _kernel(s_ref, o_ref, best_ref, *, beam):
    phase = pl.program_id(0)
    i = pl.program_id(1)

    @pl.when((phase == 0) & (i == 0))
    def _init():
        best_ref[0] = -jnp.inf

    @pl.when(phase == 0)
    def _reduce():
        best_ref[0] = jnp.maximum(best_ref[0], jnp.max(s_ref[...]))

    @pl.when(phase == 1)
    def _mask():
        thr = best_ref[0] - beam
        s = s_ref[...]
        o_ref[...] = jnp.where(s >= thr, s, MASK)


@functools.partial(jax.jit, static_argnames=("beam", "bn", "interpret"))
def beam_prune_pallas(scores, beam, *, bn=1024, interpret=False):
    """scores: (N,) f32 -> pruned scores (entries < max - beam -> -1e30)."""
    N = scores.shape[0]
    bn = min(bn, N)
    pad = (-N) % bn
    if pad:
        scores = jnp.pad(scores, (0, pad), constant_values=MASK)
    Np = N + pad
    beam = float(beam)  # static
    out = pl.pallas_call(
        functools.partial(_kernel, beam=beam),
        grid=(2, Np // bn),
        in_specs=[pl.BlockSpec((bn,), lambda p, i: (i,))],
        out_specs=pl.BlockSpec((bn,), lambda p, i: (i,)),
        out_shape=jax.ShapeDtypeStruct((Np,), jnp.float32),
        scratch_shapes=[pltpu.SMEM((1,), jnp.float32)],
        interpret=interpret,
        compiler_params=compat.CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary")),
    )(scores)
    return out[:N]
