"""Fused hypothesis unit (paper §3.5) as ONE Pallas kernel.

ASRPU's hypothesis unit is a single hardware block that merges duplicate
hypotheses (same prefix hash), applies the beam threshold, and sort-
selects the surviving top-K — previously reproduced as three separate
stages (argsort merge in core/hypothesis.py, an optional two-pass Pallas
threshold prune in kernels/beam_prune.py, and an XLA lax.top_k).  This
kernel fuses merge + threshold + top-k into one pallas_call with a
batch (stream-slot) grid axis, so the whole per-frame selection runs in
one VMEM-resident pass per slot.

Division of labour: the hash ORDERING itself (the hardware sort unit's
first half) stays outside as one batched XLA argsort — sorting is the
one primitive Mosaic has no native story for — and the kernel consumes
the sorted row: segmented logsumexp merge (Hillis-Steele doubling, no
O(N^2) equality matrix), threshold, and iterative top-k selection.

The kernel body calls the same `ref.merge_select_sorted` row function
the pure-jnp oracle vmaps, which is what makes interpret-mode parity on
CPU bit-for-bit (tests/test_hypothesis_unit.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import ref


def _kernel(key_ref, pb_ref, pnb_ref, pos_ref, opb_ref, opnb_ref, oval_ref,
            *, k, beam):
    pos, pb, pnb, valid = ref.merge_select_sorted(
        key_ref[0], pb_ref[0], pnb_ref[0], k=k, beam=beam,
        iterative_topk=True)   # no sort primitive inside Mosaic kernels
    pos_ref[0] = pos
    opb_ref[0] = pb
    opnb_ref[0] = pnb
    oval_ref[0] = valid


@functools.partial(jax.jit, static_argnames=("k", "beam", "interpret"))
def hypothesis_unit_pallas(key_s, pb_s, pnb_s, *, k, beam, interpret=False):
    """key_s: (B, N) uint32 sorted keys; pb_s/pnb_s: (B, N) f32 sorted
    channels.  One grid step per batch row (stream slot).  Returns
    (pos, pb, pnb, valid) each (B, k); `pos` indexes the sorted row."""
    B, N = key_s.shape
    row = lambda b: (b, 0)
    return pl.pallas_call(
        functools.partial(_kernel, k=k, beam=float(beam)),
        grid=(B,),
        in_specs=[pl.BlockSpec((1, N), row)] * 3,
        out_specs=(pl.BlockSpec((1, k), row), pl.BlockSpec((1, k), row),
                   pl.BlockSpec((1, k), row), pl.BlockSpec((1, k), row)),
        out_shape=(jax.ShapeDtypeStruct((B, k), jnp.int32),
                   jax.ShapeDtypeStruct((B, k), jnp.float32),
                   jax.ShapeDtypeStruct((B, k), jnp.float32),
                   jax.ShapeDtypeStruct((B, k), jnp.int32)),
        interpret=interpret,
    )(key_s, pb_s, pnb_s)
