"""Fused LayerNorm / RMSNorm Pallas kernel (f32 statistics, row-tiled).

One pass per row block: mean/var reduction + normalize + affine, fused so
x is read from HBM once (the separate mean/var/normalize HLO chain reads
it three times — this is a memory-roofline kernel).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, s_ref, b_ref, o_ref, *, eps, kind):
    x = x_ref[...].astype(jnp.float32)
    if kind == "layernorm":
        mu = x.mean(axis=-1, keepdims=True)
        var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
        y = (x - mu) * jax.lax.rsqrt(var + eps)
    else:
        var = (x ** 2).mean(axis=-1, keepdims=True)
        y = x * jax.lax.rsqrt(var + eps)
    y = y * s_ref[...][None, :]
    if b_ref is not None:
        y = y + b_ref[...][None, :]
    o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("kind", "eps", "bt", "interpret"))
def norm_pallas(x, scale, bias=None, *, kind="layernorm", eps=1e-5, bt=256,
                interpret=False):
    """x: (T, D); scale/bias: (D,). kind: layernorm | rmsnorm.

    Rows are independent, so T is padded up to a multiple of the row
    tile (zero rows normalize to finite values under the eps guard) and
    the pad is sliced off — any row count runs, not just multiples of
    `bt`."""
    T, D = x.shape
    bt = min(bt, T)
    pad = (-T) % bt
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    args = [x, scale] + ([bias] if bias is not None else [])
    in_specs = [pl.BlockSpec((bt, D), lambda i: (i, 0)),
                pl.BlockSpec((D,), lambda i: (0,))]
    if bias is not None:
        in_specs.append(pl.BlockSpec((D,), lambda i: (0,)))
        kernel = functools.partial(_kernel, eps=eps, kind=kind)
    else:
        def kernel(x_ref, s_ref, o_ref):
            _kernel(x_ref, s_ref, None, o_ref, eps=eps, kind=kind)
    Tp = T + pad
    out = pl.pallas_call(
        kernel,
        grid=(Tp // bt,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bt, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Tp, D), x.dtype),
        interpret=interpret,
    )(*args)
    return out[:T]
