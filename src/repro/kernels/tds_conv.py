"""TDS time-convolution Pallas kernel (causal, strided, slot-batched).

The conv kernels of the acoustic-scoring phase (paper §4.2).  Input blocks
overlap by the (k-1)-frame left halo — each grid step slices its context
out of the resident input, exactly like the shared-memory input windows
the ASRPU setup threads retain between kernels.  Channel mixing is
per-w-column (k taps of (Cin x Cout) matmuls on the MXU), and the conv
epilogue — bias, ReLU, TDS residual — is fused into the kernel so the
activation never round-trips to HBM between conv and epilogue.

A leading slot axis maps to a batch grid dimension: the serving engine
runs every concurrent stream's conv in ONE pallas_call.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, b_ref, *rest, k, stride, bt, W, Cin, Cout, relu):
    # x_ref holds one slot's whole padded input (ASRPU keeps conv inputs
    # resident in shared memory between kernels; TDS inputs are small
    # enough that the VMEM analogue is exact).  Each grid step produces a
    # bt-row tile of one slot.
    res_ref, o_ref = (rest if len(rest) == 2 else (None, rest[0]))
    i = pl.program_id(1)
    x = x_ref[0]                         # (Tp, W*Cin)
    w = w_ref[...]                       # (k, Cin, Cout)
    start = i * bt * stride
    acc = jnp.zeros((bt * W, Cout), jnp.float32)
    for j in range(k):
        xj = jax.lax.dynamic_slice_in_dim(x, start + j, bt * stride, axis=0)
        if stride > 1:
            xj = xj.reshape(bt, stride, W * Cin)[:, 0]
        xj = xj.reshape(bt * W, Cin)
        acc += jax.lax.dot(xj.astype(jnp.float32),
                           w[j].astype(jnp.float32))
    acc = acc.reshape(bt, W, Cout) + b_ref[...][None, None, :]
    acc = acc.reshape(bt, W * Cout)
    if relu:
        acc = jnp.maximum(acc, 0.0)
    if res_ref is not None:
        acc = acc + res_ref[0]
    o_ref[0] = acc


@functools.partial(jax.jit,
                   static_argnames=("stride", "bt", "relu", "interpret"))
def tds_conv_pallas(x, w, b, res=None, *, stride=1, bt=32, relu=False,
                    interpret=False):
    """x: (B, k-1+T, W, Cin) left-padded input (a 3-D (k-1+T, W, Cin)
    input is treated as B=1); w: (k, Cin, Cout); b: (Cout,); optional
    res: (B, T // stride, W, Cout) residual added after the ReLU.

    Returns (B, T // stride, W, Cout) (batch squeezed for 3-D inputs),
    matching ref.tds_conv_fused.  Output t consumes
    x[:, t*stride : t*stride + k] (causal window ending at t*stride +
    k - 1 in padded coords).  `bt` is halved until it divides the output
    length (same fallback as ops.int8_matmul's bm), so frame counts that
    are not a multiple of the tile still run.
    """
    squeeze = x.ndim == 3
    if squeeze:
        x = x[None]
        res = None if res is None else res[None]
    k, Cin, Cout = w.shape
    B, Tp, W, _ = x.shape
    T = Tp - (k - 1)
    assert T % stride == 0
    t_out = T // stride
    bt = min(bt, t_out)
    while t_out % bt:
        bt //= 2
    xf = x.reshape(B, Tp, W * Cin)
    in_specs = [
        pl.BlockSpec((1, Tp, W * Cin), lambda s, i: (s, 0, 0)),
        pl.BlockSpec((k, Cin, Cout), lambda s, i: (0, 0, 0)),
        pl.BlockSpec((Cout,), lambda s, i: (0,)),
    ]
    args = [xf, w, b]
    if res is not None:
        in_specs.append(pl.BlockSpec((1, bt, W * Cout),
                                     lambda s, i: (s, i, 0)))
        args.append(res.reshape(B, t_out, W * Cout))
    out = pl.pallas_call(
        functools.partial(_kernel, k=k, stride=stride, bt=bt, W=W,
                          Cin=Cin, Cout=Cout, relu=relu),
        grid=(B, t_out // bt),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bt, W * Cout), lambda s, i: (s, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, t_out, W * Cout), jnp.float32),
        interpret=interpret,
    )(*args)
    out = out.reshape(B, t_out, W, Cout)
    return out[0] if squeeze else out
