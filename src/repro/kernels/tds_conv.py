"""TDS time-convolution Pallas kernel (causal, strided).

The conv kernels of the acoustic-scoring phase (paper §4.2).  Input blocks
overlap by the (k-1)-frame left halo — the BlockSpec index_map strides by
the un-haloed tile so each grid step sees its context, exactly like the
shared-memory input windows the ASRPU setup threads retain between
kernels.  Channel mixing is per-w-column (k taps of (Cin x Cout) matmuls
on the MXU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, b_ref, o_ref, *, k, stride, bt, W, Cin, Cout):
    # x_ref holds the whole padded input (ASRPU keeps conv inputs resident
    # in shared memory between kernels; TDS inputs are small enough that
    # the VMEM analogue is exact).  Each grid step produces a bt-row tile.
    i = pl.program_id(0)
    x = x_ref[...]                       # (Tp, W*Cin)
    w = w_ref[...]                       # (k, Cin, Cout)
    start = i * bt * stride
    acc = jnp.zeros((bt * W, Cout), jnp.float32)
    for j in range(k):
        xj = jax.lax.dynamic_slice_in_dim(x, start + j, bt * stride, axis=0)
        if stride > 1:
            xj = xj.reshape(bt, stride, W * Cin)[:, 0]
        xj = xj.reshape(bt * W, Cin)
        acc += jax.lax.dot(xj.astype(jnp.float32),
                           w[j].astype(jnp.float32))
    acc = acc.reshape(bt, W, Cout) + b_ref[...][None, None, :]
    o_ref[...] = acc.reshape(bt, W * Cout)


@functools.partial(jax.jit, static_argnames=("stride", "bt", "interpret"))
def tds_conv_pallas(x, w, b, *, stride=1, bt=32, interpret=False):
    """x: (k-1+T, W, Cin) left-padded input; w: (k, Cin, Cout); b: (Cout,).

    Returns (T // stride, W, Cout), matching ref.tds_conv.  Output t
    consumes x[t*stride : t*stride + k] (causal window ending at
    t*stride + k - 1 in padded coords).
    """
    k, Cin, Cout = w.shape
    Tp, W, _ = x.shape
    T = Tp - (k - 1)
    assert T % stride == 0
    t_out = T // stride
    bt = min(bt, t_out)
    assert t_out % bt == 0, (t_out, bt)
    xf = x.reshape(Tp, W * Cin)
    out = pl.pallas_call(
        functools.partial(_kernel, k=k, stride=stride, bt=bt, W=W,
                          Cin=Cin, Cout=Cout),
        grid=(t_out // bt,),
        in_specs=[
            pl.BlockSpec((Tp, W * Cin), lambda i: (0, 0)),
            pl.BlockSpec((k, Cin, Cout), lambda i: (0, 0, 0)),
            pl.BlockSpec((Cout,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bt, W * Cout), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t_out, W * Cout), jnp.float32),
        interpret=interpret,
    )(xf, w, b)
    return out.reshape(t_out, W, Cout)
