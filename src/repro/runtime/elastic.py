"""Elastic re-meshing: resume a job on a different topology.

Checkpoints store logical host arrays (ckpt/checkpoint.py), never device
layouts, so a restart can build whatever mesh the surviving fleet
supports and re-place state with that mesh's shardings.  This module is
the policy layer: pick a mesh from an available chip count, rescale the
data-parallel stream, and re-place a restored state.

On a real cluster the coordinator calls `plan_remesh` after failure
detection (runtime/fault.StepWatchdog escalation) with the surviving
chip count; here it is exercised by tests/test_elastic.py on host
devices.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax

from repro.parallel import sharding as shlib


@dataclass(frozen=True)
class RemeshPlan:
    data: int
    model: int
    pod: Optional[int] = None

    @property
    def n_devices(self) -> int:
        return self.data * self.model * (self.pod or 1)

    def axis_names(self):
        return (("pod", "data", "model") if self.pod else ("data", "model"))

    def shape(self):
        return ((self.pod, self.data, self.model) if self.pod
                else (self.data, self.model))


def plan_remesh(n_devices: int, *, model_parallel: int,
                global_batch: int) -> RemeshPlan:
    """Choose (data, model) for the surviving fleet.

    model_parallel is preserved (weights layouts assume it); the data
    axis absorbs the loss.  The global batch must stay divisible so the
    deterministic data stream re-partitions exactly (data/pipeline.py is
    a pure function of (seed, step, shard))."""
    assert n_devices % model_parallel == 0, (n_devices, model_parallel)
    data = n_devices // model_parallel
    while data > 1 and global_batch % data != 0:
        data -= 1            # shrink to a divisor of the global batch
    return RemeshPlan(data=data, model=model_parallel)


def build_mesh(plan: RemeshPlan):
    return jax.make_mesh(plan.shape(), plan.axis_names())


def mesh_invariant_rng() -> None:
    """Elastic precondition: `jax.random` must produce the same LOGICAL
    values whatever mesh a jitted init runs under.  jax's legacy
    threefry lowering is sharding-dependent — `jax.jit(init,
    out_shardings=...)` on a 4x2 mesh and on a 2x2 mesh produce
    *different parameters from the same key* (observed ~0.5 max delta
    on the danube tiny config), so a resumed job could never be
    compared against — or reproduce — a straight run on the surviving
    topology.  Partitionable threefry makes generation
    placement-invariant (delta exactly 0).  Called by the training
    launcher before any RNG use; restarts therefore re-derive identical
    logical state regardless of the remesh plan."""
    jax.config.update("jax_threefry_partitionable", True)


def replace_state(cfg, checkpointer, state_template, mesh, step=None):
    """Restore a checkpoint INTO the new mesh's shardings (the elastic
    restart path: topology changed, logical state identical).

    Optimizer moments get their OWN sharding tree
    (`launch.steps._opt_shardings_like`): moments inherit parameter
    rules by path, which also covers int8 moment payloads
    ({'q','scale'} leaves) — the old code re-used the raw param
    shardings for 'm'/'v', which mis-places (and crashes on) quantized
    moment trees after `plan_remesh` shrinks the data axis."""
    if mesh is None:
        return checkpointer.restore(state_template, step=step)
    from repro.launch.steps import _opt_shardings_like
    p_sh = shlib.param_shardings(cfg, state_template["params"], mesh)
    o_sh = _opt_shardings_like(cfg, state_template["opt"], mesh)
    return checkpointer.restore(
        state_template, step=step,
        shardings={"params": p_sh, "opt": o_sh, "step": None})
