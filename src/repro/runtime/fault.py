"""Fault tolerance + straggler mitigation for the training loop.

Single-container reproduction of the multi-node protocol (documented for
the 1000+ node posture in DESIGN.md §5):

  * `run_resilient(step_fn)` — retries transient step failures, restores
    from the last good checkpoint after `max_retries` (node-loss path:
    on a real cluster the coordinator re-forms the mesh first; here the
    restore path itself is exercised).
  * `StepWatchdog` — EMA step-timer; a step slower than `threshold x` the
    EMA flags a straggler.  On TPU pods real mitigation is re-slicing /
    hot-spare swap; the watchdog is the detection half, and its signal is
    what `run_resilient` escalates on.
  * `Heartbeat` — liveness file another process can monitor (what a
    cluster agent would export to the coordinator).
"""
from __future__ import annotations

import pathlib
import time
from dataclasses import dataclass
from typing import Callable, Optional


@dataclass
class StepWatchdog:
    threshold: float = 3.0
    ema_decay: float = 0.9
    ema: Optional[float] = None
    stragglers: int = 0

    def observe(self, dt: float) -> bool:
        """Returns True if this step is a straggler."""
        if self.ema is None:
            self.ema = dt
            return False
        slow = dt > self.threshold * self.ema
        if slow:
            self.stragglers += 1
        else:  # only healthy steps update the baseline
            self.ema = self.ema_decay * self.ema + (1 - self.ema_decay) * dt
        return slow


@dataclass
class Heartbeat:
    path: str

    def beat(self, step: int):
        p = pathlib.Path(self.path)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(f"{step} {time.time()}\n")


class TransientError(RuntimeError):
    """Raised by step functions to simulate recoverable node failures."""


def run_resilient(step_fn: Callable, state, start_step: int, n_steps: int,
                  checkpointer=None, ckpt_every: int = 50,
                  max_retries: int = 2, watchdog: Optional[StepWatchdog] = None,
                  heartbeat: Optional[Heartbeat] = None,
                  on_metrics: Optional[Callable] = None):
    """Run `n_steps` of `step_fn(state, step) -> (state, metrics)` with
    retry -> restore-from-checkpoint escalation. Returns (state, stats)."""
    stats = {"retries": 0, "restores": 0, "stragglers": 0}
    step = start_step
    while step < start_step + n_steps:
        t0 = time.time()
        try:
            state, metrics = step_fn(state, step)
        except TransientError:
            stats["retries"] += 1
            if stats["retries"] % (max_retries + 1) == max_retries:
                # escalate: restore last good checkpoint (node-loss path)
                if checkpointer is not None and checkpointer.latest_step() is not None:
                    restored = checkpointer.latest_step()
                    state = checkpointer.restore(state)
                    step = restored
                    stats["restores"] += 1
            continue
        dt = time.time() - t0
        if watchdog is not None and watchdog.observe(dt):
            stats["stragglers"] += 1
        if heartbeat is not None:
            heartbeat.beat(step)
        if checkpointer is not None and (step + 1) % ckpt_every == 0:
            checkpointer.save_async(step + 1, state)
        if on_metrics is not None:
            on_metrics(step, metrics, dt)
        step += 1
    if checkpointer is not None:
        checkpointer.wait()
    return state, stats
