"""jamba-v0.1-52b — hybrid Mamba+attention (1:7) with MoE every other layer.

[arXiv:2403.19887; hf]
32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536, MoE 16e top-2.
Period-8 pattern with one attention layer per 8 (position 3), MoE on odd
layers. Hybrid => sub-quadratic => runs long_500k.
"""
from repro.configs.base import ModelConfig, MoESpec, SSMSpec, register

CONFIG = register(ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    layer_pattern="mmmammmm",
    moe=MoESpec(n_experts=16, top_k=2, expert_d_ff=14336, moe_every=2),
    moe_offset=1,
    ssm=SSMSpec(d_state=16, expand=2, head_dim=64, conv_kernel=4),
    rope="none",           # jamba uses no positional encoding
    source="arXiv:2403.19887; hf",
))
