"""llama4-maverick-400b-a17b — MoE decoder, early fusion (text backbone here).

[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]
48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 128e top-1.
Maverick interleaves MoE every other layer with 1 shared expert (matches the
~400B-total / 17B-active name). 128 % 16 == 0 => expert-parallel over 'model'.
"""
from repro.configs.base import ModelConfig, MoESpec, register

CONFIG = register(ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    moe=MoESpec(n_experts=128, top_k=1, expert_d_ff=8192,
                n_shared=1, shared_d_ff=8192, moe_every=2),
    moe_offset=1,
    rope="rope",
    rope_theta=500000.0,
    source="hf:meta-llama/Llama-4; unverified",
))
