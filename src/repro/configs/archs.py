"""Imports every per-arch config module so the registry is populated."""
from repro.configs import (  # noqa: F401
    musicgen_medium,
    llama4_maverick_400b_a17b,
    qwen2_moe_a27b,
    qwen2_72b,
    h2o_danube_18b,
    chatglm3_6b,
    qwen2_vl_7b,
    jamba_v01_52b,
    mamba2_13b,
    tds_asr,
)
