"""qwen2-moe-a2.7b — 4 shared + 60 routed top-4 MoE.

[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]
24L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=151936, MoE 60e top-4.
60 % 16 != 0 => TP-within-expert sharding (expert_d_ff=1408 divisible by 16).
Shared expert fused d_ff = 4*1408 = 5632.
"""
from repro.configs.base import ModelConfig, MoESpec, register

CONFIG = register(ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=151936,
    moe=MoESpec(n_experts=60, top_k=4, expert_d_ff=1408,
                n_shared=4, shared_d_ff=5632, moe_every=1),
    moe_offset=0,
    qkv_bias=True,
    source="hf:Qwen/Qwen1.5-MoE-A2.7B; hf",
))
