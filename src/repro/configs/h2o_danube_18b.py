"""h2o-danube-1.8b — llama+mistral mix with sliding-window attention.

[arXiv:2401.16818; hf]  24L d_model=2560 32H (GQA kv=8) d_ff=6912 vocab=32000.
SWA (window 4096) => sub-quadratic => runs long_500k.
head_dim = 2560/32 = 80.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="h2o-danube-1.8b",
    family="dense",
    n_layers=24,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    d_ff=6912,
    vocab_size=32000,
    attn_window=4096,
    source="arXiv:2401.16818; hf",
))
