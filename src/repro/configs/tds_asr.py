"""Paper case-study configs: the wav2letter TDS ASR system + ASRPU hardware.

The paper (§4) implements an end-to-end wav2letter system: 80-dim MFCC
features, a TDS acoustic model executed as a sequence of 79 kernels
(18 CONV / 29 FC / 32 LayerNorm), and CTC beam-search decoding over a
lexicon trie + n-gram LM, with 9000 acoustic tokens (the last kernel
launches 9000 threads, one per output neuron).

The TDS layer schedule below is chosen to match the paper's kernel counts
exactly:
  front conv (1) + 3 sub-sampling convs + 14 TDS blocks x 1 conv = 18 CONV
  14 TDS blocks x 2 FC + final FC = 29 FC
  14 TDS blocks x 2 LN + 3 sub-sample LN + final LN = 32 LayerNorm (31+1)
Block widths follow Hannun et al. (arXiv:1904.02619) scaled so that FC
layers land in the ~MB range of paper Fig. 9 (1200x1200 fp-weights ~1.4MB
at 8-bit would be 1.4MB: the paper's example "1200 neurons with 1200
inputs each ... 1.4MB" is reproduced by the w=1200 stage).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple


@dataclass(frozen=True)
class TDSStage:
    n_blocks: int
    channels: int        # c
    feat: int            # w per channel; layer width = c*w
    kernel: int          # time kernel width
    subsample: int       # stride of the stage-entry subsampling conv


@dataclass(frozen=True)
class TDSConfig:
    name: str = "tds-wav2letter"
    n_mfcc: int = 80
    # 3 stages; stage entry conv subsamples time by `subsample`.
    stages: Tuple[TDSStage, ...] = (
        TDSStage(n_blocks=2, channels=15, feat=80, kernel=9, subsample=2),
        TDSStage(n_blocks=5, channels=19, feat=80, kernel=9, subsample=2),
        TDSStage(n_blocks=7, channels=23, feat=80, kernel=9, subsample=2),
    )
    sub_kernel: int = 10         # stage-entry subsampling conv kernel
    vocab_size: int = 9000       # paper: "9000 phonetic units"
    dropout: float = 0.0

    @property
    def total_subsample(self) -> int:
        s = 1
        for st in self.stages:
            s *= st.subsample
        return s

    @property
    def n_blocks(self) -> int:
        return sum(st.n_blocks for st in self.stages)

    def kernel_counts(self) -> dict:
        """CONV/FC/LN kernel counts, paper says 18/29/32."""
        n_conv = 1 + len(self.stages) + self.n_blocks          # front+sub+TDS
        n_fc = 2 * self.n_blocks + 1                            # TDS FCs + head
        n_ln = 2 * self.n_blocks + len(self.stages) + 1         # TDS + sub + final
        return {"conv": n_conv, "fc": n_fc, "layernorm": n_ln}


@dataclass(frozen=True)
class FeatureConfig:
    sample_rate: int = 16000
    frame_ms: float = 25.0
    shift_ms: float = 10.0
    n_fft: int = 512
    n_mels: int = 80
    preemphasis: float = 0.97
    fmin: float = 20.0
    fmax: float = 7800.0
    n_mfcc: int = 80             # paper: 80-dim MFCC

    @property
    def frame_len(self) -> int:
        return int(self.sample_rate * self.frame_ms / 1000)

    @property
    def frame_shift(self) -> int:
        return int(self.sample_rate * self.shift_ms / 1000)


@dataclass(frozen=True)
class DecoderConfig:
    beam_size: int = 128         # fixed-K hypothesis memory
    beam_threshold: float = 25.0 # score beam (best - beam) pruning
    lm_weight: float = 1.5
    word_score: float = 1.0     # word insertion bonus
    blank_id: int = 0
    max_children: int = 32       # padded trie fanout


@dataclass(frozen=True)
class ASRPUHardware:
    """Paper Table 2 — used by the analytical performance model."""
    freq_hz: float = 500e6
    n_pes: int = 8
    mac_vector: int = 8
    hyp_mem_bytes: int = 24 * 1024
    icache_bytes: int = 64 * 1024
    shared_mem_bytes: int = 512 * 1024
    model_mem_bytes: int = 1 * 1024 * 1024
    pe_icache_bytes: int = 4 * 1024
    pe_dcache_bytes: int = 24 * 1024
    # paper results to validate against
    step_audio_ms: float = 80.0
    step_exec_ms: float = 40.0   # => 2x real-time
    area_mm2: float = 11.68
    peak_power_w: float = 1.8


TDS_CONFIG = TDSConfig()
FEATURE_CONFIG = FeatureConfig()
DECODER_CONFIG = DecoderConfig()
ASRPU_HW = ASRPUHardware()
