"""Config system: model configs, shape specs, and the assigned-arch registry.

Every assigned architecture is a `ModelConfig`; every workload cell is a
(`ModelConfig`, `ShapeSpec`) pair. Configs are pure data — importing this
module never touches jax device state.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Optional, Sequence


@dataclass(frozen=True)
class MoESpec:
    """Mixture-of-experts block spec (capacity-based sorted dispatch)."""
    n_experts: int
    top_k: int
    expert_d_ff: int
    n_shared: int = 0          # number of "shared expert" units (qwen2-moe: 4)
    shared_d_ff: int = 0       # d_ff of the fused shared expert (0 = none)
    moe_every: int = 1         # MoE layer every N layers (llama4/jamba: 2)
    capacity_factor: float = 1.25
    router_dtype: str = "float32"


@dataclass(frozen=True)
class SSMSpec:
    """Mamba-2 (SSD) block spec."""
    d_state: int = 128
    expand: int = 2
    head_dim: int = 64
    conv_kernel: int = 4
    chunk_size: int = 256
    ngroups: int = 1

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class ShapeSpec:
    """One workload cell shape."""
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


TRAIN_4K = ShapeSpec("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeSpec("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeSpec("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeSpec("long_500k", 524288, 1, "decode")
LM_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


@dataclass(frozen=True)
class ModelConfig:
    """Generic LM-family model configuration.

    `layer_pattern` is a per-period string over {'a': attention, 'm': mamba};
    n_layers must be a multiple of its length.  MoE placement is controlled by
    `moe.moe_every` (layer i is MoE iff i % moe_every == moe_offset).
    """
    name: str
    family: str                # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0          # 0 => d_model // n_heads
    layer_pattern: str = "a"
    moe: Optional[MoESpec] = None
    moe_offset: int = 1
    ssm: Optional[SSMSpec] = None
    rope: str = "rope"         # rope | rope2d | mrope | none
    rope_theta: float = 10000.0
    qkv_bias: bool = False
    attn_window: Optional[int] = None   # sliding-window attention
    norm: str = "rmsnorm"      # rmsnorm | layernorm
    act: str = "silu"          # silu | gelu
    embed_inputs: bool = True  # False => modality frontend stub feeds embeddings
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # attention internals
    attn_chunk_q: int = 512
    attn_chunk_kv: int = 1024
    # sub-quadratic? (controls long_500k applicability)
    source: str = ""

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        assert self.n_layers % len(self.layer_pattern) == 0, (
            self.name, self.n_layers, self.layer_pattern)

    # ---- derived -------------------------------------------------------
    @property
    def period(self) -> int:
        return len(self.layer_pattern)

    @property
    def n_repeats(self) -> int:
        return self.n_layers // self.period

    def layer_kind(self, pos_in_period: int) -> str:
        return {"a": "attn", "m": "mamba"}[self.layer_pattern[pos_in_period]]

    def is_moe_layer(self, layer_idx: int) -> bool:
        if self.moe is None:
            return False
        return layer_idx % self.moe.moe_every == (self.moe_offset % self.moe.moe_every)

    @property
    def sub_quadratic(self) -> bool:
        """True if the arch supports long_500k (SSM/hybrid/SWA)."""
        return ("m" in self.layer_pattern) or (self.attn_window is not None)

    @property
    def has_attention(self) -> bool:
        return "a" in self.layer_pattern

    def shapes(self) -> Sequence[ShapeSpec]:
        """The shape cells this arch runs (long_500k only if sub-quadratic)."""
        out = [TRAIN_4K, PREFILL_32K, DECODE_32K]
        if self.sub_quadratic:
            out.append(LONG_500K)
        return tuple(out)

    def skipped_shapes(self) -> Sequence[ShapeSpec]:
        return () if self.sub_quadratic else (LONG_500K,)

    # ---- parameter counting (for roofline MODEL_FLOPS) ------------------
    def param_counts(self) -> dict:
        """Analytic parameter counts: total and active-per-token."""
        d, hd = self.d_model, self.head_dim
        attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) \
            + (self.n_heads * hd) * d
        if self.qkv_bias:
            attn += (self.n_heads + 2 * self.n_kv_heads) * hd
        dense_mlp = 3 * d * self.d_ff if self.d_ff else 0
        total = 0
        active = 0
        for i in range(self.n_layers):
            kind = self.layer_kind(i % self.period)
            if kind == "attn":
                total += attn
                active += attn
            else:
                s = self.ssm
                di = s.d_inner(d)
                nh = s.n_heads(d)
                m = d * (2 * di + 2 * s.ngroups * s.d_state + nh) \
                    + s.conv_kernel * (di + 2 * s.ngroups * s.d_state) \
                    + di * d + 2 * nh  # A, D
                total += m
                active += m
            if self.is_moe_layer(i):
                e = self.moe
                per_expert = 3 * d * e.expert_d_ff
                total += e.n_experts * per_expert + d * e.n_experts  # + router
                active += e.top_k * per_expert
                if e.shared_d_ff:
                    total += 3 * d * e.shared_d_ff
                    active += 3 * d * e.shared_d_ff
            elif kind == "attn" or (kind == "mamba" and False):
                total += dense_mlp
                active += dense_mlp
            elif kind == "mamba" and self.d_ff:
                # hybrid: mamba layers are followed by MLP/MoE too (jamba)
                total += dense_mlp
                active += dense_mlp
            total += 2 * d  # norms
            active += 2 * d
        emb = self.vocab_size * d
        total += emb + d  # embed + final norm
        active += emb + d
        if not self.tie_embeddings:
            total += emb
            active += emb
        return {"total": total, "active": active}

    # ---- reduced config for CPU smoke tests -----------------------------
    def tiny(self) -> "ModelConfig":
        """Structurally identical, laptop-sized config for smoke tests."""
        kw = dict(
            n_layers=self.period * min(2, self.n_repeats),
            d_model=64,
            n_heads=4,
            n_kv_heads=max(1, min(self.n_kv_heads, 2)),
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab_size=256,
            attn_chunk_q=32,
            attn_chunk_kv=32,
        )
        if self.attn_window is not None:
            kw["attn_window"] = 64
        if self.moe is not None:
            # capacity_factor 8: tiny token counts route unevenly, and the
            # consistency tests (decode == prefill) need drop-free routing
            kw["moe"] = replace(
                self.moe, n_experts=min(self.moe.n_experts, 4),
                top_k=min(self.moe.top_k, 2), expert_d_ff=64,
                shared_d_ff=64 if self.moe.shared_d_ff else 0,
                capacity_factor=8.0)
        if self.ssm is not None:
            kw["ssm"] = replace(self.ssm, d_state=16, head_dim=16, chunk_size=32)
        return replace(self, **kw)


# ----------------------------------------------------------------------------
# registry
# ----------------------------------------------------------------------------
_REGISTRY: dict = {}


def register(cfg: ModelConfig) -> ModelConfig:
    assert cfg.name not in _REGISTRY, cfg.name
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    _ensure_loaded()
    return _REGISTRY[name]


def list_configs() -> list:
    _ensure_loaded()
    return sorted(_REGISTRY)


ASSIGNED_ARCHS = (
    "musicgen-medium",
    "llama4-maverick-400b-a17b",
    "qwen2-moe-a2.7b",
    "qwen2-72b",
    "h2o-danube-1.8b",
    "chatglm3-6b",
    "qwen2-vl-7b",
    "jamba-v0.1-52b",
    "mamba2-1.3b",
)


def _ensure_loaded():
    if _REGISTRY:
        return
    from repro.configs import archs  # noqa: F401  (registers everything)
