"""musicgen-medium — decoder-only transformer over EnCodec tokens.

[arXiv:2306.05284; hf]  48L d_model=1536 24H (GQA kv=24) d_ff=6144 vocab=2048.
Audio frontend (EnCodec) is a STUB per brief: `input_specs()` feeds precomputed
frame embeddings; the backbone is what we model. MusicGen uses LayerNorm + GELU.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    rope="none",            # musicgen uses learned/sinusoidal pos; stubbed frontend
    norm="layernorm",
    act="gelu",
    embed_inputs=False,     # frontend stub provides frame embeddings
    source="arXiv:2306.05284; hf",
))
