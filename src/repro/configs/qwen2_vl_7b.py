"""qwen2-vl-7b — VLM text backbone with M-RoPE (3 position sections).

[arXiv:2409.12191; hf]  28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064.
Vision frontend (dynamic-resolution ViT) is a STUB per brief: `input_specs()`
feeds precomputed patch embeddings + 3-component M-RoPE position ids.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_ff=18944,
    vocab_size=152064,
    rope="mrope",
    qkv_bias=True,
    rope_theta=1000000.0,
    embed_inputs=False,    # frontend stub provides patch embeddings
    source="arXiv:2409.12191; hf",
))
