"""mamba2-1.3b — attention-free SSD (state-space duality) stack.

[arXiv:2405.21060; unverified]
48L d_model=2048 (attn-free) d_ff=0 vocab=50280, ssm_state=128.
d_inner = 2*2048 = 4096, 64 SSD heads of dim 64. Sub-quadratic => long_500k.
ASRPU arch-applicability: the hypothesis unit + streaming decode steps apply
unchanged (SSM state is the inter-step scratchpad); attention sharding paths
are inapplicable and unused (see DESIGN.md §Arch-applicability).
"""
from repro.configs.base import ModelConfig, SSMSpec, register

CONFIG = register(ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=1,            # unused (attention-free)
    n_kv_heads=1,
    head_dim=64,
    d_ff=0,               # no MLP: mamba2 blocks only
    vocab_size=50280,
    layer_pattern="m",
    ssm=SSMSpec(d_state=128, expand=2, head_dim=64, conv_kernel=4),
    rope="none",
    tie_embeddings=True,
    source="arXiv:2405.21060; unverified",
))
