"""int8 error-feedback gradient compression for DP all-reduce.

Beyond-paper distributed-optimization trick (and the natural extension of
ASRPU's int8 MAC to the gradient path): data-parallel gradient
all-reduces move int8 blocks + fp32 block scales (~4x wire reduction vs
bf16) with per-worker error feedback so compression noise is carried, not
lost (Seide et al. / EF-SGD).

`compressed_psum` is built for shard_map: quantize locally -> psum the
int8 payload as int32 (exact) -> dequantize with the summed-scale bound.
The simpler (and exact-on-mean) variant used by default quantizes, psums
dequantized blocks, and keeps the residual locally:

    q, err  = quantize(g + err_prev)
    g_hat   = psum(dequantize(q)) / n
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import quant


def compress(g, err):
    """Returns (payload dict, new_err). g_hat = dequantize(payload)."""
    target = g.astype(jnp.float32) + err
    qs = quant.quantize(target)
    deq = quant.dequantize(qs)
    new_err = target - deq[..., :g.shape[-1]] if deq.shape != g.shape \
        else target - deq
    return qs, new_err


def decompress(qs):
    return quant.dequantize(qs)


def compressed_psum(g, err, axis_name: str):
    """Inside shard_map: error-feedback int8 all-reduce of g over axis."""
    qs, new_err = compress(g, err)
    g_hat = jax.lax.pmean(decompress(qs), axis_name)
    return g_hat.astype(g.dtype), new_err


def init_error(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
