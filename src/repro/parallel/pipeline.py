"""Pipeline parallelism: GPipe-style microbatch schedule over a 'stage'
mesh axis with collective_permute hops, inside shard_map.

Composable feature for depth-dominated deployments (the production
dry-run mesh uses DP x TP, which is the right config for the assigned
sizes; PP becomes necessary past ~1T params or very small per-chip HBM).
Autodiff through the schedule is valid (ppermute transposes to the
reverse permute), giving pipelined backward for free (GPipe semantics,
bubble fraction (S-1)/(M+S-1)).

Tested on a multi-device host platform subprocess (tests/test_pipeline.py).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P
from repro import compat


def pipeline_apply(stage_fn: Callable, stage_params, x_micro, mesh: Mesh,
                   axis: str = "stage"):
    """Run x through `n_stages` chained applications of stage_fn.

    stage_fn: (params_one_stage, x) -> y   (same shape as x)
    stage_params: pytree with leading axis n_stages (sharded over `axis`)
    x_micro: (n_micro, mb, ...) microbatched input (replicated)
    Returns (n_micro, mb, ...) output of the last stage.
    """
    n_stages = mesh.shape[axis]
    n_micro = x_micro.shape[0]
    steps = n_micro + n_stages - 1
    perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def per_stage(params, xm):
        # params: leading axis 1 (this stage's slice); xm: (n_micro, mb, ...)
        p = jax.tree.map(lambda a: a[0], params)
        sid = lax.axis_index(axis)
        buf = jnp.zeros_like(xm[0])                   # current stage input
        outs = jnp.zeros_like(xm)

        def step(carry, t):
            buf, outs = carry
            # stage 0 ingests microbatch t (others ignore)
            inject = jnp.where(t < n_micro, t, n_micro - 1)
            buf = jnp.where(sid == 0, xm[inject], buf)
            y = stage_fn(p, buf)
            # last stage emits microbatch t - (n_stages - 1)
            out_t = t - (n_stages - 1)
            emit = (sid == n_stages - 1) & (out_t >= 0)
            safe_t = jnp.clip(out_t, 0, n_micro - 1)
            outs = jnp.where(
                emit,
                lax.dynamic_update_index_in_dim(outs, y, safe_t, 0),
                outs)
            buf = lax.ppermute(y, axis, perm)
            return (buf, outs), None

        (buf, outs), _ = lax.scan(step, (buf, outs), jnp.arange(steps))
        # gather last stage's outputs to all (replicated output contract)
        return lax.psum(jnp.where(sid == n_stages - 1, outs, 0.0), axis)

    fn = compat.shard_map(
        per_stage, mesh=mesh,
        in_specs=(P(axis), P()), out_specs=P(),
        check_vma=False)
    return fn(stage_params, x_micro)


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    return (n_stages - 1) / (n_micro + n_stages - 1)
