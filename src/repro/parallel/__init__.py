from repro.parallel.sharding import (
    Sharder,
    batch_shardings,
    cache_shardings,
    param_shardings,
)
