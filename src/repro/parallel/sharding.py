"""Sharding rules: DP/FSDP over ('pod','data'), TP/EP/SP over 'model'.

All rules are *path-based* over the params pytree produced by
`models.transformer.LM.init` (leading repeat axis on every 'layers' leaf is
never sharded).  Divisibility of every sharded dim for every assigned arch
is property-tested in tests/test_sharding.py; vocab is Megatron-padded.

Axis roles
  pod, data : batch DP + FSDP weight/optimizer sharding
  model     : tensor parallel (flattened head dim / d_ff / vocab),
              expert parallel (when n_experts % model == 0),
              sequence parallel for long KV caches (decode cells)
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def batch_axes(mesh: Optional[Mesh]):
    if mesh is None:
        return ()
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


class Sharder:
    """Activation sharding-constraint helper threaded through the model.

    REPRO_BASELINE=1 disables the beyond-baseline layout optimizations
    (context-parallel attention) so §Perf can record before/after from
    the same code."""

    def __init__(self, mesh: Optional[Mesh], shard_batch: bool = True):
        import os
        self.mesh = mesh
        self.batch = batch_axes(mesh) if shard_batch else ()
        self.model = "model" if (mesh is not None
                                 and "model" in mesh.axis_names) else None
        self.baseline = os.environ.get("REPRO_BASELINE", "0") == "1"

    def _c(self, x, spec):
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))

    def act(self, x):
        """(B, S, D) activations: batch over DP axes + sequence parallelism
        over 'model' (Korthikanti et al.) — layer-boundary activations (and
        hence the layer-scan backward residual stack) are fully sharded;
        GSPMD inserts the all-gather/reduce-scatter pair around attention."""
        if self.mesh is None:
            return x
        b = self.batch if (self.batch and
                           x.shape[0] % _axsize(self.mesh, self.batch) == 0) else ()
        sp = None
        if (self.model and x.ndim >= 3
                and x.shape[1] % self.mesh.shape["model"] == 0):
            sp = self.model
        return self._c(x, P(b, sp, *([None] * (x.ndim - 2))))

    def seq(self, x):
        """(B, S, K, D) cache-layout kv: S over 'model' (sequence-parallel
        cache storage, matches cache_shardings)."""
        if self.mesh is None or x.ndim != 4 or self.baseline:
            return x
        b = self.batch if (self.batch and
                           x.shape[0] % _axsize(self.mesh, self.batch) == 0) else ()
        s = None
        if self.model and x.shape[1] % self.mesh.shape["model"] == 0:
            s = self.model
        return self._c(x, P(b, s, None, None))

    def attn_q(self, x):
        """(nq, B, K, G, cq, D) chunked-attention q tiles: shard the
        intra-tile cq dim over 'model'.  nq/nkv are *scan* axes (sharding
        them is meaningless — sequential); cq is a parallel dim present in
        every tile, so the (cq x ckv) score tiles shard over the full mesh
        and the inner scans stay collective-free for every arch (head
        counts 40/56/28/24 don't divide 16; cq does)."""
        if self.mesh is None or x.ndim != 6 or self.baseline:
            return x
        b = self.batch if (self.batch and
                           x.shape[1] % _axsize(self.mesh, self.batch) == 0) else ()
        c = None
        if self.model and x.shape[4] % self.mesh.shape["model"] == 0:
            c = self.model
        return self._c(x, P(None, b, None, None, c, None))

    def attn_kv_chunks(self, x):
        """(nkv, B, K, ckv, D) kv chunks: replicated over 'model' (each
        cq-shard needs every kv column)."""
        if self.mesh is None or x.ndim != 5 or self.baseline:
            return x
        b = self.batch if (self.batch and
                           x.shape[1] % _axsize(self.mesh, self.batch) == 0) else ()
        return self._c(x, P(None, b, None, None, None))

    def kv(self, x):
        """(B, Skv, K, D) k/v: batch-sharded, replicated over 'model'
        (one gather per layer instead of per inner step)."""
        if self.mesh is None or x.ndim != 4 or self.baseline:
            return x
        b = self.batch if (self.batch and
                           x.shape[0] % _axsize(self.mesh, self.batch) == 0) else ()
        return self._c(x, P(b, None, None, None))

    def heads(self, x):
        """(B, S, H, ...) mamba/SSD head-major activations: B over DP axes,
        heads over 'model' (mamba is naturally TP over d_inner: depthwise
        conv + per-head SSD never mix heads until out_proj)."""
        if self.mesh is None or x.ndim < 3:
            return x
        b = self.batch if (self.batch and
                           x.shape[0] % _axsize(self.mesh, self.batch) == 0) else ()
        h = None
        if self.model and x.shape[2] % self.mesh.shape["model"] == 0:
            h = self.model
        return self._c(x, P(b, None, h, *([None] * (x.ndim - 3))))

    def inner(self, x):
        """(B, S, d_inner) mamba conv activations: d_inner over 'model'."""
        if self.mesh is None or x.ndim != 3:
            return x
        b = self.batch if (self.batch and
                           x.shape[0] % _axsize(self.mesh, self.batch) == 0) else ()
        d = None
        if self.model and x.shape[2] % self.mesh.shape["model"] == 0:
            d = self.model
        return self._c(x, P(b, None, d))

    def expert(self, x, ep: bool):
        """(E, C, D|F) MoE expert buffers: E over 'model' when
        expert-parallel, capacity over the DP axes (otherwise the data
        axis idles through all expert compute), last dim over 'model'
        for TP-within-expert."""
        if self.mesh is None or x.ndim != 3:
            return x
        e = "model" if (ep and self.model) else None
        c = self.batch if (self.batch and
                           x.shape[1] % _axsize(self.mesh, self.batch) == 0) else None
        f = None
        if (not ep) and self.model and x.shape[2] % self.mesh.shape["model"] == 0:
            f = self.model
        return self._c(x, P(e, c, f))

    def tokens(self, x):
        """(T, ...) flat token-major tensors (MoE dispatch/combine sides).

        T = B*S is sharded over (DP axes, 'model') — the exact layout of a
        sequence-parallel (B, S, D) activation flattened, so dispatch
        entry/exit needs no reshard; GSPMD turns the expert-buffer
        gather/ungather into the MoE all-to-all."""
        if self.mesh is None:
            return x
        axes = self.batch
        if not axes or x.shape[0] % _axsize(self.mesh, axes) != 0:
            return x
        return self._c(x, P(axes, *([None] * (x.ndim - 1))))

    def logits(self, x):
        """(..., V) logits: vocab over model axis."""
        if self.mesh is None:
            return x
        b = self.batch if (self.batch and
                           x.shape[0] % _axsize(self.mesh, self.batch) == 0) else ()
        return self._c(x, P(b, *([None] * (x.ndim - 2)), self.model))


def _axsize(mesh: Mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def axis_size(mesh: Mesh, name: str):
    """Size of mesh axis `name`, or None when the mesh doesn't declare
    it: callers fall back to replicated instead of KeyErroring on a
    mesh without the axis (a 1D ('data',) serving mesh reaching the
    'model' rules, and vice versa)."""
    if name in mesh.axis_names:
        return mesh.shape[name]
    return None


# ---------------------------------------------------------------------------
# parameter sharding rules
# ---------------------------------------------------------------------------
def _param_rule(path: tuple, shape: tuple, cfg, mesh: Mesh) -> P:
    names = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
    # int8 serving weights: 'wq' shards like 'w'; 'wscale' (per-out-channel)
    # takes the w-rule with the contraction dim removed
    if names and names[-1] == "wq":
        names = names[:-1] + ["w"]
    elif names and names[-1] == "wscale":
        fake = tuple(shape[:-1]) + (1 << 22, shape[-1])
        spec_w = _param_rule(_names_path(names[:-1] + ["w"]), fake, cfg, mesh)
        return P(*(list(spec_w)[:-2] + [list(spec_w)[-1]]))
    fsdp = batch_axes(mesh)
    nm = axis_size(mesh, "model")
    in_layers = "layers" in names
    leaf = names[-1]
    parent = names[-2] if len(names) >= 2 else ""

    def spec(*dims):
        if in_layers:
            dims = (None,) + dims  # leading repeat axis
        # drop axes that don't divide evenly (safety net; tested exhaustively)
        out = []
        off = len(shape) - len(dims)
        assert off == 0, (names, shape, dims)
        for size, d in zip(shape, dims):
            if d is None:
                out.append(None)
                continue
            axes = d if isinstance(d, tuple) else (d,)
            if all(a in mesh.axis_names for a in axes) and \
                    _axsize(mesh, axes) and size % _axsize(mesh, axes) == 0:
                out.append(d)
            else:
                out.append(None)
        return P(*out)

    # --- embeddings / head -------------------------------------------------
    if "embed" in names:
        return spec("model", fsdp)
    if "lm_head" in names:
        if leaf == "b":
            return spec("model")
        return spec(fsdp, "model")
    # --- norms / small vectors ---------------------------------------------
    if leaf in ("scale", "bias", "A_log", "D", "dt_bias") or parent in (
            "norm1", "norm2", "final_norm", "norm_gate"):
        return spec(*([None] * (len(shape) - (1 if in_layers else 0))))
    # --- attention -----------------------------------------------------------
    if parent == "wqkv":
        return spec(fsdp, "model") if leaf == "w" else spec("model")
    if parent == "wo":
        return spec("model", fsdp) if leaf == "w" else spec(None)
    # --- MoE -----------------------------------------------------------------
    if "router" in names:
        return spec(fsdp, None)
    if leaf == "w" and parent in ("w_gate", "w_up", "w_down") and "shared" not in names:
        pass  # dense MLP handled below
    if names.count("mlp") and cfg is not None and cfg.moe is not None and \
            len(shape) - (1 if in_layers else 0) == 3:
        ep = nm is not None and cfg.moe.n_experts % nm == 0
        if leaf in ("w_gate", "w_up") or parent in ("w_gate", "w_up"):
            return spec("model", fsdp, None) if ep else spec(None, fsdp, "model")
        return spec("model", None, fsdp) if ep else spec(None, "model", fsdp)
    # --- dense MLP / shared expert / mamba projections -----------------------
    if parent in ("w_gate", "w_up", "w_z", "w_x", "w_B", "w_C", "w_dt"):
        return spec(fsdp, "model") if leaf == "w" else spec("model")
    if parent in ("w_down", "out_proj", "wo"):
        return spec("model", fsdp) if leaf == "w" else spec(None)
    if parent == "conv_x":
        return spec(None, "model") if leaf == "w" else spec("model")
    # fallback: replicate
    return P(*([None] * len(shape)))


class _NK:
    def __init__(self, key):
        self.key = key


def _names_path(names):
    return tuple(_NK(n) for n in names)


def param_shardings(cfg, param_shapes, mesh: Mesh):
    """pytree of NamedSharding matching `param_shapes`."""
    def f(path, leaf):
        return NamedSharding(mesh, _param_rule(path, leaf.shape, cfg, mesh))
    return jax.tree_util.tree_map_with_path(f, param_shapes)


# ---------------------------------------------------------------------------
# TDS serving: model-parallel weight shards (ASRPU pool-of-cores analogue)
# ---------------------------------------------------------------------------
def tds_param_specs(tds_cfg, mesh: Mesh) -> dict:
    """PartitionSpec tree for a TDS params pytree under the serving
    'model' axis: every FC/head weight matrix is split on its feature
    (contraction) axis — each device holds K/n_model weight rows and
    computes a partial sum, exactly ASRPU's pool-of-cores split where
    each program computes one slice of a layer — while convs, LayerNorm
    vectors, and biases stay replicated (they are KBs against the FCs'
    MBs).  Weights whose feature dim does not divide the axis fall back
    to replicated (same safety net as `_param_rule`), as does every
    weight when the mesh has no 'model' axis at all."""
    from repro.models.tds import build_kernel_specs
    nm = axis_size(mesh, "model")
    out = {}
    for s in build_kernel_specs(tds_cfg):
        if s.kind == "layernorm":
            out[s.name] = {"scale": P(), "bias": P()}
        elif s.kind == "conv":
            out[s.name] = {"w": P(), "b": P()}
        else:  # fc / head
            w = P("model", None) if nm and s.n_in % nm == 0 else P()
            out[s.name] = {"w": w, "b": P()}
    return out


def tds_prepared_specs(tds_cfg, mesh: Mesh) -> dict:
    """PartitionSpec tree for `tds.quantize_params` output: the int8
    payload `wq` shards exactly like its source `w` (feature axis); the
    per-output-channel scales `ws` are replicated — activation
    quantization runs on the full (replicated) rows, so the sharded int8
    path sees the same scales as the unsharded one."""
    from repro.models.tds import build_kernel_specs
    nm = axis_size(mesh, "model")
    return {s.name: {"wq": P("model", None) if nm and s.n_in % nm == 0
                     else P(),
                     "ws": P()}
            for s in build_kernel_specs(tds_cfg)
            if s.kind in ("fc", "head")}


def asr_state_specs(tree, mesh: Mesh):
    """PartitionSpec tree sharding the leading SLOT axis of an ASR
    serving state pytree over the 'data' mesh axis (ASRPU's pool of
    parallel decode workers, one sub-pool per data shard).

    Applies uniformly to every per-slot buffer the fused step carries:
    the TDS left-context `StreamState` ((B, k-1, w, c) per conv), the
    `BeamState` leaves ((B, K, ...)), and the gathered step inputs (the
    (b, w, spp) sample batch and the (b,) slot-index vector).  Trailing
    axes stay unsharded — beam expansion is embarrassingly parallel
    across slots, so a data shard holds its slots end-to-end and the
    step needs no cross-shard collectives outside the 'model'-axis
    psums of `tds_param_specs`-sharded matmuls (composes with those by
    construction: state never touches the 'model' axis).  Leaves whose
    leading dim does not divide the axis fall back to replicated (the
    engine enforces divisibility for the pool; this is the same safety
    net as `_param_rule`); so does everything on a mesh with no 'data'
    axis (the 1D model-parallel serving mesh)."""
    nd = axis_size(mesh, "data")

    def f(leaf):
        if nd and leaf.ndim >= 1 and leaf.shape[0] % nd == 0:
            return P("data", *([None] * (leaf.ndim - 1)))
        return P(*([None] * leaf.ndim))

    return jax.tree.map(f, tree)


def place_tree(tree, spec_tree, mesh: Mesh):
    """device_put every leaf with its NamedSharding(mesh, spec)."""
    return jax.tree.map(
        lambda x, sp: jax.device_put(x, NamedSharding(mesh, sp)),
        tree, spec_tree)


# ---------------------------------------------------------------------------
# batch / cache shardings
# ---------------------------------------------------------------------------
def batch_shardings(batch_shapes, mesh: Mesh):
    """Shard dim 0 (global batch) over DP axes when divisible."""
    b_axes = batch_axes(mesh)

    def f(leaf):
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        if leaf.shape[0] % _axsize(mesh, b_axes) == 0 and b_axes:
            return NamedSharding(mesh, P(b_axes, *([None] * (leaf.ndim - 1))))
        return NamedSharding(mesh, P(*([None] * leaf.ndim)))
    return jax.tree.map(f, batch_shapes)


def cache_shardings(cfg, cache_shapes, mesh: Mesh, global_batch: int):
    """KV caches: batch over DP axes when divisible, else sequence-parallel
    over ('data','model'); SSM state heads over 'model'."""
    b_axes = batch_axes(mesh)
    nb = _axsize(mesh, b_axes)
    batch_ok = b_axes and global_batch % nb == 0
    nm = axis_size(mesh, "model")
    seq_axes = ("model",) if batch_ok and nm else tuple(
        a for a in ("data", "model") if a in mesh.axis_names)
    nseq = _axsize(mesh, seq_axes)

    def f(path, leaf):
        names = [getattr(k, "key", str(k)) for k in path]
        leafname = names[-1]
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        if leafname == "kpos":
            return NamedSharding(mesh, P(
                seq_axes if leaf.shape[0] % nseq == 0 else None))
        bspec = b_axes if batch_ok else None
        if leafname in ("k", "v"):           # (R, B, Sc, K, Dh)
            sseq = seq_axes if leaf.shape[2] % nseq == 0 else None
            return NamedSharding(mesh, P(None, bspec, sseq, None, None))
        if leafname == "ssm":                 # (R, B, H, P, N)
            sh = "model" if nm and leaf.shape[2] % nm == 0 else None
            return NamedSharding(mesh, P(None, bspec, sh, None, None))
        if leafname == "conv":                # (R, B, ck-1, di)
            sd = "model" if nm and leaf.shape[3] % nm == 0 else None
            return NamedSharding(mesh, P(None, bspec, None, sd))
        return NamedSharding(mesh, P(*([None] * leaf.ndim)))
    return jax.tree_util.tree_map_with_path(f, cache_shapes)
