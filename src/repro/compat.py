"""Version-compatibility shims for the jax APIs this repo uses.

The codebase targets the modern jax API surface; this module maps those
names onto older runtimes (jax 0.4.x) where they live elsewhere or are
spelled differently:

  * ``jax.shard_map``             -> ``jax.experimental.shard_map.shard_map``
    (and its ``check_vma=`` kwarg -> ``check_rep=``)
  * ``pallas.tpu.CompilerParams`` -> ``pallas.tpu.TPUCompilerParams``
    (resolved lazily: only the Pallas kernel modules pay the
    pallas import / name lookup)
"""
from __future__ import annotations

import jax

if hasattr(jax, "shard_map"):                      # jax >= 0.6
    _shard_map = jax.shard_map
    _CHECK_KW = "check_vma"
else:                                              # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KW = "check_rep"


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    kw = {_CHECK_KW: check_vma}
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kw)


def __getattr__(name):
    if name == "CompilerParams":
        from jax.experimental.pallas import tpu as pltpu
        cp = getattr(pltpu, "CompilerParams", None) \
            or pltpu.TPUCompilerParams
        globals()[name] = cp                       # cache for next lookup
        return cp
    raise AttributeError(name)


def abstract_mesh(axis_sizes, axis_names):
    """jax.sharding.AbstractMesh across the 0.4.x -> modern signature
    change ((name, size) pairs vs separate sizes + names tuples)."""
    try:
        return jax.sharding.AbstractMesh(tuple(axis_sizes),
                                         tuple(axis_names))
    except TypeError:                              # jax 0.4.x
        return jax.sharding.AbstractMesh(
            tuple(zip(axis_names, axis_sizes)))
