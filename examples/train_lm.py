"""End-to-end training driver: train a reduced LM with the production
train loop — sharded step, AdamW, checkpoint/restart, straggler watchdog.

Runs ~200 steps of a tiny h2o-danube (llama-family, SWA) on synthetic
data and demonstrates checkpoint-resume.

  PYTHONPATH=src python examples/train_lm.py
"""
import pathlib
import sys
import tempfile

sys.path.insert(0, str(pathlib.Path(__file__).parents[1] / "src"))

from repro.launch import train


def main():
    ckpt = tempfile.mkdtemp(prefix="repro_ckpt_")
    losses = train.main(["--arch", "h2o-danube-1.8b", "--tiny",
                         "--steps", "200", "--batch", "8", "--seq", "64",
                         "--lr", "1e-3", "--ckpt", ckpt,
                         "--ckpt-every", "100", "--log-every", "50"])
    assert losses[-1] < losses[0], "loss should decrease"
    print("resuming from checkpoint for 20 more steps...")
    train.main(["--arch", "h2o-danube-1.8b", "--tiny", "--steps", "20",
                "--batch", "8", "--seq", "64", "--ckpt", ckpt, "--resume",
                "--log-every", "10"])
    print("OK: trained + checkpoint-resumed")


if __name__ == "__main__":
    main()
