"""End-to-end multi-stream ASR serving on the unified serving engine
(repro.serving.AsrEngine): a slot pool of concurrent utterance streams
advanced by ONE vmapped/jitted decoding step (the ASR twin of
examples/serve_batched_lm.py's continuous batching).

Each utterance is one `Session`; queued sessions are admitted into freed
slots; each slot keeps its own sample buffer, TDS left-context, and
beam; slots without a full 80 ms window are masked so their state passes
through unchanged — per-slot results match the single-stream decoder's
(parity-tested in tests/test_multistream.py and tests/test_serving.py,
including arbitrary-sized `Session.push` chunking).

  PYTHONPATH=src python examples/serve_multistream_asr.py [--streams 4]
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).parents[1] / "src"))

from repro.launch import serve


def main():
    argv = ["--mode", "asr", "--streams", "4", "--utterances", "6"]
    if len(sys.argv) > 1:
        argv = sys.argv[1:]
    serve.main(argv)


if __name__ == "__main__":
    main()
