"""End-to-end serving driver: batched LM inference on the unified
serving engine (repro.serving.LmEngine) — slot-based continuous
batching, the paper's decoding-step structure generalized to LM decode.

Serves a reduced mamba2 (attention-free: the ASRPU streaming-state model
maps directly) with batched requests: each request is one `Session`
(push(prompt) -> poll() for tokens), admission prefills into a pooled
decode cache with PER-SLOT positions (staggered admissions with unequal
prompt lengths stay correct), and every serve step is one fused
decode_step over all slots.

  PYTHONPATH=src python examples/serve_batched_lm.py [--arch mamba2-1.3b]
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).parents[1] / "src"))

from repro.launch import serve


def main():
    argv = ["--mode", "lm", "--arch", "mamba2-1.3b", "--requests", "6",
            "--slots", "4", "--prompt-len", "16", "--max-new", "16"]
    if len(sys.argv) > 1:
        argv = sys.argv[1:]
    serve.main(argv)


if __name__ == "__main__":
    main()
