"""Quickstart: decode one utterance end-to-end on ASRPU (paper §4).

Builds the full pipeline — MFCC features -> TDS acoustic model -> CTC
beam search over a lexicon trie + bigram LM — as a frozen serving
program (`AsrProgram`: the declarative form of the paper's Table 1
configure-command sequence), then streams a synthetic utterance through
a `Session` in 80 ms pushes.  One engine decoding step per full window
== one DecodingStep command; `finish()` == CleanDecoding + final commit.

  PYTHONPATH=src python examples/quickstart.py
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).parents[1] / "src"))

import jax

from repro.configs.tds_asr import DecoderConfig, TDSConfig, TDSStage
from repro.core import lexicon as lx
from repro.data.pipeline import SyntheticASR
from repro.models import tds
from repro.serving import AsrEngine, AsrProgram, EngineConfig


def main():
    # 1. a small TDS acoustic model (same kernel structure as the paper's)
    tds_cfg = TDSConfig(
        stages=(TDSStage(1, 4, 80, 9, 2), TDSStage(1, 4, 80, 9, 2),
                TDSStage(1, 6, 80, 9, 2)),
        vocab_size=32)
    params = tds.init_tds(jax.random.PRNGKey(0), tds_cfg)
    census = tds.kernel_census(tds_cfg)
    print(f"TDS kernels: {census} "
          f"(paper's full system: 18 conv / 29 fc / 32 layernorm)")

    # 2. lexicon trie + bigram LM
    words = {f"word{i}": [1 + (i * 3 + j) % 30 for j in range(2 + i % 3)]
             for i in range(10)}
    lex = lx.build_lexicon(words, max_children=16)
    lm = lx.uniform_bigram(len(words))

    # 3. one frozen program instead of the mutable configure-command
    #    sequence (ConfigureASR_* / ConfigureBeamWidth, paper Table 1)
    program = AsrProgram(tds_cfg, lex, lm,
                         dec_cfg=DecoderConfig(beam_size=32),
                         ).with_beam_width(25.0)
    engine = AsrEngine(EngineConfig(program, n_slots=1), params)
    plan = engine.plan
    print(f"decoding step plan: {plan.samples_per_step} samples -> "
          f"{plan.feat_frames_per_step} feature frames -> "
          f"{plan.acoustic_frames_per_step} acoustic frame(s), "
          f"{len(plan.kernels)} kernels, {plan.total_threads()} threads")

    # 4. stream one synthetic utterance through a serving session
    utt = SyntheticASR(words).utterance(0)
    audio = utt["audio"]
    spp = plan.samples_per_step
    session = engine.open()
    for off in range(0, len(audio), spp):
        session.push(audio[off:off + spp])
        best = session.poll()          # live best hypothesis so far
    best = session.finish()            # end of utterance: commit + free slot
    print(f"decoded {len(audio)/16000:.2f}s of audio in "
          f"{best['steps']} decoding steps")
    print(f"best hypothesis: words={best['words'].tolist()} "
          f"tokens={best['tokens'].tolist()} score={best['score']:.2f}")
    print(f"(untrained acoustic model — structure demo; "
          f"reference words were {utt['words'].tolist()})")
    print(f"session {session!r}: slot freed for the next connection")


if __name__ == "__main__":
    main()
