"""End-to-end ASR: train the paper's system, then transcribe streamed audio.

The full wav2letter loop from §4 of the paper at toy scale:
  1. synthesize a speech corpus over a small lexicon,
  2. train a TDS acoustic model with CTC,
  3. load it into the ASRPU runtime (configure commands),
  4. stream held-out utterances through DecodingStep / 80 ms chunks,
  5. report partial transcripts per chunk + final WER.

  PYTHONPATH=src python examples/train_and_transcribe_asr.py
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.tds_asr import (DecoderConfig, FeatureConfig, TDSConfig,
                                   TDSStage)
from repro.core import ctc, features, lexicon as lx
from repro.core.scheduler import ASRPU
from repro.data.pipeline import SyntheticASR
from repro.models import tds
from repro.optim import adamw


def main():
    feat_cfg = FeatureConfig(n_mels=16, n_mfcc=16)
    tds_cfg = TDSConfig(
        stages=(TDSStage(1, 3, 16, 5, 2), TDSStage(1, 3, 16, 5, 2),
                TDSStage(1, 4, 16, 5, 2)),
        sub_kernel=6, vocab_size=8)
    words = {"a": [1], "bc": [2, 3], "d": [4]}
    lex = lx.build_lexicon(words, max_children=8)
    lm = lx.uniform_bigram(len(words))
    data = SyntheticASR(words, tok_ms=200.0)

    # --- corpus ----------------------------------------------------------
    utts = [data.utterance(i, n_words=2) for i in range(8)]
    train, test = utts[:6], utts[6:]
    max_audio = max(len(u["audio"]) for u in utts)

    def featurize(u):
        audio = np.zeros((max_audio,), np.float32)
        audio[:len(u["audio"])] = u["audio"]
        return features.mfcc(jnp.asarray(audio), feat_cfg)

    X = jnp.stack([featurize(u) for u in train])
    T = (X.shape[1] // 8) * 8
    X = X[:, :T]
    Y = jnp.asarray(np.stack([np.pad(u["tokens"], (0, 8 - len(u["tokens"])),
                                     constant_values=-1) for u in train]))

    # --- train (CTC) ------------------------------------------------------
    params = tds.init_tds(jax.random.PRNGKey(0), tds_cfg)

    def loss_fn(p):
        lps = jax.vmap(lambda x: tds.forward(p, tds_cfg, x)[0])(X)
        return ctc.ctc_loss_batch(lps, Y)

    ocfg = adamw.AdamWConfig(lr=3e-3, weight_decay=0.0)
    opt = adamw.init(params, ocfg)
    step = jax.jit(lambda p, o: (lambda g: adamw.update(g, o, p, ocfg))(
        jax.grad(loss_fn)(p)))
    print(f"training TDS ({sum(x.size for x in jax.tree.leaves(params))} "
          f"params) with CTC...")
    for it in range(120):
        params, opt = step(params, opt)
        if (it + 1) % 40 == 0:
            print(f"  step {it+1}: ctc loss {float(loss_fn(params)):.4f}")

    # --- serve: stream the held-out utterances through the ASRPU runtime --
    asrpu = ASRPU()
    asrpu.configure_acoustic_scoring(tds_cfg, params, feat_cfg)
    dcfg = DecoderConfig(beam_size=16, beam_threshold=1e9, lm_weight=0.5,
                         word_score=0.0)
    asrpu.configure_hyp_expansion(lex, lm, dcfg)

    refs, hyps = [], []
    spp = asrpu.plan.samples_per_step
    for u in test:
        asrpu.clean_decoding()
        audio = np.zeros((max_audio,), np.float32)
        audio[:len(u["audio"])] = u["audio"]
        partials = []
        for off in range(0, len(audio), spp):
            b = asrpu.decoding_step(audio[off:off + spp])
            partials.append(list(b["words"]))
        final = asrpu.best(final=True)
        print(f"  utt ref={list(u['words'])} partials={partials[::4]} "
              f"final={list(final['words'])}")
        refs.append(list(u["words"]))
        hyps.append(list(final["words"]))
    print(f"held-out WER: {ctc.wer(refs, hyps):.2f}")


if __name__ == "__main__":
    main()
